# Reproducible entry points. `make test` is the tier-1 verify command.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-compiler bench-smoke

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q tests/test_compiler.py tests/test_core.py

bench:
	$(PY) -m benchmarks.run

bench-compiler:
	$(PY) -m benchmarks.run --mode compiler

# tiny-shape compiler benchmark as a smoke test (~seconds); the tier-1 suite
# runs the same path in-process via tests/test_benchmarks.py
bench-smoke:
	$(PY) -m benchmarks.run --mode compiler --smoke
