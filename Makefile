# Reproducible entry points. `make test` is the tier-1 verify command.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-diff bench bench-compiler bench-smoke \
	bench-serve bench-serve-smoke bench-load-smoke bench-overload-smoke \
	trace-smoke chaos-smoke tune-smoke

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q tests/test_compiler.py tests/test_core.py

# differential harness on tiny shapes: every autopump.BUILDERS entry x
# backends (reference/jax/pallas) x M in {1,2,4} x modes {T,R} vs the numpy
# reference executor (tests/differential.py; second shapes for the carry /
# grouped kernels run jax+pallas only — the full reference sweep is
# `python tests/differential.py`).  Runs inside the tier-1 budget.
test-diff:
	$(PY) -m pytest -x -q tests/test_compiler.py -k "differential"

bench:
	$(PY) -m benchmarks.run

bench-compiler:
	$(PY) -m benchmarks.run --mode compiler

# tiny-shape compiler benchmark as a smoke test (~seconds); the tier-1 suite
# runs the same path in-process via tests/test_benchmarks.py
bench-smoke:
	$(PY) -m benchmarks.run --mode compiler --smoke

# serving-path benchmark: measured plan registry vs default-pump direct ops
# (writes BENCH_serve.json — per-layer step time for prefill AND the
# per-token decode rows (kernelized decode_attention/ssd_decode vs plain
# jnp), plan hit rate split by phase, measured vs default pump).  The smoke
# variant is wired into tier-1 alongside bench-smoke via
# tests/test_benchmarks.py, which asserts the decode rows are present.
bench-serve:
	$(PY) -m benchmarks.run --mode serve

bench-serve-smoke:
	$(PY) -m benchmarks.run --mode serve --smoke

# throughput-under-load smoke: a tiny synthetic arrival trace through the
# continuous-batching scheduler (docs/serving.md) — slot occupancy, queue
# waits, per-request TTFT and tok/s from the launcher.  The BENCH_serve
# row for the same protocol ("load") is asserted fail-loud by
# tests/test_benchmarks.py, like the decode rows.
bench-load-smoke:
	$(PY) -m repro.launch.serve --arch qwen3-0.6b --smoke --batch 2 \
		--prompt-len 8 --new 4 --arrival-rate 0.5 --requests 6

# overload smoke: the same launcher at 2x the service rate with every
# overload control on — chunked prefill, lowest-priority preemption, a
# bounded admission queue and deadline-aware shedding (docs/serving.md
# "Overload behavior").  The BENCH_serve row for this protocol
# ("overload", schema 4: p99 TTFT/TPOT + shed rate, chunked+preemptive vs
# unbounded FIFO) is asserted fail-loud by tests/test_benchmarks.py.
bench-overload-smoke:
	$(PY) -m repro.launch.serve --arch qwen3-0.6b --smoke --batch 2 \
		--prompt-len 8 --new 4 --arrival-rate 2.0 --requests 16 \
		--prefill-chunk-tokens 4 --preempt lowest_priority \
		--max-queue 6 --deadline-ms 12

# chaos smoke: the fault-injection matrix (docs/robustness.md) — every
# injection point on the compile→serve path must degrade one ladder rung
# and still produce fault-free tokens at ≤5e-6 logit parity, plus the
# self-healing plan-store contracts (quarantine backoff, corruption
# recovery, cross-process write merging).  Runs inside tier-1: `make test`
# picks up tests/test_chaos.py with the rest of the suite.
chaos-smoke:
	$(PY) -m pytest -x -q tests/test_chaos.py

# offline-tuner smoke (docs/robustness.md "Artifact lifecycle"): one fleet
# pass measures the deduped plan grid under heartbeat-stamped leases and
# publishes the verified plan artifact; a cold replica then serves from it
# — its warmup must print "0 freshly measured".  The same contract (plus
# the lease-reclaim / salvage / per-entry-rejection crash cases) is wired
# into tier-1 via tests/test_tune.py and the BENCH_serve "warm_start" row.
tune-smoke:
	rm -rf /tmp/repro_tune_smoke
	$(PY) -m repro.launch tune --arch qwen3-0.6b --smoke --batch 2 \
		--max-len 16 --attention-impl pallas --shards 2 \
		--work-dir /tmp/repro_tune_smoke \
		--out /tmp/repro_tune_smoke/plans.artifact.json
	REPRO_CACHE_DIR=/tmp/repro_tune_smoke/replica \
	$(PY) -m repro.launch serve --arch qwen3-0.6b --smoke --batch 2 \
		--prompt-len 8 --new 4 --attention-impl pallas \
		--kernel-plan measure \
		--plan-artifact /tmp/repro_tune_smoke/plans.artifact.json

# flight-recorder smoke: one traced Engine.generate() through the serve
# launcher must produce valid Chrome-trace JSON (nested warmup/prefill/
# per-token-decode spans — open at ui.perfetto.dev).  The same contract is
# wired into tier-1 via tests/test_benchmarks.py::test_trace_smoke_launcher.
trace-smoke:
	$(PY) -m repro.launch.serve --arch qwen3-0.6b --smoke --batch 2 \
		--prompt-len 8 --new 4 --trace /tmp/repro_trace_smoke.json \
		--metrics
	$(PY) -c "import json; t=json.load(open('/tmp/repro_trace_smoke.json')); \
		assert t['traceEvents'], 'empty trace'; \
		print('trace-smoke ok:', len(t['traceEvents']), 'events')"
