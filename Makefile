# Reproducible entry points. `make test` is the tier-1 verify command.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-compiler

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q tests/test_compiler.py tests/test_core.py

bench:
	$(PY) -m benchmarks.run

bench-compiler:
	$(PY) -m benchmarks.run compiler
