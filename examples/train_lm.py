"""End-to-end driver: train a ~100M-param qwen3-family LM for a few hundred
steps with the pumped gradient stream, checkpointing, and failure recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--dim 512]

On this CPU container the default config is ~15M params so a few hundred
steps finish in minutes; pass --dim 768 --layers 12 for the full ~100M run
(same code path, longer wall time).  On a TPU slice, swap the host mesh for
make_production_mesh() — nothing else changes.
"""
import argparse
import shutil
import tempfile

from repro import optim
from repro.configs.base import ModelConfig, ShapeConfig
from repro.train.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--pump", default="2")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="example-lm", family="dense",
        n_layers=args.layers, d_model=args.dim,
        n_heads=max(4, args.dim // 64), n_kv_heads=max(2, args.dim // 128),
        d_ff=args.dim * 4, vocab_size=8192, qk_norm=True,
        tie_embeddings=True, dtype="float32")
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    shape = ShapeConfig("ex", args.seq, args.batch, "train")

    ckpt_root = tempfile.mkdtemp(prefix="repro_ckpt_")
    pump = args.pump if args.pump == "auto" else int(args.pump)
    out = train(
        cfg, shape,
        optim.AdamWConfig(lr=1e-3, warmup_steps=args.steps // 10,
                          total_steps=args.steps),
        TrainConfig(n_steps=args.steps, pump_factor=pump,
                    ckpt_root=ckpt_root, ckpt_every=100, log_every=25))
    h = out["history"]
    print(f"[example] loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"(pump={out['pump']}, ckpts in {ckpt_root})")
    assert h[-1]["loss"] < h[0]["loss"], "loss must decrease"
    shutil.rmtree(ckpt_root, ignore_errors=True)


if __name__ == "__main__":
    main()
