"""Quickstart: temporal vectorization end to end in five minutes (CPU).

1. Build a dataflow graph for a computation, stream it, multi-pump it, and
   watch the resource/throughput numbers move exactly as in the paper.
2. Run the corresponding Pallas kernel (interpret mode) in both modes.
3. Train a tiny LM with the *pod-scale* pump (microbatched gradient stream).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (AccessPattern, Affine, Domain, Graph,
                        apply_multipump, apply_streaming, executor,
                        throughput_model)
from repro.core.ir import PumpSpec
from repro.kernels import ops


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


# ---------------------------------------------------------------------------
section("1. The compiler view: stream, then multi-pump")
N, V = 64, 4
g = Graph("vecadd")
g.memory("x", (N,)); g.memory("y", (N,)); g.memory("z", (N,))
dom = Domain.of(("i", 0, N // V))
acc = AccessPattern(dom, (Affine.of("i", V),), width=V)
g.compute("add", dom, fn=lambda in0, in1: {"out0": in0 + in1},
          vector_width=V)
g.connect("x", "add", acc); g.connect("y", "add", acc)
g.connect("add", "z", acc)

streamed, report = apply_streaming(g)
print("streaming pass:", report.streamed)

for mode, label in (("T", "throughput ×M at equal resources"),
                    ("R", "resources ÷M at equal throughput")):
    pumped, rep = apply_multipump(streamed, factor=2, mode=mode)
    r0, r1 = rep.resources_before, rep.resources_after
    print(f"mode {mode} ({label}):")
    print(f"  compute units {r0['compute_units']} -> {r1['compute_units']}, "
          f"adapters +{r1['adapters']}, "
          f"throughput {throughput_model(streamed):.0f} -> "
          f"{throughput_model(pumped):.0f} elems/cycle")
    # value preservation
    x = np.arange(N, dtype=np.float32); y = 2 * x
    out = executor.run(pumped, {"x": x, "y": y})["z"]
    assert np.allclose(out, x + y)
print("value-preservation: OK (issuer/packer are exact inverses)")

# ---------------------------------------------------------------------------
section("2. The kernel view: pumped Pallas kernels (interpret mode)")
a = jax.random.normal(jax.random.PRNGKey(0), (128, 128), jnp.float32)
b = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.float32)
gold = a @ b
for pump in (PumpSpec(1), PumpSpec(2, "T"), PumpSpec(2, "R")):
    out = ops.matmul(a, b, bm=64, bn=64, bk=32, pump=pump)
    err = float(jnp.abs(out - gold).max())
    print(f"matmul pump={pump.factor} mode={pump.mode}: max err {err:.1e}")

# the dependency-carrying showcase: Floyd-Warshall cannot be spatially
# vectorized, but pumps fine — and AUTOPUMP picks M automatically by
# running the full §3 pipeline (IR -> streaming -> capacity -> transform)
from repro.core import autopump
from repro.kernels import ref
plan = autopump("floyd_warshall", 64)
print(f"autopump(floyd_warshall): {plan.summary()}")
d = jax.random.uniform(jax.random.PRNGKey(2), (64, 64), jnp.float32, 0.1, 10)
fw1 = ops.floyd_warshall(d, pump=1)
fw2 = ops.floyd_warshall(d, pump=plan.spec)
assert np.allclose(np.asarray(fw1), np.asarray(fw2), atol=1e-5)
print("floyd-warshall pumped == original: dependencies preserved")

# ---------------------------------------------------------------------------
section("3. The pod view: pumped gradient stream (grad accumulation)")
from repro.configs.base import ModelConfig, ShapeConfig
from repro.train.trainer import TrainConfig, train
from repro import optim

cfg = ModelConfig("quickstart-lm", "dense", 2, 64, 4, 2, 128, 128,
                  dtype="float32")
shape = ShapeConfig("qs", 64, 8, "train")
out = train(cfg, shape,
            optim.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30),
            TrainConfig(n_steps=30, pump_factor=4, log_every=10))
print(f"trained with pump=4: loss {out['history'][0]['loss']:.3f} -> "
      f"{out['history'][-1]['loss']:.3f}")
print("\nquickstart complete.")
