"""Fault-tolerance drill: kill training mid-run, resume from checkpoint,
then elastically re-mesh the checkpoint onto a different data-parallel
degree.

    PYTHONPATH=src python examples/failover_drill.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import manager as ckpt
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataIterator
from repro.launch import sharding as shard_mod
from repro.launch import steps as steps_mod
from repro.models import model as model_mod
from repro.runtime import failover

CFG = ModelConfig("drill", "dense", 2, 64, 4, 2, 128, 128, dtype="float32")
SHAPE = ShapeConfig("d", 64, 8, "train")


def main():
    root = tempfile.mkdtemp(prefix="repro_drill_") + "/ckpt"
    optcfg = optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    params = model_mod.init_params(CFG, jax.random.PRNGKey(0))
    opt_state = optim.init(optcfg, params)
    step_fn = jax.jit(steps_mod.make_train_step(CFG, optcfg))
    data = DataIterator(CFG, SHAPE)

    fail_once = {"armed": True}

    def train_fn(state, step):
        if step == 13 and fail_once["armed"]:
            fail_once["armed"] = False
            print(f"[drill] >>> injecting node failure at step {step} <<<")
            raise failover.FailureInjected("simulated TPU slice loss")
        data.step = step          # exactly-once batches
        p, o, m = step_fn(state["params"], state["opt"], next(data))
        if step % 10 == 0:
            print(f"[drill] step {step:3d} loss {float(m['loss']):.4f}")
        return {"params": p, "opt": o}

    final = failover.run_with_recovery(
        train_fn, {"params": params, "opt": opt_state},
        n_steps=25, ckpt_root=root, ckpt_every=5)
    print("[drill] survived the failure; 25 effective steps completed")

    # --- elastic re-mesh: place the checkpoint on a different mesh ----------
    latest = ckpt.latest_valid(root)
    new_mesh = jax.make_mesh((1, 1), ("data", "model"))
    placed, extra = failover.elastic_remesh(
        latest, final, new_mesh, lambda t, m: shard_mod.shardings(t, m))
    n = sum(l.size for l in jax.tree.leaves(placed["params"]))
    print(f"[drill] elastically re-meshed checkpoint (step {extra['step']}, "
          f"{n/1e3:.0f}K params) onto mesh {dict(zip(new_mesh.axis_names, new_mesh.devices.shape))}")
    # straggler policy demo
    pol = failover.StragglerPolicy(base_pump=8)
    for w, t in [(0, 1.0), (1, 1.05), (2, 3.2)]:
        for _ in range(10):
            pol.observe(w, t)
    print(f"[drill] straggler-aware pump factors: {pol.pump_factors()} "
          "(slow host derated, sync schedule preserved)")


if __name__ == "__main__":
    main()
