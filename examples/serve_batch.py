"""Serve a small model with batched requests: prefill + pumped decode.

Demonstrates the serving path for three architecture families (dense GQA,
MLA, SSM) with the same Engine, including the compressed-MLA cache and the
O(1) SSM state cache.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import load_arch
from repro.models import model as model_mod
from repro.serve.engine import Engine, ServeConfig


def demo(arch: str, batch=2, prompt=8, new=8):
    cfg = load_arch(arch, smoke=True)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(batch=batch,
                                          max_len=prompt + new + 1))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompts, new)
    dt = time.time() - t0
    print(f"[serve] {arch:24s} generated {tuple(out.shape)} "
          f"in {dt:5.1f}s  ({batch * new / dt:5.1f} tok/s)  "
          f"first: {out[0][:6].tolist()}")
    return out


def main():
    demo("qwen3-0.6b")            # dense GQA + qk_norm
    demo("deepseek-v2-lite-16b")  # MLA compressed cache + MoE dropless
    demo("mamba2-1.3b")           # SSM recurrent state, O(1) per token
    demo("zamba2-2.7b")           # hybrid
    print("[serve] all families served.")


if __name__ == "__main__":
    main()
