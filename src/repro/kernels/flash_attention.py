"""Flash attention with a temporally-pumped KV stream.

The transformer hot-spot kernel; the paper's technique applies to its
*KV feeding path*: attention's inner loop carries a sequential dependency
(the online-softmax running max/denominator), so the KV loop cannot be
spatially vectorized across blocks — but it can be *temporally* vectorized:

  one grid step DMAs a KV panel widened ×M from HBM (the wide transaction on
  the long path) and the in-kernel fori_loop (issuer) performs M dependent
  online-softmax updates back-to-back in the fast domain.  Grid-step count —
  and with it per-step DMA descriptor overhead — drops ×M; the VMEM-resident
  compute tile (q block × head_dim) is untouched.

Layout: q (B, Hq, S, D), k/v (B, Hkv, T, D) with GQA folding done via the
BlockSpec index map (kv head = q head // group) so no materialized repeat.
The softmax state (m, l, acc) lives in VMEM scratch and persists across the
sequential innermost KV grid dimension — the Pallas analogue of the paper's
accumulator staying inside the fast clock domain between transactions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ir import PumpSpec

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  pump: int, bkv: int, bq: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)

    def issue(mstep, _):
        k = k_ref[0, 0, pl.dslice(mstep * bkv, bkv), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(mstep * bkv, bkv), :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bkv)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            k_pos = (ki * pump + mstep) * bkv + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_cur
        return _

    jax.lax.fori_loop(0, pump, issue, None, unroll=False)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = False,
                           scale: float | None = None,
                           bq: int = 128, bkv: int = 128,
                           pump: PumpSpec | int = 1,
                           interpret: bool = True) -> jax.Array:
    """Multi-head attention. q: (B, Hq, S, D), k/v: (B, Hkv, T, D)."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    mfac = pump.factor
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not divisible by Hkv={hkv}")
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    bq = min(bq, s)
    bkv = min(bkv, t)
    kwide = bkv * mfac
    if s % bq or t % kwide:
        raise ValueError(f"S={s} %% bq={bq} or T={t} %% bkv*M={kwide} != 0; "
                         "pad in the ops wrapper")
    grid = (b, hq, s // bq, t // kwide)

    kernel = functools.partial(_flash_kernel, pump=mfac, bkv=bkv, bq=bq,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, kwide, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, kwide, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def transactions(b: int, hq: int, s: int, t: int, bq: int = 128,
                 bkv: int = 128, pump: PumpSpec | int = 1) -> int:
    """KV-stream grid steps (wide DMA transactions)."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    return b * hq * (s // min(bq, s)) * (t // (min(bkv, t) * pump.factor))
