"""Jit'd public wrappers around the kernel library.

Responsibilities: shape padding to block multiples, dtype policy, automatic
pump-factor planning (``pump='auto'`` asks the capacity model, ``'measure'``
times candidates), and the interpret/compile switch (CPU container validates
with interpret=True; on TPU pass interpret=False).

Flash attention, the SSD scan and grouped GEMM are **compiled, not
hand-scheduled**: their default path builds the kernel's executable IR graph
(:mod:`repro.core.autopump`) and routes it through
``repro.compiler.compile(backend='pallas')`` — the fused-region emission
derives the BlockSpecs, carry scratch and pump schedule that the hand-wired
Pallas kernels in this package previously encoded by hand.  The hand-wired
kernels remain as a differential reference and as the fallback
(``impl='pallas'`` or any compiler-route failure, which warns visibly).

The decode hot path is compiler-only: :func:`decode_attention` (S=1 against
a preallocated KV cache, position-offset mask from an int32 ``pos`` input),
:func:`ssd_decode` (single-token SSD state update, multi-output tile
emission) and ``ssd_scan(final_state=True)`` (the scan plus its final
inter-chunk state) have no hand-wired counterparts — serving reaches them
through the plan registry's pos-bucketed wrappers.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ir import PumpSpec
from repro.core.pump_plan import VMEM_BYTES

from . import flash_attention as _fa
from . import grouped_gemm as _gg
from . import floyd_warshall as _fw
from . import matmul as _mm
from . import ssd_scan as _ssd
from . import stencil as _st
from . import vecadd as _va


def _as_spec(pump, kernel: Optional[str] = None, builder_args=(),
             builder_kwargs=None, **plan_kwargs) -> PumpSpec:
    if pump == "auto":
        # compiler-backed planning: the chosen factor is memoized in the
        # persistent compile cache, so repeated serve/benchmark processes
        # skip the capacity-model search entirely.
        from repro.compiler import plan_pump
        return plan_pump(**plan_kwargs)
    if pump == "measure":
        # measured-runtime planning: compile the kernel's IR graph through
        # the fused-region pallas backend with autotune='measure' and reuse
        # the winning factor here; the measured plan persists in the same
        # compile cache, so only the first process ever pays the timing runs.
        spec = _measured_spec(kernel, builder_args, builder_kwargs or {})
        if spec is not None:
            return spec
        from repro.compiler import plan_pump
        return plan_pump(**plan_kwargs)
    if isinstance(pump, int):
        return PumpSpec(factor=pump)
    return pump


def _measured_spec(kernel, builder_args, builder_kwargs):
    if kernel is None:
        return None
    from repro.core.autopump import BUILDERS
    from repro import compiler
    try:
        g, est = BUILDERS[kernel](*builder_args, **builder_kwargs)
        kern = compiler.compile(g, factor="auto", estimate=est,
                                backend="pallas", autotune="measure")
    except compiler.LoweringError as e:
        # expected for non-executable builder shapes (e.g. non-divisible
        # blocks leave fn=None): fall back to the capacity model, visibly
        import warnings
        warnings.warn(f"pump='measure' for {kernel}: graph not executable "
                      f"({e}); falling back to capacity-model planning",
                      stacklevel=3)
        return None
    return kern.spec


def _pump_request(pump):
    """Normalize a ``pump`` argument into ``(factor, mode, autotune)`` for
    ``compiler.compile``: ``'auto'`` → capacity-model factor, ``'measure'``
    → measured-runtime autotune, int/PumpSpec → explicit."""
    if pump == "auto":
        return "auto", "T", None
    if pump == "measure":
        return "auto", "T", "measure"
    if isinstance(pump, PumpSpec):
        return pump.factor, pump.mode, None
    return int(pump), "T", None


def _on_accelerator() -> bool:
    return any(d.platform == "tpu" for d in jax.devices())


def _use_compiler_route(impl: str, interpret: bool) -> bool:
    """The compiler route serves CPU validation (its carryloop/blockloop jit
    tiers) and real TPU emission.  ``interpret=False`` on CPU is an explicit
    request for *compiled* pallas execution, which the hand-wired path
    reports loudly instead of being silently downgraded."""
    return impl == "compiler" and (interpret or _on_accelerator())


@functools.lru_cache(maxsize=256)
def _compile_kernel_cached(kernel: str, builder_args, builder_kwargs_items,
                           pump):
    """Build the kernel's IR graph and compile it through the fused-region
    pallas backend.  The lru layer skips per-call graph reconstruction and
    fingerprint hashing on repeat shapes (the compiler's own memo already
    makes the compile itself O(1))."""
    from repro import compiler
    from repro.core.autopump import BUILDERS
    factor, mode, autotune = _pump_request(pump)
    g, est = BUILDERS[kernel](*builder_args, **dict(builder_kwargs_items))
    return compiler.compile(g, factor=factor, mode=mode, estimate=est,
                            backend="pallas", autotune=autotune)


def _compile_kernel(kernel: str, builder_args, builder_kwargs, pump):
    return _compile_kernel_cached(kernel, tuple(builder_args),
                                  tuple(sorted(builder_kwargs.items())),
                                  pump if isinstance(pump, (PumpSpec, str))
                                  else int(pump))


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), n


# ------------------------------------------------------------------ vecadd --
@functools.partial(jax.jit, static_argnames=("vector_width", "pump_factor",
                                             "pump_mode", "interpret"))
def _vecadd(x, y, vector_width, pump_factor, pump_mode, interpret):
    spec = PumpSpec(factor=pump_factor, mode=pump_mode)
    block = vector_width * (pump_factor if pump_mode == "T" else 1)
    xp, n = _pad_to(x, 0, block)
    yp, _ = _pad_to(y, 0, block)
    return _va.vecadd_pallas(xp, yp, vector_width=vector_width, pump=spec,
                             interpret=interpret)[:n]


def vecadd(x, y, *, vector_width: int = 8, pump: PumpSpec | int | str = 1,
           interpret: bool = True):
    """``pump``: factor, PumpSpec, ``'auto'`` (capacity model) or
    ``'measure'`` (timed on the compiled IR graph, cached)."""
    spec = _as_spec(pump, kernel="vecadd", builder_args=(x.shape[0],),
                    builder_kwargs=dict(vector_width=vector_width),
                    block_bytes_in=2 * vector_width * x.dtype.itemsize,
                    block_bytes_out=vector_width * x.dtype.itemsize,
                    flops_per_block=vector_width)
    return _vecadd(x, y, vector_width, spec.factor, spec.mode, interpret)


# ------------------------------------------------------------------ matmul --
@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "pump_factor",
                                             "pump_mode", "interpret"))
def _matmul(a, b, bm, bn, bk, pump_factor, pump_mode, interpret):
    spec = PumpSpec(factor=pump_factor, mode=pump_mode)
    kw = bk * (pump_factor if pump_mode == "T" else 1)
    ap, m = _pad_to(a, 0, bm)
    ap, k = _pad_to(ap, 1, kw)
    bp, _ = _pad_to(b, 0, kw)
    bp, n = _pad_to(bp, 1, bn)
    out = _mm.matmul_pallas(ap, bp, bm=bm, bn=bn, bk=bk, pump=spec,
                            interpret=interpret)
    return out[:m, :n]


def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
           pump: PumpSpec | int | str = 1, interpret: bool = True):
    """``pump``: factor, PumpSpec, ``'auto'`` (capacity model) or
    ``'measure'`` (timed on the compiled IR graph, cached)."""
    spec = _as_spec(
        pump, kernel="matmul",
        builder_args=(a.shape[0], b.shape[1], a.shape[1]),
        builder_kwargs=dict(bm=bm, bn=bn, bk=bk),
        block_bytes_in=(bm * bk + bk * bn) * a.dtype.itemsize,
        block_bytes_out=0,  # accumulated in VMEM, written once per tile
        flops_per_block=2.0 * bm * bn * bk)
    return _matmul(a, b, bm, bn, bk, spec.factor, spec.mode, interpret)


# ----------------------------------------------------------------- stencil --
@functools.partial(jax.jit, static_argnames=("stages", "kind", "coef",
                                             "pump_factor", "interpret"))
def _stencil(x, stages, kind, coef, pump_factor, interpret):
    return _st.stencil_chain_pallas(x, stages, kind=kind, coef=coef,
                                    pump=pump_factor, interpret=interpret)


def stencil_chain(x, stages: int, *, kind: str = "jacobi", coef: float = 0.1,
                  pump: PumpSpec | int = 1, interpret: bool = True):
    f = pump.factor if isinstance(pump, PumpSpec) else pump
    if (x.shape[0] - 2) % f:
        raise ValueError("interior plane count must divide the pump factor")
    return _stencil(x, stages, kind, coef, f, interpret)


# ---------------------------------------------------------- floyd-warshall --
@functools.partial(jax.jit, static_argnames=("pump_factor", "interpret"))
def _fw_run(d, pump_factor, interpret):
    return _fw.floyd_warshall_pallas(d, pump=pump_factor, interpret=interpret)


def floyd_warshall(dist, *, pump: PumpSpec | int = 1, interpret: bool = True):
    f = pump.factor if isinstance(pump, PumpSpec) else pump
    n = dist.shape[0]
    if n % f:
        raise ValueError(f"n={n} must divide pump factor {f}")
    return _fw_run(dist, f, interpret)


# --------------------------------------------------------- flash attention --
@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "pump_factor", "interpret"))
def _flash(q, k, v, causal, bq, bkv, pump_factor, interpret):
    spec = PumpSpec(factor=pump_factor)
    b, hq, s, d = q.shape
    kwide = min(bkv, k.shape[2]) * pump_factor
    qp, s0 = _pad_to(q, 2, min(bq, s))
    kp, _ = _pad_to(k, 2, kwide)
    vp, _ = _pad_to(v, 2, kwide)
    # padded KV positions must not contribute: causal masking handles the
    # tail for causal=True; for non-causal we bias keys via -inf on k? We
    # instead require T % bkv == 0 after padding and mask via position ids:
    # simplest robust approach: pad K with -inf-scoring keys by zeroing V and
    # giving K a huge negative last-dim component is fragile; we pad S only.
    out = _fa.flash_attention_pallas(qp, kp, vp, causal=causal,
                                     bq=min(bq, s), bkv=min(bkv, k.shape[2]),
                                     pump=spec, interpret=interpret)
    return out[:, :, :s0, :]


def _flash_compiled(q, k, v, causal, bq, bkv, pump):
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    bq, bkv = min(bq, s), min(bkv, t)
    if t % bkv:
        raise ValueError(f"T={t} %% bkv={bkv} != 0")
    qp, s0 = _pad_to(q, 2, bq)
    kern = _compile_kernel(
        "flash_attention", (b, hq, qp.shape[2], t, d),
        dict(bq=bq, bkv=bkv, hkv=hkv, causal=causal, dtype=str(q.dtype),
             itemsize=q.dtype.itemsize), pump)
    out = kern({"q": qp, "k": k, "v": v})["o"]
    return out[:, :, :s0, :]


def flash_attention(q, k, v, *, causal: bool = False, bq: int = 128,
                    bkv: int = 128, pump: PumpSpec | int | str = 1,
                    interpret: bool = True, impl: str = "compiler"):
    """Multi-head attention (GQA folded via a group-indexed table).

    ``impl='compiler'`` (default) compiles the executable IR builder through
    ``repro.compiler`` — BlockSpecs, the online-softmax carry and the pump
    schedule are all derived; ``impl='pallas'`` forces the hand-wired kernel
    (kept as the differential reference).  ``interpret=False`` on CPU keeps
    the hand-wired path's loud failure semantics."""
    if _use_compiler_route(impl, interpret):
        try:
            return _flash_compiled(q, k, v, causal, bq, bkv, pump)
        except Exception as e:
            warnings.warn(f"flash_attention: compiler route failed ({e}); "
                          "falling back to the hand-wired kernel",
                          stacklevel=2)
    d = q.shape[-1]
    spec = _as_spec(pump,
                    block_bytes_in=2 * bkv * d * q.dtype.itemsize,
                    block_bytes_out=0,
                    flops_per_block=4.0 * bq * bkv * d)
    if k.shape[2] % (min(bkv, k.shape[2]) * spec.factor):
        raise ValueError("KV length must divide bkv * pump factor")
    return _flash(q, k, v, causal, bq, bkv, spec.factor, interpret)


# ---------------------------------------------------------------- SSD scan --
@functools.partial(jax.jit, static_argnames=("chunk", "pump_factor",
                                             "interpret"))
def _ssd_jit(x, dt, A, B, C, chunk, pump_factor, interpret):
    return _ssd.ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                                pump=pump_factor, interpret=interpret)


def _ssd_compiled(x, dt, A, B, C, chunk, pump, final_state=False):
    b, l, h, p = x.shape
    grp, n = B.shape[2], B.shape[3]
    chunk = min(chunk, l)
    if l % chunk:
        raise ValueError(f"L={l} %% chunk={chunk} != 0")
    kern = _compile_kernel(
        "ssd_scan", (b, l, h, p, n),
        dict(chunk=chunk, n_groups=grp, dtype=str(x.dtype),
             itemsize=x.dtype.itemsize, final_state=bool(final_state)), pump)
    out = kern({"x": x, "dt": dt, "a": A, "bmat": B, "cmat": C})
    if final_state:
        return out["y"], out["state"]
    return out["y"]


def ssd_scan(x, dt, A, B, C, *, chunk: int = 16,
             pump: PumpSpec | int | str = 1, interpret: bool = True,
             impl: str = "compiler", final_state: bool = False):
    """Mamba-2 SSD chunked scan.  ``impl='compiler'`` (default) compiles the
    carry-graph IR builder; ``impl='pallas'`` forces the hand-wired kernel
    (the differential reference).  ``final_state=True`` also returns the
    final inter-chunk state (B, H, N, P) as a second output — the carry
    state surfaced through ``CarrySpec.final_fn``; compiler-only (the
    hand-wired kernel never exposes its state)."""
    if _use_compiler_route(impl, interpret):
        try:
            return _ssd_compiled(x, dt, A, B, C, chunk, pump, final_state)
        except Exception as e:
            if final_state:
                raise   # no hand-wired fallback can produce the state
            warnings.warn(f"ssd_scan: compiler route failed ({e}); falling "
                          "back to the hand-wired kernel", stacklevel=2)
    if final_state:
        raise ValueError("ssd_scan(final_state=True) requires the compiler "
                         "route (impl='compiler')")
    b, l, h, p = x.shape
    n = B.shape[-1]
    spec = _as_spec(pump,
                    block_bytes_in=(chunk * (p + 1 + 2 * n)) * 4,
                    block_bytes_out=chunk * p * 4,
                    flops_per_block=2.0 * chunk * chunk * (n + p))
    if l % (chunk * spec.factor):
        raise ValueError(f"L={l} must divide chunk*M={chunk * spec.factor}")
    return _ssd_jit(x, dt, A, B, C, chunk, spec.factor, interpret)


# ------------------------------------------------------- decode attention --
def decode_attention(q, k_cache, v_cache, pos, *, bkv: int = 128,
                     pump: PumpSpec | int | str = 1, impl: str = "compiler"):
    """Single-position (S=1) attention against a preallocated KV cache.

    q: (B, H, D); caches: (B, Hkv, T, D); ``pos`` is the current write
    position (scalar or (B,) int32) — valid cache slots are 0..pos, masked
    *symbolically* inside the kernel (the position-offset causal mask is an
    index compare derived from the carry step, never a materialized (B, T)
    boolean).  Compiler-only: the decode builder has no hand-wired
    counterpart; serving routes here through the plan registry
    (``PlanRegistry.decode_attention``), which adds pos-bucketing."""
    if impl != "compiler":
        raise ValueError("decode_attention is compiler-only")
    b, h, d = q.shape
    hkv, t = k_cache.shape[1], k_cache.shape[2]
    bkv_e = min(bkv, t)
    if t % bkv_e:
        raise ValueError(f"T={t} %% bkv={bkv_e} != 0")
    kern = _compile_kernel(
        "decode_attention", (b, h, t, d),
        dict(bkv=bkv_e, hkv=hkv, dtype=str(q.dtype),
             itemsize=q.dtype.itemsize), pump)
    posv = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)),
                            (b,))
    return kern({"q": q, "k": k_cache, "v": v_cache, "pos": posv})["o"]


# -------------------------------------------------------------- ssd decode --
def ssd_decode(state, x, dt, A, B, C, *, pump: PumpSpec | int | str = 1,
               impl: str = "compiler"):
    """Single-token SSD recurrent step: ``state' = state·exp(A·dt) +
    (B·dt)⊗x``, ``y = C·state'``.  state: (B, H, N, P) fp32; x: (B, H, P);
    dt: (B, H) post-softplus; A: (H,); B/C: (B, G, N).  Returns
    (y, new_state).  Compiler-only (multi-output tile emission)."""
    if impl != "compiler":
        raise ValueError("ssd_decode is compiler-only")
    b, h, n, p = state.shape
    grp = B.shape[1]
    kern = _compile_kernel(
        "ssd_decode", (b, h, p, n),
        dict(n_groups=grp, dtype=str(x.dtype),
             itemsize=x.dtype.itemsize), pump)
    out = kern({"state": state, "x": x, "dt": dt, "a": A,
                "bmat": B, "cmat": C})
    return out["y"], out["state_out"]


# ------------------------------------------------------------ grouped gemm --
def ragged_request_args(e, d, f, padded, bc, bf, bd, dtype, itemsize):
    """Canonical (builder_args, builder_kwargs) for one ragged grouped-GEMM
    request.  The single source of truth for the compile/plan key: the plan
    registry derives warmup keys from it and the execution path below
    compiles under it, so a warmed plan is a guaranteed hit for the real
    call by construction."""
    rows_p = sum(padded)
    dp = -(-d // bd) * bd
    fp = -(-f // bf) * bf
    return ((e, rows_p, dp, fp),
            dict(bc=bc, bf=bf, bd=bd, group_sizes=tuple(padded),
                 dtype=dtype, itemsize=itemsize))


def ragged_grouped_gemm_compiled(x, w, sizes, padded, bc, bf, bd, *,
                                 kernel_fn=None, pump=1):
    """Shared ragged-execution core (megablocks idiom).

    ``x`` is a row-major concatenation of per-expert row groups
    (``sum(sizes)`` rows); each group is zero-padded up to ``padded[i]``
    (a multiple of the row tile ``bc``; 0 skips the expert entirely), the
    ragged IR builder compiles with group-indexed table access, and the real
    rows are sliced back out.  Callers that already hold the padded layout
    (``sizes == padded``, e.g. the MoE serving path, which scatters tokens
    into it once for all three expert GEMMs) skip the per-group
    segmentation and re-slicing entirely.  ``kernel_fn(builder_args,
    builder_kwargs)`` lets the plan registry own the compile (stats +
    measured plans); the default routes through this module's compile
    cache.
    """
    e, d, f = w.shape
    rows_p = sum(padded)
    if rows_p == 0:
        return jnp.zeros((0, f), x.dtype)
    prepadded = list(sizes) == list(padded)
    if prepadded:
        xp = x
    else:
        parts, off = [], 0
        for sz, psz in zip(sizes, padded):
            seg = x[off:off + sz]
            off += sz
            if psz > sz:
                seg = jnp.pad(seg, ((0, psz - sz), (0, 0)))
            if psz:
                parts.append(seg)
        xp = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    xp, _ = _pad_to(xp, 1, bd)
    wp, _ = _pad_to(w, 1, bd)
    wp, _ = _pad_to(wp, 2, bf)
    builder_args, builder_kwargs = ragged_request_args(
        e, d, f, padded, bc, bf, bd, str(x.dtype), x.dtype.itemsize)
    if kernel_fn is None:
        kern = _compile_kernel("grouped_gemm", builder_args, builder_kwargs,
                               pump)
    else:
        kern = kernel_fn(builder_args, builder_kwargs)
    out = kern({"x": xp, "w": wp})["o"][:, :f]
    if prepadded:
        return out
    outs, off = [], 0
    for sz, psz in zip(sizes, padded):
        if sz:
            outs.append(out[off:off + sz])
        off += psz
    if not outs:
        return jnp.zeros((0, f), x.dtype)
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "pump_factor",
                                             "pump_mode", "interpret"))
def _grouped(x, w, bc, bf, bd, pump_factor, pump_mode, interpret):
    spec = PumpSpec(factor=pump_factor, mode=pump_mode)
    dw = bd * (pump_factor if pump_mode == "T" else 1)
    xp, c0 = _pad_to(x, 1, bc)
    xp, d0 = _pad_to(xp, 2, dw)
    wp, _ = _pad_to(w, 1, dw)
    wp, f0 = _pad_to(wp, 2, bf)
    out = _gg.grouped_gemm_pallas(xp, wp, bc=bc, bf=bf, bd=bd, pump=spec,
                                  interpret=interpret)
    return out[:, :c0, :f0]


def _grouped_compiled(x, w, bc, bf, bd, pump):
    e, c, d = x.shape
    f = w.shape[2]
    bc, bf, bd = min(bc, c), min(bf, f), min(bd, d)
    xp_, c0 = _pad_to(x, 1, bc)
    xp_, _ = _pad_to(xp_, 2, bd)
    wp, _ = _pad_to(w, 1, bd)
    wp, f0 = _pad_to(wp, 2, bf)
    kern = _compile_kernel(
        "grouped_gemm", (e, xp_.shape[1], xp_.shape[2], wp.shape[2]),
        dict(bc=bc, bf=bf, bd=bd, dtype=str(x.dtype),
             itemsize=x.dtype.itemsize), pump)
    out = kern({"x": xp_, "w": wp})["o"]
    return out[:, :c0, :f0]


def grouped_gemm(x, w, *, bc: int = 128, bf: int = 128, bd: int = 128,
                 pump: PumpSpec | int | str = 1, interpret: bool = True,
                 impl: str = "compiler", group_sizes=None):
    """Per-expert batched GEMM (MoE hot-spot).

    Dense form (``group_sizes=None``): x (E,C,D) @ w (E,D,F).
    Ragged form: ``group_sizes`` is a static sequence of per-expert row
    counts; x is the (sum(group_sizes), D) row-major concatenation of the
    expert groups and the result keeps that layout — tokens pad only to the
    ``bc`` row tile instead of a dense worst-case capacity, and empty
    experts emit no tiles at all.  The ragged form is compiler-only
    (group-indexed table BlockSpecs have no hand-wired counterpart).

    ``impl='compiler'`` (default) compiles the IR builder (expert axis as
    the outermost grid symbol, contraction accumulated over the reduction
    symbol); ``impl='pallas'`` forces the hand-wired kernel."""
    if group_sizes is not None:
        if impl != "compiler":
            raise ValueError("ragged grouped_gemm (group_sizes=...) is "
                             "compiler-only; the hand-wired kernel has no "
                             "ragged form")
        sizes = [int(sz) for sz in group_sizes]
        e, d, f = w.shape
        if x.ndim != 2 or x.shape[0] != sum(sizes):
            raise ValueError(f"ragged x has {x.shape[0]} rows, group_sizes "
                             f"sum to {sum(sizes)}")
        if len(sizes) != e:
            raise ValueError(f"{len(sizes)} group sizes for {e} experts")
        bc_e = min(bc, max(max(sizes, default=1), 1))
        padded = [-(-sz // bc_e) * bc_e if sz else 0 for sz in sizes]
        return ragged_grouped_gemm_compiled(
            x, w, sizes, padded, bc_e, min(bf, f), min(bd, d),
            pump=pump if isinstance(pump, (PumpSpec, str)) else int(pump))
    if _use_compiler_route(impl, interpret):
        try:
            return _grouped_compiled(x, w, bc, bf, bd, pump)
        except Exception as e:
            warnings.warn(f"grouped_gemm: compiler route failed ({e}); "
                          "falling back to the hand-wired kernel",
                          stacklevel=2)
    spec = _as_spec(pump,
                    block_bytes_in=(bc * bd + bd * bf) * x.dtype.itemsize,
                    block_bytes_out=0,
                    flops_per_block=2.0 * bc * bf * bd)
    return _grouped(x, w, bc, bf, bd, spec.factor, spec.mode, interpret)
