"""Jit'd public wrappers around the Pallas kernels.

Responsibilities: shape padding to block multiples, dtype policy, automatic
pump-factor planning (``pump='auto'`` asks ``core.pump_plan`` for the best
factor under the VMEM capacity model), and the interpret/compile switch
(CPU container validates with interpret=True; on TPU pass interpret=False).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ir import PumpSpec
from repro.core.pump_plan import VMEM_BYTES

from . import flash_attention as _fa
from . import grouped_gemm as _gg
from . import floyd_warshall as _fw
from . import matmul as _mm
from . import ssd_scan as _ssd
from . import stencil as _st
from . import vecadd as _va


def _as_spec(pump, kernel: Optional[str] = None, builder_args=(),
             builder_kwargs=None, **plan_kwargs) -> PumpSpec:
    if pump == "auto":
        # compiler-backed planning: the chosen factor is memoized in the
        # persistent compile cache, so repeated serve/benchmark processes
        # skip the capacity-model search entirely.
        from repro.compiler import plan_pump
        return plan_pump(**plan_kwargs)
    if pump == "measure":
        # measured-runtime planning: compile the kernel's IR graph through
        # the fused-region pallas backend with autotune='measure' and reuse
        # the winning factor here; the measured plan persists in the same
        # compile cache, so only the first process ever pays the timing runs.
        spec = _measured_spec(kernel, builder_args, builder_kwargs or {})
        if spec is not None:
            return spec
        from repro.compiler import plan_pump
        return plan_pump(**plan_kwargs)
    if isinstance(pump, int):
        return PumpSpec(factor=pump)
    return pump


def _measured_spec(kernel, builder_args, builder_kwargs):
    if kernel is None:
        return None
    from repro.core.autopump import BUILDERS
    from repro import compiler
    try:
        g, est = BUILDERS[kernel](*builder_args, **builder_kwargs)
        kern = compiler.compile(g, factor="auto", estimate=est,
                                backend="pallas", autotune="measure")
    except compiler.LoweringError as e:
        # expected for non-executable builder shapes (e.g. non-divisible
        # blocks leave fn=None): fall back to the capacity model, visibly
        import warnings
        warnings.warn(f"pump='measure' for {kernel}: graph not executable "
                      f"({e}); falling back to capacity-model planning",
                      stacklevel=3)
        return None
    return kern.spec


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), n


# ------------------------------------------------------------------ vecadd --
@functools.partial(jax.jit, static_argnames=("vector_width", "pump_factor",
                                             "pump_mode", "interpret"))
def _vecadd(x, y, vector_width, pump_factor, pump_mode, interpret):
    spec = PumpSpec(factor=pump_factor, mode=pump_mode)
    block = vector_width * (pump_factor if pump_mode == "T" else 1)
    xp, n = _pad_to(x, 0, block)
    yp, _ = _pad_to(y, 0, block)
    return _va.vecadd_pallas(xp, yp, vector_width=vector_width, pump=spec,
                             interpret=interpret)[:n]


def vecadd(x, y, *, vector_width: int = 8, pump: PumpSpec | int | str = 1,
           interpret: bool = True):
    """``pump``: factor, PumpSpec, ``'auto'`` (capacity model) or
    ``'measure'`` (timed on the compiled IR graph, cached)."""
    spec = _as_spec(pump, kernel="vecadd", builder_args=(x.shape[0],),
                    builder_kwargs=dict(vector_width=vector_width),
                    block_bytes_in=2 * vector_width * x.dtype.itemsize,
                    block_bytes_out=vector_width * x.dtype.itemsize,
                    flops_per_block=vector_width)
    return _vecadd(x, y, vector_width, spec.factor, spec.mode, interpret)


# ------------------------------------------------------------------ matmul --
@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "pump_factor",
                                             "pump_mode", "interpret"))
def _matmul(a, b, bm, bn, bk, pump_factor, pump_mode, interpret):
    spec = PumpSpec(factor=pump_factor, mode=pump_mode)
    kw = bk * (pump_factor if pump_mode == "T" else 1)
    ap, m = _pad_to(a, 0, bm)
    ap, k = _pad_to(ap, 1, kw)
    bp, _ = _pad_to(b, 0, kw)
    bp, n = _pad_to(bp, 1, bn)
    out = _mm.matmul_pallas(ap, bp, bm=bm, bn=bn, bk=bk, pump=spec,
                            interpret=interpret)
    return out[:m, :n]


def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
           pump: PumpSpec | int | str = 1, interpret: bool = True):
    """``pump``: factor, PumpSpec, ``'auto'`` (capacity model) or
    ``'measure'`` (timed on the compiled IR graph, cached)."""
    spec = _as_spec(
        pump, kernel="matmul",
        builder_args=(a.shape[0], b.shape[1], a.shape[1]),
        builder_kwargs=dict(bm=bm, bn=bn, bk=bk),
        block_bytes_in=(bm * bk + bk * bn) * a.dtype.itemsize,
        block_bytes_out=0,  # accumulated in VMEM, written once per tile
        flops_per_block=2.0 * bm * bn * bk)
    return _matmul(a, b, bm, bn, bk, spec.factor, spec.mode, interpret)


# ----------------------------------------------------------------- stencil --
@functools.partial(jax.jit, static_argnames=("stages", "kind", "coef",
                                             "pump_factor", "interpret"))
def _stencil(x, stages, kind, coef, pump_factor, interpret):
    return _st.stencil_chain_pallas(x, stages, kind=kind, coef=coef,
                                    pump=pump_factor, interpret=interpret)


def stencil_chain(x, stages: int, *, kind: str = "jacobi", coef: float = 0.1,
                  pump: PumpSpec | int = 1, interpret: bool = True):
    f = pump.factor if isinstance(pump, PumpSpec) else pump
    if (x.shape[0] - 2) % f:
        raise ValueError("interior plane count must divide the pump factor")
    return _stencil(x, stages, kind, coef, f, interpret)


# ---------------------------------------------------------- floyd-warshall --
@functools.partial(jax.jit, static_argnames=("pump_factor", "interpret"))
def _fw_run(d, pump_factor, interpret):
    return _fw.floyd_warshall_pallas(d, pump=pump_factor, interpret=interpret)


def floyd_warshall(dist, *, pump: PumpSpec | int = 1, interpret: bool = True):
    f = pump.factor if isinstance(pump, PumpSpec) else pump
    n = dist.shape[0]
    if n % f:
        raise ValueError(f"n={n} must divide pump factor {f}")
    return _fw_run(dist, f, interpret)


# --------------------------------------------------------- flash attention --
@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "pump_factor", "interpret"))
def _flash(q, k, v, causal, bq, bkv, pump_factor, interpret):
    spec = PumpSpec(factor=pump_factor)
    b, hq, s, d = q.shape
    kwide = min(bkv, k.shape[2]) * pump_factor
    qp, s0 = _pad_to(q, 2, min(bq, s))
    kp, _ = _pad_to(k, 2, kwide)
    vp, _ = _pad_to(v, 2, kwide)
    # padded KV positions must not contribute: causal masking handles the
    # tail for causal=True; for non-causal we bias keys via -inf on k? We
    # instead require T % bkv == 0 after padding and mask via position ids:
    # simplest robust approach: pad K with -inf-scoring keys by zeroing V and
    # giving K a huge negative last-dim component is fragile; we pad S only.
    out = _fa.flash_attention_pallas(qp, kp, vp, causal=causal,
                                     bq=min(bq, s), bkv=min(bkv, k.shape[2]),
                                     pump=spec, interpret=interpret)
    return out[:, :, :s0, :]


def flash_attention(q, k, v, *, causal: bool = False, bq: int = 128,
                    bkv: int = 128, pump: PumpSpec | int | str = 1,
                    interpret: bool = True):
    d = q.shape[-1]
    spec = _as_spec(pump,
                    block_bytes_in=2 * bkv * d * q.dtype.itemsize,
                    block_bytes_out=0,
                    flops_per_block=4.0 * bq * bkv * d)
    if k.shape[2] % (min(bkv, k.shape[2]) * spec.factor):
        raise ValueError("KV length must divide bkv * pump factor")
    return _flash(q, k, v, causal, bq, bkv, spec.factor, interpret)


# ---------------------------------------------------------------- SSD scan --
@functools.partial(jax.jit, static_argnames=("chunk", "pump_factor",
                                             "interpret"))
def _ssd_jit(x, dt, A, B, C, chunk, pump_factor, interpret):
    return _ssd.ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                                pump=pump_factor, interpret=interpret)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 16,
             pump: PumpSpec | int | str = 1, interpret: bool = True):
    b, l, h, p = x.shape
    n = B.shape[-1]
    spec = _as_spec(pump,
                    block_bytes_in=(chunk * (p + 1 + 2 * n)) * 4,
                    block_bytes_out=chunk * p * 4,
                    flops_per_block=2.0 * chunk * chunk * (n + p))
    if l % (chunk * spec.factor):
        raise ValueError(f"L={l} must divide chunk*M={chunk * spec.factor}")
    return _ssd_jit(x, dt, A, B, C, chunk, spec.factor, interpret)


# ------------------------------------------------------------ grouped gemm --
@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "pump_factor",
                                             "pump_mode", "interpret"))
def _grouped(x, w, bc, bf, bd, pump_factor, pump_mode, interpret):
    spec = PumpSpec(factor=pump_factor, mode=pump_mode)
    dw = bd * (pump_factor if pump_mode == "T" else 1)
    xp, c0 = _pad_to(x, 1, bc)
    xp, d0 = _pad_to(xp, 2, dw)
    wp, _ = _pad_to(w, 1, dw)
    wp, f0 = _pad_to(wp, 2, bf)
    out = _gg.grouped_gemm_pallas(xp, wp, bc=bc, bf=bf, bd=bd, pump=spec,
                                  interpret=interpret)
    return out[:, :c0, :f0]


def grouped_gemm(x, w, *, bc: int = 128, bf: int = 128, bd: int = 128,
                 pump: PumpSpec | int | str = 1, interpret: bool = True):
    """Per-expert batched GEMM (MoE hot-spot).  x (E,C,D) @ w (E,D,F)."""
    spec = _as_spec(pump,
                    block_bytes_in=(bc * bd + bd * bf) * x.dtype.itemsize,
                    block_bytes_out=0,
                    flops_per_block=2.0 * bc * bf * bd)
    return _grouped(x, w, bc, bf, bd, spec.factor, spec.mode, interpret)
