"""Grouped (per-expert) GEMM with a pumped contraction stream.

The MoE hot-spot: ``out[e] = x[e] @ w[e]`` for E experts — the batched
einsum at the heart of ``moe_apply``.  On TPU each expert's GEMM is an
independent MXU job; the expert axis is the outer grid dim (and the EP
sharding axis at chip scale).

Temporal vectorization applies to the *contraction stream* exactly as in
``matmul.py``: one grid step DMAs a ``bd·M``-wide panel of x[e] and w[e]
(the wide transaction) and the in-kernel issuer performs M accumulation
passes.  Mode R narrows the per-issue output tile instead.

This kernel also demonstrates the paper's point about *composability*: the
same transformation applies unchanged whether the compute is one GEMM or E
of them — only the data-movement description (the IR graph) differs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ir import PumpSpec


def _gg_kernel(x_ref, w_ref, o_ref, *, pump: int, bd: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def issue(m, acc):
        xs = x_ref[0, :, pl.dslice(m * bd, bd)]
        ws = w_ref[0, pl.dslice(m * bd, bd), :]
        return acc + jnp.dot(xs, ws, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, pump, issue,
                            jnp.zeros(o_ref.shape[1:], jnp.float32),
                            unroll=False)
    o_ref[0] += acc.astype(o_ref.dtype)


def grouped_gemm_pallas(x: jax.Array, w: jax.Array, *,
                        bc: int = 128, bf: int = 128, bd: int = 128,
                        pump: PumpSpec | int = 1,
                        out_dtype=None,
                        interpret: bool = True) -> jax.Array:
    """x: (E, C, D), w: (E, D, F) -> (E, C, F)."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    e, c, d = x.shape
    e2, d2, f = w.shape
    assert (e, d) == (e2, d2), (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    mfac = pump.factor
    dwide = bd * mfac if pump.mode == "T" else bd
    if pump.mode == "R":
        if bf % mfac:
            raise ValueError(f"bf={bf} not divisible by M={mfac} in mode R")
    for name, dim, blk in (("C", c, bc), ("F", f, bf), ("D", d, dwide)):
        if dim % blk:
            raise ValueError(f"{name}={dim} %% block {blk} != 0")
    grid = (e, c // bc, f // bf, d // dwide)
    inner = mfac if pump.mode == "T" else 1

    kernel = functools.partial(_gg_kernel, pump=inner, bd=bd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, dwide), lambda e_, i, j, k: (e_, i, k)),
            pl.BlockSpec((1, dwide, bf), lambda e_, i, j, k: (e_, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, i, j, k: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), out_dtype),
        interpret=interpret,
    )(x, w)


def transactions(e: int, c: int, d: int, f: int, bc: int = 128,
                 bf: int = 128, bd: int = 128,
                 pump: PumpSpec | int = 1) -> int:
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    dw = bd * pump.factor if pump.mode == "T" else bd
    return e * (c // bc) * (f // bf) * (d // dw)
