"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes and dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def vecadd(x, y):
    return x + y


def matmul(a, b, out_dtype=jnp.float32):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


# ---------------------------------------------------------------- stencils --
def jacobi3d(x):
    """7-point Jacobi on the interior; boundary copied (single iteration)."""
    y = x
    interior = (
        x[:-2, 1:-1, 1:-1] + x[2:, 1:-1, 1:-1]
        + x[1:-1, :-2, 1:-1] + x[1:-1, 2:, 1:-1]
        + x[1:-1, 1:-1, :-2] + x[1:-1, 1:-1, 2:]
        + x[1:-1, 1:-1, 1:-1]
    ) * (1.0 / 7.0)
    return y.at[1:-1, 1:-1, 1:-1].set(interior)


def diffusion3d(x, coef=0.1):
    """Explicit 3-D diffusion step, boundary copied."""
    lap = (
        x[:-2, 1:-1, 1:-1] + x[2:, 1:-1, 1:-1]
        + x[1:-1, :-2, 1:-1] + x[1:-1, 2:, 1:-1]
        + x[1:-1, 1:-1, :-2] + x[1:-1, 1:-1, 2:]
        - 6.0 * x[1:-1, 1:-1, 1:-1]
    )
    return x.at[1:-1, 1:-1, 1:-1].add(coef * lap)


def stencil_chain(x, stages: int, kind: str = "jacobi"):
    fn = jacobi3d if kind == "jacobi" else diffusion3d
    for _ in range(stages):
        x = fn(x)
    return x


# ---------------------------------------------------------- floyd-warshall --
def floyd_warshall(dist):
    """All-pairs shortest paths; the canonical dependency-carrying loop."""
    n = dist.shape[0]

    def body(k, d):
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # (1, n)
        col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (n, 1)
        return jnp.minimum(d, col + row)

    return jax.lax.fori_loop(0, n, body, dist)


# --------------------------------------------------------- flash attention --
def attention(q, k, v, *, causal: bool = False, scale: float | None = None,
              bias=None):
    """O(S^2) reference attention. q,k,v: (B, H, S, D); kv may have fewer
    heads (GQA) — callers broadcast before calling."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        logits = logits + bias
    if causal:
        s, t = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((s, t), dtype=bool), k=t - s)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)


# ------------------------------------------------------------------ SSD ----
def ssd_scan(x, dt, A, B, C, *, chunk: int = 0):
    """Mamba-2 SSD (state-space dual) reference, sequential over time.

    x : (b, l, h, p)   inputs per head
    dt: (b, l, h)      positive step sizes
    A : (h,)           negative state decay
    B : (b, l, g, n)   input projection (g groups broadcast over heads)
    C : (b, l, g, n)   output projection
    returns y: (b, l, h, p)

    Recurrence per head: S_t = exp(A·dt_t)·S_{t-1} + dt_t·B_t x_tᵀ ;
    y_t = C_t · S_t.
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    heads_per_group = h // g
    Bh = jnp.repeat(B, heads_per_group, axis=2)  # (b, l, h, n)
    Ch = jnp.repeat(C, heads_per_group, axis=2)

    decay = jnp.exp(A[None, None, :] * dt)      # (b, l, h)

    def step(state, t):
        # state: (b, h, n, p)
        d = decay[:, t][..., None, None]
        upd = jnp.einsum("bhn,bhp->bhnp", Bh[:, t] * dt[:, t][..., None], x[:, t])
        state = state * d + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state)
        return state, y

    init = jnp.zeros((b, h, n, p), dtype=jnp.float32)
    _, ys = jax.lax.scan(step, init,
                         jnp.arange(l))
    return jnp.transpose(ys, (1, 0, 2, 3)).astype(x.dtype)  # (b, l, h, p)


def grouped_gemm(x, w, out_dtype=None):
    """x: (E, C, D), w: (E, D, F) -> (E, C, F) in fp32 accumulation."""
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    return out.astype(out_dtype or x.dtype)
