"""Communication-avoiding matrix multiplication (paper §4.2, Table 3).

The paper double-pumps a 1-D systolic array of vectorized PEs built from the
I/O-optimal CA-MMM of de Fine Licht et al. [10].  The TPU re-think
(DESIGN.md §2): the MXU *is* the systolic array, so the spatial PE chain maps
onto the (bm × bn) output tile held in VMEM, and the paper's "feeding the
chain" maps onto the K-stream of (bm × bk)/(bk × bn) operand panels DMA'd
from HBM.

Temporal vectorization here = *pumping the K-stream*:

  Mode T: one grid step DMAs a K-panel widened ×M (``bk·M``) and issues M
          MXU passes over its sub-panels (in-kernel fori_loop = issuer);
          grid-step count — the long-path transaction count — drops ×M.
  Mode R: transactions keep their width, but the *active compute tile* is
          narrowed ×M along bn and issued M times per transaction (fori over
          column slices).  The per-issue MXU footprint — the DSP replication
          analogue — drops ×M at an unchanged transaction schedule.

The output tile is accumulated in-place across the sequential K grid
dimension (zero-initialized at k==0), which is the I/O-optimal schedule: A
and B panels stream exactly once per output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ir import PumpSpec


def _mm_kernel_t(a_ref, b_ref, o_ref, *, pump: int, bk: int):
    """Mode T body: M sub-panels of the wide K transaction, full tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def issue(m, acc):
        a = a_ref[:, pl.dslice(m * bk, bk)]
        b = b_ref[pl.dslice(m * bk, bk), :]
        return acc + jnp.dot(a, b, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, pump, issue,
                            jnp.zeros(o_ref.shape, jnp.float32), unroll=False)
    o_ref[...] += acc.astype(o_ref.dtype)


def _mm_kernel_r(a_ref, b_ref, o_ref, *, pump: int, bn_narrow: int):
    """Mode R body: narrow compute tile issued M times per transaction."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def issue(m, _):
        sl = pl.dslice(m * bn_narrow, bn_narrow)
        acc = jnp.dot(a_ref[...], b_ref[:, sl],
                      preferred_element_type=jnp.float32)
        o_ref[:, sl] += acc.astype(o_ref.dtype)
        return _

    jax.lax.fori_loop(0, pump, issue, None, unroll=False)


def matmul_pallas(a: jax.Array, b: jax.Array, *,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  pump: PumpSpec | int = 1,
                  out_dtype=None,
                  interpret: bool = True) -> jax.Array:
    """``a @ b`` with a pump-M K-stream.  a: (M, K), b: (K, N)."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    m_sz, k_sz = a.shape
    k2, n_sz = b.shape
    assert k_sz == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    mfac = pump.factor

    kwide = bk * mfac if pump.mode == "T" else bk
    if pump.mode == "R" and bn % mfac:
        raise ValueError(f"bn={bn} not divisible by M={mfac} for mode R")
    for name, dim, blk in (("M", m_sz, bm), ("N", n_sz, bn), ("K", k_sz, kwide)):
        if dim % blk:
            raise ValueError(f"{name}={dim} not divisible by block {blk}")
    grid = (m_sz // bm, n_sz // bn, k_sz // kwide)

    if pump.mode == "T":
        kernel = functools.partial(_mm_kernel_t, pump=mfac, bk=bk)
    else:
        kernel = functools.partial(_mm_kernel_r, pump=mfac, bn_narrow=bn // mfac)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kwide), lambda i, j, k: (i, k)),
            pl.BlockSpec((kwide, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_sz, n_sz), out_dtype),
        interpret=interpret,
    )(a, b)


def transactions(m: int, n: int, k: int, bm: int = 128, bn: int = 128,
                 bk: int = 128, pump: PumpSpec | int = 1) -> int:
    """Grid steps = wide DMA transactions on the long path."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    kw = bk * pump.factor if pump.mode == "T" else bk
    return (m // bm) * (n // bn) * (k // kw)


def compute_tile_bytes(bm: int = 128, bn: int = 128,
                       pump: PumpSpec | int = 1) -> int:
    """Active MXU tile footprint per issue — the DSP replication analogue."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    bn_eff = bn // pump.factor if pump.mode == "R" else bn
    return bm * bn_eff * 4
