"""Vector addition Pallas kernel (paper §4.1, Table 2).

The paper's simplest multi-pumping demonstrator: ``z = x + y`` with spatial
vectorization V and optional temporal pump M.

TPU mapping (DESIGN.md §2):
  - one grid step       = one wide transaction on the long path (HBM→VMEM DMA)
  - BlockSpec width     = V·M elements per transaction (Mode T widens by M)
  - in-kernel fori_loop = the *issuer*: M narrow sub-tiles of width V are fed
                          to the adder sequentially (the fast domain)
  - the adder body      = V spatial lanes, unchanged by the pump
  - Pallas pipelining   = the *synchronizer* (next DMA overlaps current body)

Mode R narrows the sub-tile to V/M instead, keeping the transaction width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ir import PumpSpec


def _vecadd_kernel(x_ref, y_ref, z_ref, *, lanes: int, pump: int):
    """Body: ``pump`` temporal iterations over ``lanes``-wide sub-tiles."""

    def issue(m, _):
        sl = pl.dslice(m * lanes, lanes)
        z_ref[sl] = x_ref[sl] + y_ref[sl]
        return _

    jax.lax.fori_loop(0, pump, issue, None, unroll=False)


def vecadd_pallas(x: jax.Array, y: jax.Array, *,
                  vector_width: int = 8,
                  pump: PumpSpec | int = 1,
                  interpret: bool = True) -> jax.Array:
    """``z = x + y`` with spatial width V and temporal pump M.

    Mode T: transaction = V·M elements, compute tile V wide, M iterations.
    Mode R: transaction = V elements, compute tile V/M wide, M iterations.
    """
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    (n,) = x.shape
    v, m = vector_width, pump.factor
    if pump.mode == "T":
        block = v * m
        lanes = v
    else:
        block = v
        if v % m:
            raise ValueError(f"V={v} not divisible by M={m} in mode R")
        lanes = v // m
    if n % block:
        raise ValueError(f"n={n} not divisible by transaction width {block}")
    grid = (n // block,)

    kernel = functools.partial(_vecadd_kernel, lanes=lanes, pump=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, y)


def grid_steps(n: int, vector_width: int, pump: PumpSpec | int = 1) -> int:
    """Long-path transactions issued — the DMA-descriptor cost metric."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    block = vector_width * (pump.factor if pump.mode == "T" else 1)
    return n // block
