"""Jacobi-3D / Diffusion-3D stencil chains (paper §4.3, Tables 4–5).

StencilFlow maps a DAG of stencil stages onto FPGA pipelines; each stage is
an independent kernel connected by streams, and the paper multi-pumps each
stage's compute domain.

TPU mapping: a stage processes the volume plane-by-plane along the leading
axis.  One grid step consumes one *slab* of ``M`` planes — the wide
transaction — and the in-kernel fori_loop (issuer) runs the 7-point update
plane-by-plane inside it.  The plane update itself is spatially vectorized
over the (d1, d2) lanes (VPU), and the pump leaves it untouched, so the halo
dependency between consecutive planes survives — the property that makes
temporal vectorization a superclass of spatial vectorization.

Halo handling: Pallas index maps address whole blocks, so overlapping slabs
are fed as three plane-aligned views (x[p-1], x[p], x[p+1]) prepared by the
ops wrapper — the same three-row line buffer StencilFlow keeps in BRAM, here
materialized as three streamed VMEM blocks.

Chains of S stages are S chained pallas_calls communicating through HBM
(the analogue of the inter-kernel streams + synchronization steps in §4.3;
the paper likewise isolates each stage in its own clock domain).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ir import PumpSpec


def _stencil_kernel(prev_ref, cur_ref, nxt_ref, o_ref, *, pump: int,
                    kind: str, coef: float):
    """Slab body: ``pump`` plane updates per wide transaction."""

    def issue(m, _):
        prev = prev_ref[m, :, :]
        cur = cur_ref[m, :, :]
        nxt = nxt_ref[m, :, :]
        c = cur[1:-1, 1:-1]
        neigh = (prev[1:-1, 1:-1] + nxt[1:-1, 1:-1]
                 + cur[:-2, 1:-1] + cur[2:, 1:-1]
                 + cur[1:-1, :-2] + cur[1:-1, 2:])
        if kind == "jacobi":
            out = (neigh + c) * (1.0 / 7.0)
        else:  # diffusion
            out = c + coef * (neigh - 6.0 * c)
        o_ref[m, :, :] = cur.at[1:-1, 1:-1].set(out)
        return _

    jax.lax.fori_loop(0, pump, issue, None, unroll=False)


def stencil_step_pallas(x: jax.Array, *, kind: str = "jacobi",
                        coef: float = 0.1,
                        pump: PumpSpec | int = 1,
                        interpret: bool = True) -> jax.Array:
    """One stencil stage over volume x: (d0, d1, d2).

    Interior (d0-2) planes are processed in slabs of M planes; boundary
    planes are copied.  d0-2 must be divisible by M.
    """
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    m = pump.factor
    d0, d1, d2 = x.shape
    interior = d0 - 2
    if interior % m:
        raise ValueError(f"interior planes {interior} not divisible by M={m}")
    grid = (interior // m,)

    kernel = functools.partial(_stencil_kernel, pump=m, kind=kind, coef=coef)
    spec = pl.BlockSpec((m, d1, d2), lambda i: (i, 0, 0))
    interior_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((interior, d1, d2), x.dtype),
        interpret=interpret,
    )(x[:-2], x[1:-1], x[2:])
    return jnp.concatenate([x[:1], interior_out, x[-1:]], axis=0)


def stencil_chain_pallas(x: jax.Array, stages: int, *, kind: str = "jacobi",
                         coef: float = 0.1, pump: PumpSpec | int = 1,
                         interpret: bool = True) -> jax.Array:
    for _ in range(stages):
        x = stencil_step_pallas(x, kind=kind, coef=coef, pump=pump,
                                interpret=interpret)
    return x


def transactions(d0: int, pump: PumpSpec | int = 1) -> int:
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    return (d0 - 2) // pump.factor


def slab_bytes(d1: int, d2: int, pump: PumpSpec | int = 1,
               itemsize: int = 4) -> int:
    """VMEM slab footprint per grid step (the BRAM line-buffer analogue)."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    return 3 * pump.factor * d1 * d2 * itemsize
