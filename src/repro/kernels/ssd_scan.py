"""Mamba-2 SSD (state-space duality) chunked scan with a pumped chunk stream.

The architecture-pool flagship for temporal vectorization: the inter-chunk
state recurrence

    S_chunk+1 = decay(chunk) * S_chunk + contribution(chunk)

is a true sequential dependency, so chunks cannot be spatially vectorized —
precisely the situation (paper §4.4) where multi-pumping still applies.  One
grid step DMAs an M-chunk-wide panel of (x, dt, B, C) from HBM; the in-kernel
fori_loop (issuer) runs the M dependent chunk updates back-to-back while the
state lives in VMEM scratch (the fast domain).  Long-path transactions drop
×M; the intra-chunk compute tile — two (c×c)(c×p) MXU matmuls — is untouched.

Math (per batch b, head h; chunk arrays xc (c,p), dtc (c,), Bc/Cc (c,n)):
    a_t   = exp(A_h · dt_t)                        per-step decay
    logP_t = Σ_{s<=t} log a_s                      running decay (cumsum)
    y_t   = C_t·S_in · P_t  +  Σ_{s<=t} (P_t/P_s)·dt_s·(C_t·B_s)·x_s
    S_out = S_in · P_c  +  Σ_t (P_c/P_t)·dt_t·B_t xᵀ_t
the intra-chunk sum is the "dual" quadratic form G @ x with
    G[t,s] = (C_t·B_s) · exp(logP_t − logP_s) · dt_s  for s ≤ t.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ir import PumpSpec


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                pump: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    A = a_ref[0]  # scalar decay rate for this head

    def issue(mstep, _):
        sl = pl.dslice(mstep * chunk, chunk)
        xc = x_ref[0, sl, 0, :].astype(jnp.float32)    # (c, p)
        dtc = dt_ref[0, sl, 0].astype(jnp.float32)     # (c,)
        Bc = b_ref[0, sl, 0, :].astype(jnp.float32)    # (c, n)
        Cc = c_ref[0, sl, 0, :].astype(jnp.float32)    # (c, n)

        logp = jnp.cumsum(A * dtc)                     # (c,) decreasing
        # inter-chunk contribution: y_t += (C_t · S_in) * P_t
        s_in = state_ref[...]                          # (n, p)
        y_carry = jnp.exp(logp)[:, None] * jnp.dot(
            Cc, s_in, preferred_element_type=jnp.float32)        # (c, p)
        # intra-chunk dual form
        cb = jnp.dot(Cc, Bc.T, preferred_element_type=jnp.float32)  # (c, c)
        ratio = logp[:, None] - logp[None, :]          # logP_t - logP_s
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        mask = t_idx >= s_idx
        G = jnp.where(mask, cb * jnp.exp(jnp.where(mask, ratio, 0.0))
                      * dtc[None, :], 0.0)
        y_intra = jnp.dot(G, xc, preferred_element_type=jnp.float32)
        y_ref[0, sl, 0, :] = (y_carry + y_intra).astype(y_ref.dtype)
        # state update
        p_total = logp[-1]
        w = jnp.exp(p_total - logp) * dtc              # (c,)
        state_ref[...] = s_in * jnp.exp(p_total) + jnp.dot(
            (Bc * w[:, None]).T, xc, preferred_element_type=jnp.float32)
        return _

    jax.lax.fori_loop(0, pump, issue, None, unroll=False)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array, *,
                    chunk: int = 16,
                    pump: PumpSpec | int = 1,
                    interpret: bool = True) -> jax.Array:
    """SSD scan. x: (b,l,h,p), dt: (b,l,h), A: (h,), B/C: (b,l,g,n)."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    mfac = pump.factor
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if h % g:
        raise ValueError(f"h={h} not divisible by groups g={g}")
    hpg = h // g
    cwide = chunk * mfac
    if l % cwide:
        raise ValueError(f"L={l} %% chunk*M={cwide} != 0; pad in ops wrapper")
    grid = (b, h, l // cwide)

    kernel = functools.partial(_ssd_kernel, pump=mfac, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cwide, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, cwide, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, cwide, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
            pl.BlockSpec((1, cwide, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
        ],
        out_specs=pl.BlockSpec((1, cwide, 1, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)


def transactions(b: int, l: int, h: int, chunk: int = 16,
                 pump: PumpSpec | int = 1) -> int:
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    return b * h * (l // (chunk * pump.factor))
