"""Pallas TPU kernels with temporal-vectorization (multi-pumping) support.

Every kernel takes ``pump`` — a :class:`repro.core.ir.PumpSpec`, an int
factor, or ``'auto'`` (capacity-model planning) — and is validated against
the pure-jnp oracles in :mod:`repro.kernels.ref` (see tests/test_kernels.py).

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
executed in interpret mode on this CPU container; pass ``interpret=False``
on real hardware.

Use ``repro.kernels.ops.<kernel>`` for the jit'd wrappers; the submodules
(vecadd, matmul, stencil, floyd_warshall, flash_attention, ssd_scan) hold
the raw pallas_call builders and structural metrics.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
