"""Floyd–Warshall all-pairs shortest paths (paper §4.4, Table 6).

The paper's showcase for the *superclass* claim: the k-loop carries a true
dependency (iteration k reads the distance matrix produced by iteration
k−1), so traditional (spatial) vectorization of k is impossible — yet
temporal vectorization applies, because the compute is left sequential and
only the feeding is widened.

TPU mapping: the distance matrix lives in VMEM (500² f32 = 1 MB); the grid
walks k in *slabs of M iterations per grid step*.  Baseline (O): one k per
grid step — n long-path transactions of one pivot row/column each.  Pumped
(DP): one grid step receives an M-wide transaction (M pivot rows) and the
in-kernel fori_loop — the issuer — performs the M dependent relaxations
back-to-back in the fast domain.  The relaxation itself is spatially
vectorized over j (VPU lanes); the k dependency is untouched.

The matrix is carried across grid steps via input/output aliasing (the grid
is sequential on TPU), which is exactly the paper's "retain internal
dependencies" condition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ir import PumpSpec


def _fw_kernel(d_ref, o_ref, *, pump: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = d_ref[...]

    def relax(m, _):
        k = i * pump + m
        d = o_ref[...]
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # (1, n) pivot row
        col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (n, 1) pivot col
        o_ref[...] = jnp.minimum(d, col + row)
        return _

    jax.lax.fori_loop(0, pump, relax, None, unroll=False)


def floyd_warshall_pallas(dist: jax.Array, *,
                          pump: PumpSpec | int = 1,
                          interpret: bool = True) -> jax.Array:
    """All-pairs shortest paths over an (n, n) distance matrix."""
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    m = pump.factor
    n = dist.shape[0]
    if n % m:
        raise ValueError(f"n={n} not divisible by pump factor {m}")
    grid = (n // m,)

    kernel = functools.partial(_fw_kernel, pump=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), dist.dtype),
        interpret=interpret,
    )(dist)


def transactions(n: int, pump: PumpSpec | int = 1) -> int:
    if isinstance(pump, int):
        pump = PumpSpec(factor=pump)
    return n // pump.factor
