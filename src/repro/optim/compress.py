"""Gradient compression with error feedback (beyond-paper extension).

int8 block-quantized gradients for the cross-pod (DCN) all-reduce: the pod
axis is the slowest link, and fp32→int8 quarters its payload.  Error
feedback keeps the quantization unbiased over time (the residual is added
back into the next step's gradient before quantizing).

This composes with trainer multi-pumping: the pumped (accumulated) gradient
is quantized once per M microbatches, so the compression cost itself is
amortized M×.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _leaf_quantize(g, err):
    g = g.astype(jnp.float32) + (err.astype(jnp.float32)
                                 if err is not None else 0.0)
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:g.size].reshape(g.shape)
    return q, scale, (g - deq)


def quantize(grads, err_state=None):
    """grads pytree -> (q pytree of (int8, scale), new error-feedback state)."""
    if err_state is None:
        err_state = jax.tree.map(lambda _: None, grads,
                                 is_leaf=lambda x: x is None)
    out = jax.tree.map(_leaf_quantize, grads, err_state,
                       is_leaf=lambda x: x is None)
    q = jax.tree.map(lambda t: (t[0], t[1]), out,
                     is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    err = jax.tree.map(lambda t: t[2], out,
                       is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    return q, err


def dequantize(q, like):
    def deq(pair, g):
        qi, scale = pair
        flat = (qi.astype(jnp.float32) * scale).reshape(-1)[:g.size]
        return flat.reshape(g.shape)
    return jax.tree.map(deq, q, like,
                        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(fp32)."""
    total = sum(l.size for l in jax.tree.leaves(grads))
    q_bytes = total * 1 + (total // BLOCK + 1) * 4
    return q_bytes / (total * 4)
