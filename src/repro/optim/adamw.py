"""Sharded AdamW with mixed-precision state and gradient clipping.

State layout (per parameter leaf):
    master : fp32 master copy (params themselves may be bf16)
    m, v   : first/second moments, dtype selectable (bf16 halves the
             optimizer-state HBM footprint — required to fit deepseek-v3
             on 512×16 GB chips, see EXPERIMENTS.md §Dry-run)

The state inherits each parameter's PartitionSpec (ZeRO-style: whatever
sharding the param has, its optimizer state has too), so no separate spec
table is needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # "bfloat16" halves state memory
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any
    m: Any
    v: Any


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(cfg: AdamWConfig, params) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params)
    return AdamWState(jnp.zeros((), jnp.int32), master, m, v)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else jnp.ones(())
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, master, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return new_master, m32.astype(mdt), v32.astype(mdt), \
            new_master.astype(p.dtype)

    out = jax.tree.map(upd, grads, state.master, state.m, state.v, params)
    new_master = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda t: t[3], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_master, new_m, new_v), metrics
