"""Optimizers: sharded AdamW + gradient compression."""
from . import adamw, compress
from .adamw import AdamWConfig, AdamWState, init, update, schedule, global_norm

__all__ = ["adamw", "compress", "AdamWConfig", "AdamWState", "init",
           "update", "schedule", "global_norm"]
