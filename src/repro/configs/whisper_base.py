"""whisper-base [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

6L encoder + 6L decoder, d_model=512 8H, d_ff=2048, vocab=51865.  The conv
frontend is a stub: input_specs provides precomputed frame embeddings
(B, 1500, 512).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    n_encoder_layers=6,
    encoder_seq=1500,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    n_encoder_layers=2,
    encoder_seq=32,
    tie_embeddings=True,
    dtype="float32",
)
