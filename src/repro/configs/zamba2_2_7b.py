"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242].

54L d_model=2560, ssm_state=64; a single shared (attn + FFN) block with
32H (kv=32) and d_ff=10240 is applied between groups of 6 Mamba2 layers
(9 applications, one weight copy) — the Zamba2 shared-block scheme.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    hybrid_attn_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, n_groups=1, chunk=64,
                  conv_width=4, expand=2),
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    hybrid_attn_every=2,
    ssm=SSMConfig(state_dim=16, head_dim=32, n_groups=1, chunk=8,
                  conv_width=4, expand=2),
    dtype="float32",
)
