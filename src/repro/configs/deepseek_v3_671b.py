"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437].

61L d_model=7168 128H, MoE 256e top-8 with d_expert=2048, vocab=129280,
MLA kv_lora=512 q_lora=1536 rope=64 nope=128 v=128; first 3 layers dense.
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,             # dense-layer FFN width
    vocab_size=129280,
    moe=MoEConfig(n_experts=256, n_shared_experts=1, top_k=8,
                  d_expert=2048, capacity_factor=1.25,
                  inference_capacity_factor=2.0, n_dense_layers=3),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    mtp_depth=1,
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    moe=MoEConfig(n_experts=8, n_shared_experts=1, top_k=2, d_expert=32,
                  n_dense_layers=1, capacity_factor=8.0),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=24, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
    mtp_depth=1,
    dtype="float32",
)
