"""Architecture configs: one module per assigned arch + shape definitions."""
from .base import (ARCH_IDS, FULL_ATTENTION_ARCHS, SHAPES, MLAConfig,
                   ModelConfig, MoEConfig, SSMConfig, ShapeConfig, cells,
                   load_arch)

__all__ = ["ARCH_IDS", "FULL_ATTENTION_ARCHS", "SHAPES", "MLAConfig",
           "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "cells",
           "load_arch"]
