"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attention-free, vocab=50280, ssm_state=128.
Mamba-2 1.3b: expand=2 → d_inner=4096, head_dim=64 → 64 SSD heads.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,            # d_inner / ssm head_dim
    n_kv_heads=64,
    d_ff=0,                # attn-free, no FFN (mixer only)
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=1, chunk=64,
                  conv_width=4, expand=2),
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=16, head_dim=32, n_groups=1, chunk=8,
                  conv_width=4, expand=2),
    dtype="float32",
)
