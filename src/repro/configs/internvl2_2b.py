"""internvl2-2b [vlm] — InternViT (stub) + InternLM2 backbone
[arXiv:2404.16821].

LM: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The ViT
frontend is a stub: input_specs provides precomputed patch embeddings
(B, 256, 1024); the projector MLP is part of the model.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    n_vision_tokens=256,
    d_vision=1024,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_vision_tokens=8,
    d_vision=32,
    dtype="float32",
)
