"""Config system: architecture + shape + run configs.

Every assigned architecture is a :class:`ModelConfig` instance in its own
module (``repro/configs/<id>.py``) exposing ``CONFIG`` (full size) and
``SMOKE`` (reduced same-family config for CPU tests).  Shapes are the four
assigned (seq_len × global_batch) cells; ``RunConfig`` carries everything the
launcher needs.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0                 # routed experts
    n_shared_experts: int = 0
    top_k: int = 2
    d_expert: int = 0                  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    # serve-path capacity: 0 = strictly dropless (cap = t·k, exact but the
    # buffer is worst-case sized); >0 = cap = ceil(icf·t·k/E) with gates
    # renormalized over kept assignments (§Perf B3)
    inference_capacity_factor: float = 0.0
    router_aux_weight: float = 0.001   # load-balance loss weight
    n_dense_layers: int = 0            # leading layers that use dense FFN
    # dropless serving path: route the expert GEMMs through the ragged
    # grouped-gemm kernel (row groups pad to the row tile, empty experts
    # skipped) instead of the dense (E, cap, d) einsum.  Needs concrete
    # routing counts, so it engages only outside jit traces (eager serving
    # layers / benchmarks); traced calls keep the dense path.
    ragged_dropless: bool = False


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0               # 0 = no q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128               # N
    head_dim: int = 64                 # P
    n_groups: int = 1                  # B/C groups (g)
    chunk: int = 64                    # SSD chunk length
    conv_width: int = 4
    expand: int = 2                    # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): shared attention block every k ssm layers
    hybrid_attn_every: int = 0
    # enc-dec (whisper-style)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500            # precomputed frame embeddings (stub)
    # vlm (internvl2-style)
    n_vision_tokens: int = 0           # prefix patch embeddings (stub)
    d_vision: int = 0
    # multi-token prediction (deepseek-v3)
    mtp_depth: int = 0
    # implementation switches
    attention_impl: str = "xla_chunked"  # xla_chunked | pallas
    ssm_impl: str = "xla"                # xla | pallas
    # kernel-plan policy for the pallas impl paths: 'measure' (default)
    # routes through the shape-bucketed plan registry with measured-runtime
    # pump autotuning (repro.compiler.registry); 'direct' keeps the raw
    # kernels.ops call with default pump — the differential reference.
    kernel_plan: str = "measure"
    # opt-in: route cache prefill (s > 1) through the flash kernel.  Only
    # valid when every prefill starts on a FRESH cache (pos == 0) — the
    # kernel attends over the current tokens with a position-relative
    # causal mask, which equals masked attention over the just-written
    # cache only at pos 0.  The serve Engine (whose prefill always builds
    # a fresh cache) sets this; chunked multi-segment prefill must not.
    fresh_prefill_kernel: bool = False
    # continuation prefill (s > 1 into a cache already holding pos > 0
    # tokens — chunked prefill, preemption resume): attention attends over
    # the WHOLE cache prefix, not just the current chunk, and the SSM path
    # seeds the scan from the cached recurrent state + conv tail.  At
    # pos == 0 every continuation term is exactly zero, so the flag is a
    # strict superset of the fresh-prefill math; it stays off by default
    # because the extra terms cost work the fresh path never needs.
    prefill_continuation: bool = False
    attn_block_kv: int = 1024            # KV chunk for chunked attention
    remat: bool = True
    dtype: str = "bfloat16"

    def __post_init__(self):
        # every model layer tests `kernel_plan == "measure"`: a typo'd
        # value would silently disable the whole measured-plan machinery,
        # so reject anything but the two routing policies outright
        if self.kernel_plan not in ("measure", "direct"):
            raise ValueError(
                f"kernel_plan must be 'measure' or 'direct', "
                f"got {self.kernel_plan!r}")

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.head_dim_
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "encdec", "vlm", "hybrid"):
            if self.mla:
                m = self.mla
                q = d * (self.n_heads * (m.nope_head_dim + m.rope_head_dim)) \
                    if not m.q_lora_rank else \
                    d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                        m.nope_head_dim + m.rope_head_dim)
                kv = d * (m.kv_lora_rank + m.rope_head_dim) \
                    + m.kv_lora_rank * self.n_heads * (
                        m.nope_head_dim + m.v_head_dim)
                o = self.n_heads * m.v_head_dim * d
                attn = q + kv + o
            else:
                attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            per_layer += attn
        ffn_dense = 3 * d * self.d_ff
        if self.family == "moe" and self.moe:
            mo = self.moe
            ffn_moe = 3 * d * mo.d_expert * (mo.n_experts + mo.n_shared_experts) \
                + d * mo.n_experts
            n_moe = L - mo.n_dense_layers
            total_ffn = mo.n_dense_layers * ffn_dense + n_moe * ffn_moe
            return emb + L * per_layer + total_ffn
        if self.family in ("ssm", "hybrid") and self.ssm:
            s = self.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            ssm_layer = (d * (2 * d_in + 2 * s.n_groups * s.state_dim + n_h)
                         + d_in * d + s.conv_width * (
                             d_in + 2 * s.n_groups * s.state_dim))
            if self.family == "ssm":
                return emb + L * ssm_layer
            # hybrid: shared attn+ffn block counted once
            shared = per_layer + ffn_dense
            return emb + L * ssm_layer + shared
        return emb + L * (per_layer + ffn_dense)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k)."""
        if self.family != "moe" or not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        mo = self.moe
        full = self.param_count()
        inactive = 3 * d * mo.d_expert * (mo.n_experts - mo.top_k) \
            * (L - mo.n_dense_layers)
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs for which long_500k is skipped (pure full-attention)
FULL_ATTENTION_ARCHS = {
    "deepseek-v3-671b", "deepseek-v2-lite-16b", "whisper-base",
    "granite-3-2b", "qwen2.5-14b", "qwen2-7b", "qwen3-0.6b", "internvl2-2b",
}

ARCH_IDS = [
    "mamba2-1.3b", "deepseek-v3-671b", "deepseek-v2-lite-16b", "whisper-base",
    "granite-3-2b", "qwen2.5-14b", "qwen2-7b", "qwen3-0.6b", "internvl2-2b",
    "zamba2-2.7b",
]


def _modname(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def load_arch(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.SMOKE if smoke else mod.CONFIG


def cells(include_skipped: bool = False):
    """All assigned (arch × shape) dry-run cells."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skip = shape.name == "long_500k" and arch in FULL_ATTENTION_ARCHS
            if skip and not include_skipped:
                continue
            out.append((arch, shape.name))
    return out
