"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434].

27L d_model=2048 16H, MoE 64e top-6 with d_expert=1408, vocab=102400;
v2-lite has no q compression; first layer dense (d_ff=10944).
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,
    vocab_size=102400,
    moe=MoEConfig(n_experts=64, n_shared_experts=2, top_k=6,
                  d_expert=1408, capacity_factor=1.25,
                  inference_capacity_factor=2.0, n_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    moe=MoEConfig(n_experts=8, n_shared_experts=2, top_k=2, d_expert=32,
                  n_dense_layers=1, capacity_factor=8.0),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
    dtype="float32",
)
