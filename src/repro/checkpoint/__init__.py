from . import manager
