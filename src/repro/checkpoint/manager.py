"""Atomic, sharded, content-verified checkpointing.

Layout (one directory per step):

    <root>/step_000042/
        manifest.json      # tree structure, shapes, dtypes, shard hashes
        shard_00000.npz    # flat-leaf arrays (one file per host in multi-
                           # host deployment; single file here)
    <root>/LATEST          # atomically-renamed pointer file

Fault-tolerance contract (exercised by tests/test_failover.py):
  - two-phase commit: write to ``<dir>.tmp`` then ``os.rename`` (atomic on
    POSIX), LATEST pointer updated last — a crash mid-write never corrupts
    the restore path;
  - every shard carries a sha256 in the manifest; restore verifies before
    trusting a checkpoint and falls back to the previous LATEST otherwise;
  - the data-pipeline step is saved inside the checkpoint, giving
    exactly-once batch semantics across restarts;
  - ``restore_resharded`` re-shards a checkpoint onto a different mesh
    (elastic scaling: the saved arrays are host numpy, placement is
    re-derived from the target mesh's sharding rules).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_paths(tree):
    return [jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def save(root: str, step: int, state: Dict[str, Any],
         extra: Optional[dict] = None) -> str:
    """Two-phase atomic save of an arbitrary pytree ``state``."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(state)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.kind not in "biufc":       # ml_dtypes (bf16 etc.): store raw
            a = a.view(np.uint8).reshape(a.shape + (a.dtype.itemsize,))
        arrays[f"leaf_{i:05d}"] = a
    shard_path = os.path.join(tmp, "shard_00000.npz")
    np.savez(shard_path, **arrays)
    with open(shard_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()

    manifest = {
        "step": step,
        "paths": _leaf_paths(state),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shards": {"shard_00000.npz": digest},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    latest_tmp = os.path.join(root, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.rename(latest_tmp, os.path.join(root, "LATEST"))
    return final


def _verify(ckpt_dir: str) -> bool:
    try:
        with open(os.path.join(ckpt_dir, "manifest.json")) as f:
            manifest = json.load(f)
        for shard, digest in manifest["shards"].items():
            with open(os.path.join(ckpt_dir, shard), "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != digest:
                    return False
        return True
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def available_steps(root: str) -> list:
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def verify(ckpt_dir: str) -> bool:
    """Public manifest/hash verification (see ``_verify``) — the failover
    loop uses it to pre-screen restore candidates."""
    return _verify(ckpt_dir)


def latest_valid(root: str) -> Optional[str]:
    """Newest checkpoint that passes hash verification (corrupt → skip)."""
    latest_file = os.path.join(root, "LATEST")
    candidates = []
    if os.path.exists(latest_file):
        with open(latest_file) as f:
            candidates.append(os.path.join(root, f.read().strip()))
    for s in reversed(available_steps(root)):
        p = os.path.join(root, f"step_{s:08d}")
        if p not in candidates:
            candidates.append(p)
    for c in candidates:
        if os.path.isdir(c) and _verify(c):
            return c
    return None


def restore(ckpt_dir: str, like: Dict[str, Any]) -> Tuple[Dict[str, Any], dict]:
    """Restore into the structure of ``like`` (host numpy leaves)."""
    import ml_dtypes
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(ckpt_dir, "shard_00000.npz"))
    leaves = []
    for i, dt in enumerate(manifest["dtypes"]):
        a = data[f"leaf_{i:05d}"]
        shape = tuple(manifest["shapes"][i])
        if tuple(a.shape) != shape:            # raw-byte stored ml_dtype
            want = np.dtype(getattr(ml_dtypes, dt, dt))
            a = a.view(want).reshape(shape)
        leaves.append(a)
    _, treedef = _flatten(like)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, manifest["extra"]


def restore_resharded(ckpt_dir: str, like, mesh, shardings_tree):
    """Elastic restore: place saved host arrays under a (new) mesh sharding."""
    state, extra = restore(ckpt_dir, like)
    placed = jax.tree.map(
        lambda arr, sh: jax.device_put(arr, sh), state, shardings_tree)
    return placed, extra


def prune(root: str, keep: int = 3) -> None:
    steps = available_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)
