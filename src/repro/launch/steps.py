"""Step builders: jit'd train / prefill / decode steps with shardings.

These are the functions the dry-run lowers and the trainer executes.  All
take abstract ShapeDtypeStructs just as well as real arrays (nothing inside
allocates), so ``build_*`` + ``.lower(...)`` is the whole multi-pod story.

The trainer's *temporal pump* (paper Mode T at pod scale) lives here:
``train_step`` with ``pump_factor=M`` consumes a batch of M microbatches,
runs M sequential grad computations (fast domain — the issuer is a
lax.scan), and applies ONE optimizer update + gradient synchronization per
wide transaction (the packed gradient).  XLA/GSPMD materializes the gradient
all-reduce at the point of use — once per M microbatches instead of per
microbatch — which is exactly the collective-term reduction measured in
EXPERIMENTS.md §Perf.

:class:`StepTimer` is the timing discipline for every step consumer (the
serve engine, launchers, benchmarks): compile/measure cost is attributed to
a phase's first call and steady-state step time is accumulated separately,
so warmup never pollutes the numbers serving decisions are made on.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs, optim
from repro.models import model as model_mod
from repro.configs.base import ModelConfig, ShapeConfig

from . import sharding as shard_mod


# ------------------------------------------------------------- step timing --
class StepTimer:
    """Separates compile/measure time from steady-state step time.

    The first call of each named phase pays tracing + XLA compilation (and,
    on the registry path, any cold plan measurement) and is recorded as that
    phase's cold time (``compile_s``); every later call lands in a warm
    :class:`repro.obs.metrics.Histogram` — the percentile math (p50/p90/p99)
    lives there, not in a parallel implementation here.  Serving reports
    must never average warmup into steady-state step time — the
    measured-pump wins are a steady-state property, and a one-off compile
    can be 1000× a decode step.

        timer = StepTimer()
        logits, cache = timer.run("decode", decode_fn, params, cache, batch)
        timer.stats()["decode"]          # flat legacy keys + cold/warm split
        timer.stats()["decode"]["warm"]  # {"calls", "mean_s", "p50_s", ...}
    """

    def __init__(self):
        self.compile_s: Dict[str, float] = {}
        self._warm: Dict[str, obs.Histogram] = {}

    def run(self, phase: str, fn, *args):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        if phase not in self.compile_s:
            self.compile_s[phase] = dt
        else:
            hist = self._warm.get(phase)
            if hist is None:
                hist = self._warm[phase] = obs.Histogram()
            hist.record(dt)
        return out

    @property
    def steady(self) -> Dict[str, list]:
        """Raw warm samples per phase (compat view over the histograms)."""
        return {phase: h.values for phase, h in self._warm.items()}

    def stats(self) -> Dict[str, Dict[str, Any]]:
        out = {}
        for phase, comp in self.compile_s.items():
            hist = self._warm.get(phase)
            n = hist.count if hist else 0

            def _r(v):
                return round(v, 6) if v is not None else None

            out[phase] = {
                # flat legacy keys (benchmarks/tests consume these)
                "compile_s": round(comp, 6),
                "steady_mean_s": _r(hist.mean) if hist else None,
                # best observed step: the number benchmarks compare against
                # (min drops scheduler tails on a shared box, mirroring the
                # paired best-of-N protocol in benchmarks/serve_report.py)
                "steady_best_s": _r(hist.min) if hist else None,
                "steady_p50_s": _r(hist.percentile(50)) if hist else None,
                "steady_p99_s": _r(hist.percentile(99)) if hist else None,
                "steps": n,
                # explicit warm-vs-cold split: cold = first call (trace +
                # XLA compile + cold plan measurement), warm = steady state
                "cold": {"calls": 1, "total_s": round(comp, 6)},
                "warm": {
                    "calls": n,
                    "total_s": _r(hist.total) if hist else 0.0,
                    "mean_s": _r(hist.mean) if hist else None,
                    "best_s": _r(hist.min) if hist else None,
                    "p50_s": _r(hist.percentile(50)) if hist else None,
                    "p90_s": _r(hist.percentile(90)) if hist else None,
                    "p99_s": _r(hist.percentile(99)) if hist else None,
                },
            }
        return out


# ----------------------------------------------------------- abstract trees --
def abstract_params(cfg: ModelConfig, param_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: model_mod.init_params(cfg, k, dtype=param_dtype),
        jax.random.PRNGKey(0))


def abstract_opt_state(optcfg: optim.AdamWConfig, params):
    return jax.eval_shape(lambda p: optim.init(optcfg, p), params)


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig,
                   pump_factor: int = 1) -> Dict[str, Any]:
    """ShapeDtypeStructs for one global training batch.

    With pump_factor=M the leading batch dim is split into M microbatches:
    (M, B/M, S).  The wide transaction stays (B, S) tokens; M is the
    temporal packing inside it.
    """
    b, s = shape.global_batch, shape.seq_len
    if pump_factor > 1:
        assert b % pump_factor == 0
        lead = (pump_factor, b // pump_factor)
    else:
        lead = (b,)
    tok = jax.ShapeDtypeStruct(lead + (s,), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            lead + (cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            lead + (cfg.n_vision_tokens, cfg.d_vision), jnp.float32)
    return batch


def abstract_decode_batch(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    batch = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig,
                   cache_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model_mod.init_cache(cfg, shape.global_batch, shape.seq_len,
                                     cache_dtype))


# -------------------------------------------------------------- train step --
def make_train_step(cfg: ModelConfig, optcfg: optim.AdamWConfig,
                    pump_factor: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def single_loss(params, batch):
        return model_mod.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if pump_factor > 1:
            # temporal vectorization of the gradient stream: M dependent
            # accumulation iterations per one optimizer/collective step
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(single_loss)(params, mb)
                grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros(()), zeros), batch)
            inv = 1.0 / pump_factor
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            loss, grads = jax.value_and_grad(single_loss)(params, batch)
        new_params, new_opt, metrics = optim.update(optcfg, grads, opt_state,
                                                    params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def train_shardings(cfg: ModelConfig, optcfg, mesh, shape: ShapeConfig,
                    param_dtype=jnp.bfloat16, pump_factor: int = 1):
    """(in_shardings, out_shardings, abstract args) for make_train_step."""
    params = abstract_params(cfg, param_dtype)
    opt_state = abstract_opt_state(optcfg, params)
    batch = abstract_batch(cfg, shape, pump_factor)

    p_shard = shard_mod.shardings(params, mesh)
    pspecs = shard_mod.fit_specs(shard_mod.param_specs(params), params, mesh)
    # ZeRO across pods: optimizer state (master/m/v) additionally shards the
    # FSDP axis over ("pod", "data") — params stay pod-replicated (cheap
    # all-gather within pod), while the 8×-larger optimizer state is divided
    # across ALL chips.  deepseek-v3: 21 GB → 15.7 GB/chip (EXPERIMENTS §Dry-run).
    ospecs = pspecs
    if "pod" in mesh.axis_names:
        def widen(sp):
            return P(*[("pod", e) if e == "data"
                       else (("pod",) + e if isinstance(e, tuple)
                             and "data" in e else e) for e in sp])
        ospecs = jax.tree.map(widen, pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        ospecs = shard_mod.fit_specs(ospecs, params, mesh)
    o_shard = optim.AdamWState(
        step=NamedSharding(mesh, P()),
        master=shard_mod.shardings(opt_state.master, mesh, ospecs),
        m=shard_mod.shardings(opt_state.m, mesh, ospecs),
        v=shard_mod.shardings(opt_state.v, mesh, ospecs),
    )
    bsp = shard_mod.batch_spec(mesh)
    bax = bsp[0] if len(bsp) else None
    bdim = 1 if pump_factor > 1 else 0   # microbatch axis leads when pumped

    def bspec(l):
        spec = [None] * l.ndim
        if l.ndim > bdim:
            spec[bdim] = bax
        return NamedSharding(mesh, shard_mod._fit(P(*spec), l.shape, mesh))

    b_shard = jax.tree.map(bspec, batch)
    metrics_shard = {"loss": NamedSharding(mesh, P()),
                     "grad_norm": NamedSharding(mesh, P()),
                     "lr": NamedSharding(mesh, P())}
    in_sh = (p_shard, o_shard, b_shard)
    out_sh = (p_shard, o_shard, metrics_shard)
    return in_sh, out_sh, (params, opt_state, batch)


# ------------------------------------------------------------ prefill step --
def make_prefill_step(cfg: ModelConfig, last_only: bool = True):
    """Forward pass over a full prompt (inference-prefill).  Serving only
    needs the final position's logits (§Perf C1); pass last_only=False for
    scoring workloads that need the whole sequence."""

    def prefill_step(params, batch):
        logits, _ = model_mod.forward(cfg, params, batch,
                                      last_only=last_only)
        return logits

    return prefill_step


# ------------------------------------------------------------- decode step --
def make_decode_step(cfg: ModelConfig):
    """(params, cache, batch) -> (next_token_logits, new_cache)."""

    def decode_step(params, cache, batch):
        logits, new_cache = model_mod.decode_step(cfg, params, batch, cache)
        return logits, new_cache

    return decode_step


def serve_shardings(cfg: ModelConfig, mesh, shape: ShapeConfig,
                    param_dtype=jnp.bfloat16, fsdp: bool = False):
    """Decode-path shardings.  ``fsdp=False`` (default) keeps weights
    TP-resident (sharded over "model" only): per-token FSDP all-gathers
    were 53 MB/layer/token on qwen2.5 decode — §Perf E2.  Training keeps
    FSDP; prefill amortizes the gathers over the whole prompt."""
    params = abstract_params(cfg, param_dtype)
    cache = abstract_cache(cfg, shape)
    batch = abstract_decode_batch(cfg, shape)
    pspecs = shard_mod.param_specs(params)
    if not fsdp and cfg.family != "moe":
        # MoE keeps FSDP for decode: only top-k of E experts touch a token,
        # so gathering the (small) active slices beats holding every
        # expert's weights 16-way resident (§Perf E3).
        pspecs = shard_mod.strip_axis(pspecs, "data")
    p_shard = shard_mod.shardings(params, mesh, pspecs)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           shard_mod.cache_specs(cache, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    b_shard = jax.tree.map(
        lambda l: NamedSharding(mesh, shard_mod._fit(
            shard_mod.batch_spec(mesh) if l.ndim else P(), l.shape, mesh)),
        batch)
    return p_shard, c_shard, b_shard, (params, cache, batch)
