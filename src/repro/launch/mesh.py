"""Production mesh construction.

Axes:
  pod   : outer pure-DP axis; only gradient all-reduce crosses it (DCN-
          friendly — optionally int8-compressed, optim/compress.py)
  data  : DP + FSDP (ZeRO-3 parameter/optimizer sharding)
  model : TP (heads/ffn), EP (experts), SP (long sequences)

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the same axis names (smoke tests, examples)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_degree(mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    return sizes.get("data", 1) * sizes.get("pod", 1)
