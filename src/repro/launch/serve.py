"""Serving launcher: batched generation with the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 16 --new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import load_arch
from repro.models import model as model_mod
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = load_arch(args.arch, smoke=args.smoke)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0),
                                   dtype=jnp.float32 if args.smoke
                                   else jnp.bfloat16)
    scfg = ServeConfig(batch=args.batch,
                       max_len=args.prompt_len + args.new + 1,
                       temperature=args.temperature)
    eng = Engine(cfg, params, scfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc_out = None
    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        enc_out = encdec.encode(cfg, params, frames)

    t0 = time.time()
    out = eng.generate(prompts, args.new, enc_out=enc_out)
    dt = time.time() - t0
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s)")
    print("[serve] first sequence:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
