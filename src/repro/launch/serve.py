"""Serving launcher: batched generation with the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 16 --new 32

Traffic-shaped mode: ``--arrival-rate R`` switches from one batched
``generate`` call to the continuous-batching scheduler — a synthetic
arrival trace (``--requests N`` requests; geometric inter-arrival gaps for
R in (0,1], Bernoulli-packed overload arrivals for R > 1) drains through
``Engine.serve_stream`` with ``--max-slots`` decode lanes (default
``--batch``, the warmed plan bucket), printing tokens/s, slot occupancy,
queue waits and per-request TTFT.  See docs/serving.md "Continuous
batching".

Overload controls (docs/serving.md "Overload behavior"):
``--prefill-chunk-tokens`` bounds per-step prefill work,
``--preempt longest_remaining|lowest_priority`` enables slot preemption,
``--max-queue`` bounds the admission queue (overflow shed as
``queue_full``), and ``--deadline-ms`` attaches a completion deadline to
every synthetic request and turns on deadline-aware shedding.

Observability: ``--trace out.json`` records a Chrome-trace of the whole run
(warmup → prefill → per-token decode; open at https://ui.perfetto.dev),
``--metrics`` prints the unified metrics snapshot (plan-registry hit rates,
emission-tier mix, latency percentiles), ``--profile DIR`` brackets the
generate call with a ``jax.profiler`` capture.  See docs/observability.md.
"""
from __future__ import annotations

import argparse
import contextlib
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import load_arch
from repro.models import model as model_mod
from repro.serve.engine import Engine, ServeConfig


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attention-impl", default=None,
                    help="override cfg.attention_impl (xla_chunked|pallas)")
    ap.add_argument("--ssm-impl", default=None,
                    help="override cfg.ssm_impl (xla|pallas)")
    ap.add_argument("--kernel-plan", default=None,
                    help="override cfg.kernel_plan (measure|direct)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the plan-registry bucket-grid warmup")
    ap.add_argument("--plan-artifact", default=None, metavar="PATH",
                    help="warm-start from a published plan artifact "
                         "(python -m repro.launch tune): verified entries "
                         "replay with zero autotune measurements; "
                         "rejected/missing entries re-measure locally")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    metavar="R",
                    help="traffic-shaped mode: drain a synthetic arrival "
                         "trace through the continuous-batching scheduler "
                         "(geometric gaps for R in (0,1]; R > 1 packs "
                         "overload arrivals)")
    ap.add_argument("--max-slots", type=int, default=None,
                    help="decode lanes for --arrival-rate mode "
                         "(default: --batch, the warmed plan bucket)")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests in the --arrival-rate trace")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=None,
                    metavar="T",
                    help="chunked prefill: cap per-step prefill work at T "
                         "tokens (long prompts admit over several steps)")
    ap.add_argument("--preempt", default=None, metavar="POLICY",
                    choices=("longest_remaining", "lowest_priority"),
                    help="enable slot preemption under queue pressure "
                         "(longest_remaining|lowest_priority)")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="bound the admission queue at N; overflow is shed "
                         "with reason queue_full")
    ap.add_argument("--deadline-ms", type=float, default=None, metavar="MS",
                    help="attach a MS deadline to every synthetic request "
                         "and shed provably-unmeetable ones")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the run to PATH")
    ap.add_argument("--metrics", action="store_true",
                    help="print the full metrics snapshot after the run")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of generate() to DIR")
    args = ap.parse_args(argv)

    if args.trace:
        obs.enable()

    cfg = load_arch(args.arch, smoke=args.smoke)
    overrides = {k: v for k, v in (("attention_impl", args.attention_impl),
                                   ("ssm_impl", args.ssm_impl),
                                   ("kernel_plan", args.kernel_plan)) if v}
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0),
                                   dtype=jnp.float32 if args.smoke
                                   else jnp.bfloat16)
    scfg = ServeConfig(batch=args.batch,
                       max_len=args.prompt_len + args.new + 1,
                       temperature=args.temperature,
                       warmup=not args.no_warmup,
                       plan_artifact=args.plan_artifact)
    eng = Engine(cfg, params, scfg)
    if eng.artifact_report is not None:
        a = eng.artifact_report
        if "error" in a:
            print(f"[serve] plan artifact UNREADABLE ({a['error']}) — "
                  f"tuning locally")
        else:
            print(f"[serve] plan artifact: {a['verified']}/{a['total']} "
                  f"entr(ies) verified, {a['rejected']} rejected"
                  + (f" ({a['reasons']})" if a["rejected"] else "")
                  + (f", {a['missing']} unmeasured upstream"
                     if a["missing"] else ""))
    prof = (obs.profile("serve.generate", logdir=args.profile)
            if args.profile else contextlib.nullcontext())

    if args.arrival_rate is not None:
        # traffic-shaped mode: synthetic arrivals through the scheduler
        if cfg.family == "encdec":
            ap.error("--arrival-rate mode needs a decoder cache "
                     "(encdec archs are not supported by the scheduler)")
        from repro.serve import scheduler as sched_mod
        reqs = sched_mod.synthetic_workload(
            args.requests, seed=1,
            prompt_lens=(max(1, args.prompt_len // 2), args.prompt_len),
            new_tokens=(args.new,), arrival_rate=args.arrival_rate,
            vocab=cfg.vocab_size,
            deadlines_ms=((args.deadline_ms,)
                          if args.deadline_ms is not None else None))
        occ = []
        t0 = time.time()
        with prof:
            results, shed = eng.serve_stream(
                reqs, max_slots=args.max_slots,
                step_hook=lambda s: occ.append(s["occupancy"]),
                prefill_chunk_tokens=args.prefill_chunk_tokens,
                preempt_policy=args.preempt,
                max_queue=args.max_queue,
                deadline_aware=args.deadline_ms is not None,
                return_shed=True)
        dt = time.time() - t0
        total_new = sum(r.n_new for r in reqs)
        served_new = sum(len(r.tokens) for r in results) if results else 0
        ttft = sorted(r.ttft_s for r in results) or [float("nan")]
        waits = [r.queue_wait_steps for r in results] or [0]
        n_deg = sum(1 for r in results if r.degraded)
        print(f"[serve] streamed {len(results)}/{len(reqs)} requests "
              f"({served_new}/{total_new} new tokens) in {dt:.2f}s wall "
              f"— {served_new / dt:.1f} tok/s at rate "
              f"{args.arrival_rate}")
        print(f"[serve] slots: peak occupancy {max(occ, default=0)}/"
              f"{args.max_slots or args.batch} over {len(occ)} steps; "
              f"queue wait: max {max(waits)} step(s); "
              f"ttft p50 {ttft[len(ttft) // 2] * 1e3:.1f}ms")
        n_pre = sum(r.preemptions for r in results)
        if n_pre:
            print(f"[serve] preemptions: {n_pre} across "
                  f"{sum(1 for r in results if r.preemptions)} request(s) "
                  f"(policy {args.preempt})")
        if shed:
            reasons: dict = {}
            for s in shed:
                reasons[s.reason] = reasons.get(s.reason, 0) + 1
            detail = ", ".join(f"{k}={v}" for k, v in sorted(reasons.items()))
            print(f"[serve] SHED: {len(shed)}/{len(reqs)} request(s) "
                  f"rejected by admission control ({detail})")
        if n_deg:
            print(f"[serve] DEGRADED: {n_deg} request(s) re-served off "
                  f"the planned path")
        out = None
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        enc_out = None
        if cfg.family == "encdec":
            from repro.models import encdec
            frames = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
            enc_out = encdec.encode(cfg, params, frames)
        t0 = time.time()
        with prof:
            out = eng.generate(prompts, args.new, enc_out=enc_out)
        dt = time.time() - t0

    stats = eng.stats()
    dec = stats["phases"].get("decode", {})
    pre = stats["phases"].get("prefill", {})
    steady = dec.get("steady_mean_s")
    if out is not None:
        # steady-state tok/s excludes warmup + compile (first prefill/
        # decode): measured-pump wins are a steady-state property, and one
        # cold compile can be 1000x a decode step
        tps = args.batch / steady if steady else float("nan")
        print(f"[serve] generated {out.shape} in {dt:.2f}s wall")
        print(f"[serve] warmup: {stats['warmup_s']:.2f}s "
              f"({stats['plans_warmed']} plans warmed, "
              f"{stats['warmup_measured']} freshly measured); "
              f"compile: prefill {pre.get('compile_s', 0):.2f}s, "
              f"decode {dec.get('compile_s', 0):.2f}s")
        for line in obs.format_phases(stats["phases"]).splitlines():
            print(f"[serve] {line}")
        print(f"[serve] steady-state decode: "
              f"{(steady or float('nan')) * 1e3:.2f} ms/step mean "
              f"({tps:.1f} tok/s)")
    if stats["registry"] is not None:
        # prefill vs decode bucket split: a cold decode bucket (misses > 0
        # after warmup) must be visible at a glance, not buried in a total
        r = stats["registry"]
        print(f"[serve] plan registry: prefill {r['prefill']} | "
              f"decode {r['decode']} | hit_rate={r['hit_rate']} "
              f"fallbacks={r['fallbacks']} measure_s={r['measure_s']}")
    # robustness surface (docs/robustness.md): degraded requests, failed
    # warmup buckets and quarantined plans all say "the ladder was walked" —
    # zero on a healthy run, and a loud launch-output line when not
    from repro.compiler import default_cache
    quarantined = default_cache().quarantine_entries()
    if (stats["degraded_requests"] or stats["warmup_failed"]
            or quarantined):
        print(f"[serve] DEGRADED: {stats['degraded_requests']} request(s) "
              f"served off the planned path, {stats['warmup_failed']} "
              f"warmup bucket(s) failed, {len(quarantined)} plan(s) "
              f"quarantined")
        for key, q in sorted(quarantined.items()):
            print(f"[serve]   quarantine {key[:20]}…: {q['reason']} "
                  f"(fail #{q['fails']})")
    if out is not None:
        print("[serve] first sequence:", out[0][:16].tolist())
    else:
        first = min(results, key=lambda r: r.rid)
        print("[serve] first request tokens:",
              [int(t) for t in first.tokens[:16]])

    if args.metrics:
        for line in obs.format_snapshot(obs.snapshot()).splitlines():
            print(f"[metrics] {line}")
    if args.trace:
        obs.write_trace(args.trace,
                        metadata={"arch": args.arch, "batch": args.batch,
                                  "prompt_len": args.prompt_len,
                                  "n_new": args.new})
        print(f"[serve] trace written to {args.trace} "
              f"(open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
