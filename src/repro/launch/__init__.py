"""Launchers: mesh construction, sharding rules, step builders, dry-run,
training and serving entry points."""
from . import mesh, sharding, steps

__all__ = ["mesh", "sharding", "steps"]
