"""Declarative sharding rules: param/batch/cache pytrees → PartitionSpecs.

Rules are name+shape based and *divisibility-safe*: any axis that does not
divide its mesh extent is silently replicated (essential for smoke configs
on 1 device and for small leaves like norm scales).  Conventions follow
launch/mesh.py: "data" carries FSDP, "model" carries TP/EP/SP.

The same rule table drives both the dry-run in_shardings and the trainer's
``with_sharding_constraint`` activation annotations.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _fit(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec entries that don't divide; pad/trim rank."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ent in zip(shape, entries[:len(shape)]):
        if ent is None:
            out.append(None)
            continue
        axes = ent if isinstance(ent, tuple) else (ent,)
        prod = int(np.prod([sizes.get(a, 1) for a in axes]))
        out.append(ent if dim % prod == 0 and prod > 1 else None)
    return P(*out)


# ------------------------------------------------------------- param rules --
def _param_rule(path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
    """Base spec by parameter role; leading stacked-layer axes handled by
    caller padding (specs are right-aligned to the trailing dims)."""
    names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
    last = names[-1] if names else ""
    joined = "/".join(names)

    if last == "embedding":                       # (V, d)
        return P("model", "data")
    if "moe" in joined and last in ("gate", "up"):   # (E, d, f) experts
        return P("model", "data", None)
    if "moe" in joined and last == "down":           # (E, f, d)
        return P("model", None, "data")
    if last in ("scale", "bias", "b", "A_log", "dt_bias", "D", "conv_b"):
        return P()                                 # small: replicate
    if last == "conv_w":                           # (W, conv_dim)
        return P(None, "model")
    if last == "w":
        parent = names[-2] if len(names) >= 2 else ""
        if parent in ("wo", "down", "out_proj", "wkv_b", "wq_b", "fc2"):
            # row-parallel: contract dim is model-sharded
            return P("model", "data")
        # column-parallel default: wq, wk, wv, gate, up, in_proj, router, ...
        return P("data", "model")
    return P()


def param_specs(params: Any, sample_shapes: Any = None) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs).

    Stacked-layer leading axes (from scan) are detected by rank: the base
    rule covers the trailing dims and leading dims are unsharded.
    """
    def rule(path, leaf):
        shape = leaf.shape
        base = _param_rule(path, shape)
        base_len = len([e for e in base]) if len(base) else 0
        # right-align: pad leading Nones for stacked axes
        if base_len and len(shape) > base_len:
            base = P(*([None] * (len(shape) - base_len) + list(base)))
        elif base_len and len(shape) < base_len:
            base = P(*list(base)[-len(shape):]) if len(shape) else P()
        return base

    return jax.tree_util.tree_map_with_path(rule, params)


def fit_specs(specs: Any, tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s, l: _fit(s, l.shape, mesh), specs, tree,
                        is_leaf=lambda x: isinstance(x, P))


def shardings(tree: Any, mesh: Mesh, specs: Any = None) -> Any:
    """NamedSharding pytree for ``tree`` under ``mesh``."""
    if specs is None:
        specs = param_specs(tree)
    specs = fit_specs(specs, tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------- batch rules --
def batch_spec(mesh: Mesh) -> P:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return P(tuple(axes)) if axes else P()


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    bspec = batch_spec(mesh)

    def rule(leaf):
        if leaf.ndim == 0:
            return P()
        return _fit(P(bspec[0] if len(bspec) else None), leaf.shape, mesh)

    return jax.tree.map(rule, batch)


# ------------------------------------------------------------- cache rules --
def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """KV/SSM cache sharding: batch over (pod, data), heads over model.

    Layout conventions: gqa (L, B, Hkv, T, hd); mla (L, B, T, r);
    mamba state (L, B, H, N, P), conv (L, B, W-1, C); pos (L,).
    """
    b = batch_spec(mesh)
    bax = b[0] if len(b) else None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes.get("model", 1)

    def rule(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        last = names[-1]
        if last == "pos":
            return P()
        if last in ("k", "v"):        # (L, B, Hkv, T, hd)
            # shard heads over "model" when they divide, else the time axis
            # (sequence-parallel cache — the long_500k / small-Hkv case)
            if leaf.ndim == 5 and leaf.shape[2] % msize == 0:
                return _fit(P(None, bax, "model", None, None), leaf.shape,
                            mesh)
            return _fit(P(None, bax, None, "model", None), leaf.shape, mesh)
        if last in ("c_kv", "k_rope"):  # (L, B, T, r): sequence-parallel
            return _fit(P(None, bax, "model", None), leaf.shape, mesh)
        if last == "state":           # (L, B, H, N, P)
            return _fit(P(None, bax, "model", None, None), leaf.shape, mesh)
        if last == "conv":            # (L, B, W-1, C)
            return _fit(P(None, bax, None, "model"), leaf.shape, mesh)
        return _fit(P(None, bax), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache)


def strip_axis(specs: Any, axis: str) -> Any:
    """Remove one mesh axis from every spec (e.g. disable TP for small
    models where per-layer collectives dominate — EXPERIMENTS.md §Perf D)."""
    def strip(sp):
        out = []
        for e in sp:
            if e == axis:
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != axis)
                out.append(kept if kept else None)
            else:
                out.append(e)
        return P(*out)
    return jax.tree.map(strip, specs, is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that tolerates non-dividing dims."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _fit(spec, x.shape, mesh)))
