import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (device count locks at
# first init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the jitted step (train / prefill / decode),
lowers it against abstract inputs with full production shardings, compiles,
and records:

  - memory_analysis()        → bytes/device (proves the config fits HBM)
  - cost_analysis()          → HLO FLOPs / bytes (roofline compute+memory)
  - collective byte counts   → parsed from the optimized HLO (roofline
                               collective term)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import SHAPES, cells, load_arch
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# parse operand shapes like f32[16,128]{1,0} / bf16[2,4,8]
_SHAPE_RE = re.compile(r"(pred|s4|s8|s16|s32|s64|u8|u16|u32|u64|bf16|f16|"
                       r"f32|f64|c64|c128)\[([0-9,]*)\]")
_BYTES = {"pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
          "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "c64": 8,
          "s64": 8, "u64": 8, "f64": 8, "c128": 16}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"[%\w.\-]+\s*=\s*(\S+)\s+(\S+)\(", ls)
        if not m:
            continue
        shape_part, op = m.group(1), m.group(2)
        kind = next((k for k in COLLECTIVE_OPS if op.startswith(k)), None)
        if kind is None:
            continue
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(shape_part))
        out[kind] += nbytes
        out["count"] += 1
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             pump_factor: int = 1, param_dtype=jnp.bfloat16,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = load_arch(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    optcfg = optim.AdamWConfig(moment_dtype="bfloat16")
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            step = steps_mod.make_train_step(cfg, optcfg, pump_factor)
            in_sh, out_sh, args = steps_mod.train_shardings(
                cfg, optcfg, mesh, shape, param_dtype, pump_factor)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(0, 1))
            lowered = jitted.lower(*args)
        elif shape.kind == "prefill":
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch import sharding as shard_mod
            step = steps_mod.make_prefill_step(cfg)
            params = steps_mod.abstract_params(cfg, param_dtype)
            p_sh = shard_mod.shardings(params, mesh)
            batch = steps_mod.abstract_batch(cfg, shape)
            del batch["labels"]
            bsp = shard_mod.batch_spec(mesh)
            bax = bsp[0] if len(bsp) else None
            b_sh = jax.tree.map(
                lambda l: NamedSharding(mesh, shard_mod._fit(
                    P(*((bax,) + (None,) * (l.ndim - 1))), l.shape, mesh)),
                batch)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, batch)
        else:  # decode
            step = steps_mod.make_decode_step(cfg)
            p_sh, c_sh, b_sh, (params, cache, batch) = \
                steps_mod.serve_shardings(cfg, mesh, shape, param_dtype)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=(None, c_sh), donate_argnums=(1,))
            lowered = jitted.lower(params, cache, batch)

        compiled = lowered.compile()

    t1 = time.time()
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "pump_factor": pump_factor,
        "kind": shape.kind,
        "compile_s": round(t1 - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": {k: v for k, v in coll.items() if v},
        "collective_total": sum(v for k, v in coll.items() if k != "count"),
        "collective_count": coll["count"],
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']} "
              f"OK in {result['compile_s']}s  "
              f"flops={result['flops']:.3e}  "
              f"bytes={result['bytes_accessed']:.3e}  "
              f"coll={result['collective_total']:.3e}B "
              f"({result['collective_count']} ops)")
        sys.stdout.flush()
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--pump", type=int, default=1)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    results = []
    if args.all:
        todo = cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in todo:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, multi_pod=mp,
                                        pump_factor=args.pump))
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape, mp, repr(e)[:300]))
                print(f"[dryrun] FAIL {arch} × {shape} × "
                      f"{'2x16x16' if mp else '16x16'}: {e!r}"[:400])
                sys.stdout.flush()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    print(f"\n[dryrun] {len(results)} cells OK, {len(failures)} failed")
    if failures:
        for f in failures:
            print("  FAIL:", f)
        sys.exit(1)


if __name__ == "__main__":
    main()
