"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 100 --pump auto --ckpt /tmp/ckpt

On this CPU container use --smoke (reduced config).  On a real TPU slice the
same entry point runs the full config under make_production_mesh(); jax
initializes the distributed runtime from the TPU environment.
"""
from __future__ import annotations

import argparse

import jax

from repro import optim
from repro.configs.base import SHAPES, ShapeConfig, load_arch
from repro.launch import mesh as mesh_mod
from repro.train.trainer import TrainConfig, train


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--pump", default="1", help="int or 'auto'")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--failover", action="store_true",
                    help="wire the failover runtime into the loop: per-step "
                         "heartbeat stamping + straggler pump derating")
    ap.add_argument("--heartbeat-timeout", type=float, default=300.0,
                    help="seconds without progress before a worker is "
                         "considered dead (--failover)")
    args = ap.parse_args(argv)

    cfg = load_arch(args.arch, smoke=args.smoke)
    shape = SHAPES[args.shape]
    if args.smoke:
        shape = ShapeConfig("smoke", args.seq or 64, args.batch or 8, "train")
    elif args.batch or args.seq:
        shape = ShapeConfig("custom", args.seq or shape.seq_len,
                            args.batch or shape.global_batch, "train")

    mesh = (mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else mesh_mod.make_host_mesh())
    pump = args.pump if args.pump == "auto" else int(args.pump)
    optcfg = optim.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                               total_steps=args.steps)
    tcfg = TrainConfig(n_steps=args.steps, pump_factor=pump,
                       ckpt_root=args.ckpt,
                       param_dtype="float32" if args.smoke else "bfloat16")
    heartbeat = straggler = None
    if args.failover:
        from repro.runtime.failover import Heartbeat, StragglerPolicy
        heartbeat = Heartbeat(timeout_s=args.heartbeat_timeout)
        straggler = StragglerPolicy()
    out = train(cfg, shape, optcfg, tcfg, mesh=mesh,
                heartbeat=heartbeat, straggler=straggler)
    hist = out["history"]
    if hist:
        print(f"[train] done: loss {hist[0]['loss']:.4f} -> "
              f"{hist[-1]['loss']:.4f} over {args.steps} steps "
              f"(pump={out['pump']})")
    if heartbeat is not None:
        dead = heartbeat.dead_workers()
        factors = straggler.pump_factors()
        print(f"[failover] heartbeat: {len(heartbeat._step)} worker(s) "
              f"stamped, {len(dead)} dead; straggler pump factors "
              f"{factors}")


if __name__ == "__main__":
    main()
