"""Offline tuner launcher: measure the plan grid once, publish an artifact.

    PYTHONPATH=src python -m repro.launch tune --arch qwen3-0.6b --smoke \
        --batch 2 --max-len 32 --out plans.artifact.json

Runs one tuner worker (``repro.tune``) against a shared lease ledger +
compile-cache store: the (kernel × bucket) grid is enumerated from the
config, deduped by compile-cache content hash, sharded, and drained under
heartbeat-stamped leases — run the same command on N machines sharing
``--work-dir`` and they partition the grid automatically; a worker killed
mid-measurement loses its lease and a survivor reclaims the shard.  The
published artifact is schema-versioned with a per-entry verified manifest
(partial results salvage), and ``launch.serve --plan-artifact`` warm-starts
replicas from it with zero autotune measurements.
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path
from typing import Optional, Sequence


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64,
                    help="tune the bucket grid up to this sequence length "
                         "(match the serving ServeConfig.max_len)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="publish the plan artifact to PATH (default: "
                         "<work-dir>/plans.artifact.json)")
    ap.add_argument("--work-dir", default=None, metavar="DIR",
                    help="shared fleet directory for the lease ledger and "
                         "plan store (default: $REPRO_CACHE_DIR or "
                         "~/.cache/repro)")
    ap.add_argument("--worker-id", default=None,
                    help="fleet member id (default: tuner-<pid>)")
    ap.add_argument("--shards", type=int, default=4,
                    help="lease shards to partition the grid into")
    ap.add_argument("--ttl", type=float, default=30.0, metavar="S",
                    help="lease TTL: a worker silent for S seconds loses "
                         "its shard to reclaim")
    ap.add_argument("--backend", default="pallas")
    ap.add_argument("--attention-impl", default=None)
    ap.add_argument("--ssm-impl", default=None)
    args = ap.parse_args(argv)

    from repro.configs.base import load_arch
    from repro.tune import run_fleet

    cfg = load_arch(args.arch, smoke=args.smoke)
    overrides = {k: v for k, v in (("attention_impl", args.attention_impl),
                                   ("ssm_impl", args.ssm_impl)) if v}
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)

    work_dir = Path(args.work_dir or os.environ.get("REPRO_CACHE_DIR")
                    or (Path.home() / ".cache" / "repro"))
    out = Path(args.out) if args.out else work_dir / "plans.artifact.json"
    worker_id = args.worker_id or f"tuner-{os.getpid()}"

    rep = run_fleet(cfg, args.batch, args.max_len,
                    ledger_path=work_dir / "tune_ledger.json",
                    store_path=work_dir / "compile_cache.json",
                    out_path=out, n_shards=args.shards,
                    worker_id=worker_id, ttl_s=args.ttl,
                    backend=args.backend)

    w = rep["worker"]
    print(f"[tune] {worker_id}: grid {rep['work_items']} request(s) -> "
          f"{rep['groups']} deduped group(s); measured {w['measured']}, "
          f"replayed {w['replayed']}, failed {len(w['failed'])}")
    print(f"[tune] ledger: "
          + ", ".join(f"{k}={v}" for k, v in sorted(rep["ledger"].items()))
          + (f"; lease errors {w['lease_errors']}"
             if w["lease_errors"] else ""))
    if w["shards_lost"]:
        print(f"[tune] LOST LEASES: {len(w['shards_lost'])} shard(s) "
              f"reclaimed by other workers — their results publish from "
              f"the new owners")
    art = rep.get("artifact")
    if art:
        status = "complete" if art["complete"] else \
            f"SALVAGED ({art['missing']} group(s) unmeasured)"
        print(f"[tune] artifact: {art['entries']} plan(s) -> {art['path']} "
              f"[{status}]")
        print(f"[tune] serve replicas warm-start with: "
              f"python -m repro.launch serve --arch {args.arch} "
              f"--plan-artifact {art['path']}")
    print(json.dumps({"worker": worker_id,
                      "measured": w["measured"],
                      "replayed": w["replayed"],
                      "artifact": art}, indent=None))


if __name__ == "__main__":
    main()
