"""Subcommand dispatch: ``python -m repro.launch {tune,serve} ...``.

The per-module entry points stay directly runnable
(``python -m repro.launch.serve``); this wrapper only routes."""
from __future__ import annotations

import sys


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    commands = {"tune": "repro.launch.tune", "serve": "repro.launch.serve"}
    if not argv or argv[0] not in commands:
        known = ", ".join(sorted(commands))
        sys.exit(f"usage: python -m repro.launch {{{known}}} [args...]")
    import importlib
    mod = importlib.import_module(commands[argv[0]])
    mod.main(argv[1:])


if __name__ == "__main__":
    main()
