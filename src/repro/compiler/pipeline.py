"""Pass-pipeline driver.

A :class:`Pipeline` is an ordered list of :class:`~repro.compiler.passes.GraphPass`
instances.  ``run`` walks them over a graph: passes whose ``can_apply``
rejects are recorded as skipped (with the reason) and the graph flows through
unchanged; applied passes contribute their own report object.  The resulting
:class:`PipelineReport` is the compiler's provenance record — it also carries
the compile-cache bookkeeping (key, which layer served the request, and a hit
counter) that :func:`repro.compiler.compile` fills in.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

from repro import obs
from repro.core.ir import Graph
from repro.core.pump_plan import VMEM_BYTES

from .passes import (FifoDepthPass, GraphPass, MultipumpPass, StreamFusionPass,
                     StreamingPass)


@dataclasses.dataclass
class PassRecord:
    name: str
    applied: bool
    reason: str = ""
    report: Any = None
    resources: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PipelineReport:
    graph: str
    records: List[PassRecord] = dataclasses.field(default_factory=list)
    # compile/autotune cache bookkeeping (filled by repro.compiler.compile)
    cache_key: Optional[str] = None
    served_from: Optional[str] = None   # None | "disk" | "memory"
    cache_hits: int = 0
    # lowering-time degradation notes (misaligned pump factors, dropped
    # temporal axes, emission-tier downgrades) — deduplicated messages
    warnings: List[str] = dataclasses.field(default_factory=list)
    # measured-runtime autotune provenance: {"winner", "timings_us",
    # "backend", "replayed"} when compile(..., autotune='measure') ran or
    # a measured plan was replayed from the cache
    autotune: Optional[dict] = None
    # pallas-backend emission provenance: {region name: {"tier", ...}}
    emission: Optional[dict] = None

    def record(self, name: str) -> Optional[PassRecord]:
        for r in self.records:
            if r.name == name:
                return r
        return None

    def warn(self, msg: str) -> None:
        """Append a degradation note, deduplicated: lowering revisits (and
        bucket-grid sweeps that aggregate reports) re-emit byte-identical
        messages, and each unique message should be recorded once."""
        if msg not in self.warnings:
            self.warnings.append(msg)

    @property
    def warning_count(self) -> int:
        return len(self.warnings)

    @property
    def factor(self) -> int:
        r = self.record("multipump")
        if r is not None and r.applied and r.report is not None:
            return r.report.factor
        return 1

    @property
    def mode(self) -> str:
        r = self.record("multipump")
        if r is not None and r.applied and r.report is not None:
            return r.report.mode
        return "T"

    def summary(self) -> str:
        parts = [f"{r.name}:{'+' if r.applied else '-'}" for r in self.records]
        cache = f" cache={self.served_from or 'miss'}({self.cache_hits})"
        tail = f" warn={self.warning_count}" if self.warnings else ""
        return (f"[{self.graph}] " + " ".join(parts) + f" M={self.factor}"
                + cache + tail)


class Pipeline:
    """Deterministic driver running registered passes in order."""

    def __init__(self, passes: Sequence[GraphPass]):
        self.passes = list(passes)

    @staticmethod
    def default(factor="auto", mode: str = "T", vmem_budget: int = VMEM_BYTES,
                max_factor: int = 16, estimate=None, fuse: bool = True,
                size_fifos: bool = True) -> "Pipeline":
        """The paper's §3 ordering: stream, fuse, pump, then size FIFOs
        (depths depend on the chosen pump factor, so sizing runs last)."""
        passes: List[GraphPass] = [StreamingPass()]
        if fuse:
            passes.append(StreamFusionPass())
        passes.append(MultipumpPass(factor=factor, mode=mode,
                                    vmem_budget=vmem_budget,
                                    max_factor=max_factor, estimate=estimate))
        if size_fifos:
            passes.append(FifoDepthPass())
        return Pipeline(passes)

    def run(self, g: Graph) -> Tuple[Graph, PipelineReport]:
        report = PipelineReport(graph=g.name)
        cur = g
        with obs.span("compiler.pipeline", cat="compile", graph=g.name,
                      nodes=len(g.nodes), edges=len(g.edges)) as pspan:
            for p in self.passes:
                with obs.span("compiler.pass", cat="compile", graph=g.name,
                              **{"pass": p.name}) as sp:
                    ok, why = p.can_apply(cur)
                    if not ok:
                        sp.set(applied=False, reason=why)
                        report.records.append(PassRecord(p.name, False, why))
                        continue
                    cur, prep = p.apply(cur)
                    applied = bool(getattr(prep, "applied", True))
                    reason = getattr(prep, "reason", "ok") or "ok"
                    sp.set(applied=applied, reason=reason)
                    report.records.append(PassRecord(p.name, applied, reason,
                                                     prep, cur.resources()))
            pspan.set(factor=report.factor, mode=report.mode)
        return cur, report
