"""Pallas emission backend: fused region lowering.

Where :mod:`.lowering` schedules the transformed graph node by node (every
Reader/Writer a flat HBM gather/scatter, every adapter a value-identity
loop), this backend partitions the graph into **fused compute regions** —
the maximal ``Memory → Reader → … → Writer → Memory`` chains between memory
containers, with Sync boundaries realized by the Pallas pipeline itself —
and emits each region as *one* blocked kernel.  The paper's pump factor M is
realized structurally: as the **innermost temporal grid axis** of the
region's grid, not as an in-kernel loop.

    Mode T: the innermost grid dimension (extent G) splits into G/M wide
            transactions × M narrow beats — offsets rewritten by the exact
            substitution ``g -> g*M + _pump``.
    Mode R: the output-carrying block dimension narrows by M and the ``_pump``
            axis walks its M sub-tiles; operand blocks narrowed only where
            they share the output's grid symbol.

Each region is emitted at the highest tier its structure admits:

``pallas``     a real ``pl.pallas_call``: every access has a *block-unit*
               index map (offsets divide by the block), every compute a
               per-tile body (``meta['tile_fn']``), and the output tiling
               covers the memory.  Used on TPU; on CPU only when forced
               (``pallas_mode='interpret'``), since interpret mode exists
               for validation, not speed.
``blockloop``  a structurally identical fused ``fori_loop`` over the same
               grid with element-unit ``dynamic_slice`` blocks — the
               ``jax.jit`` fallback of the pallas emission on CPU.  Handles
               overlapping halo windows pallas block indexing cannot.
``gather``     region-level fallback: one gather → compute-chain → scatter
               per region (still fused; no per-node barriers or gearbox
               loops).  Used when computes lack a tile form (e.g. the
               dependency-carrying floyd-warshall pivot loop).

Grid dimensions absent from the output access (plus the temporal axis when
it splits one of them) are *reduction* dimensions: the emitted kernel
zero-initializes the output tile on their first visit and accumulates with
``+`` thereafter — computes marked ``meta['reduce']='add'`` return partial
contributions per grid step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.executor import _toposort
from repro.core.ir import Graph, NodeKind
from repro.core.symbolic import (Affine, BlockedAccess, blocked_access,
                                 narrow_block, split_temporal)

from .lowering import LoweringError, _indices, scatter_indices

PUMP_SYM = "_pump"
_PASS_THROUGH = (NodeKind.STREAM, NodeKind.SYNC, NodeKind.ISSUER,
                 NodeKind.PACKER, NodeKind.READER, NodeKind.WRITER)


# ------------------------------------------------------------ region graph --
@dataclasses.dataclass
class Region:
    """One fused region: the modules between memory containers."""

    name: str
    members: List[str]                       # non-memory node names
    computes: List[str]                      # topo order
    # per compute, operand sources in edge order:
    #   ("mem", memory name, AccessPattern) | ("comp", upstream compute name)
    bindings: Dict[str, List[Tuple]]
    # (compute, memory, AccessPattern) writes out of the region
    outputs: List[Tuple[str, str, Any]]
    pump: int = 1
    mode: str = "T"


def _trace_to_source(g: Graph, edge) -> Tuple:
    """Walk an in-edge backwards through pass-through modules to its origin:
    a memory (with the reader's access pattern) or an upstream compute."""
    e = edge
    while True:
        src = g.nodes[e.src]
        if src.kind == NodeKind.MEMORY:
            return ("mem", src.name, e.access)
        if src.kind == NodeKind.COMPUTE:
            return ("comp", src.name)
        ins = g.in_edges(src.name)
        if len(ins) != 1:
            raise LoweringError(
                f"pass-through module {src.name} has {len(ins)} inputs")
        e = ins[0]


def _trace_to_sink(g: Graph, edge) -> Optional[Tuple]:
    """Walk an out-edge forward to a memory write; None when it feeds a
    downstream compute inside the region instead."""
    e = edge
    while True:
        dst = g.nodes[e.dst]
        if dst.kind == NodeKind.MEMORY:
            return (dst.name, e.access)
        if dst.kind == NodeKind.COMPUTE:
            return None
        outs = g.out_edges(dst.name)
        if len(outs) != 1:
            raise LoweringError(
                f"pass-through module {dst.name} has {len(outs)} outputs")
        e = outs[0]


def partition_regions(g: Graph) -> List[Region]:
    """Split ``g`` into fused regions: connected components of the module/
    stream subgraph, with memory containers as the region boundaries."""
    # union-find over non-memory nodes
    parent: Dict[str, str] = {n.name: n.name for n in g.nodes.values()
                              if n.kind != NodeKind.MEMORY}

    def root(n: str) -> str:
        while parent[n] != n:
            parent[n] = parent[parent[n]]
            n = parent[n]
        return n

    for e in g.edges:
        if e.src in parent and e.dst in parent:
            parent[root(e.src)] = root(e.dst)

    groups: Dict[str, List[str]] = {}
    for n in parent:
        groups.setdefault(root(n), []).append(n)

    order = _toposort(g)
    pos = {n: i for i, n in enumerate(order)}
    regions = []
    for members in groups.values():
        members.sort(key=pos.__getitem__)
        computes = [n for n in members
                    if g.nodes[n].kind == NodeKind.COMPUTE]
        if not computes:
            continue   # dangling adapters with no compute: nothing to emit
        bindings: Dict[str, List[Tuple]] = {}
        outputs: List[Tuple[str, str, Any]] = []
        for c in computes:
            bindings[c] = [_trace_to_source(g, e) for e in g.in_edges(c)]
            for e in g.out_edges(c):
                sink = _trace_to_sink(g, e)
                if sink is not None:
                    outputs.append((c, sink[0], sink[1]))
        pump = max((g.nodes[c].pump for c in computes), default=1)
        mode = next((g.nodes[c].meta.get("pump_mode") for c in computes
                     if g.nodes[c].meta.get("pump_mode")), "T")
        regions.append(Region(name=computes[0], members=members,
                              computes=computes, bindings=bindings,
                              outputs=outputs, pump=pump, mode=mode))

    # schedule regions by memory dataflow, not by node position: a region
    # reading memory m must run after every region writing m (the node-level
    # toposort guarantees this order exists)
    writers: Dict[str, List[int]] = {}
    for i, r in enumerate(regions):
        for _c, mem, _a in r.outputs:
            writers.setdefault(mem, []).append(i)
    deps: Dict[int, set] = {i: set() for i in range(len(regions))}
    for i, r in enumerate(regions):
        for srcs in r.bindings.values():
            for src in srcs:
                if src[0] == "mem":
                    deps[i].update(j for j in writers.get(src[1], ())
                                   if j != i)
    ordered: List[Region] = []
    done: set = set()
    while len(done) < len(regions):
        ready = sorted(
            (i for i in deps if i not in done and deps[i] <= done),
            key=lambda i: pos[regions[i].computes[0]])
        if not ready:   # pragma: no cover - node toposort forbids cycles
            raise LoweringError("cyclic memory dependency between regions")
        for i in ready:
            done.add(i)
            ordered.append(regions[i])
    return ordered


# ------------------------------------------------------------- region plan --
@dataclasses.dataclass
class RegionPlan:
    """A tile-emittable region: unified grid + blocked views per operand."""

    region: Region
    grid: Tuple[Tuple[str, int], ...]        # outermost → innermost
    reduce_syms: Tuple[str, ...]             # grid syms absent from output
    blocks: Dict[Tuple[str, int], BlockedAccess]   # (compute, operand idx)
    out_compute: str
    out_mem: str
    out_block: BlockedAccess
    tile_fns: Dict[str, Callable]
    pump: int = 1                            # realized temporal factor
    mode: str = "T"
    pallas_ok: bool = True                   # block-unit maps + full coverage


def _tile_fn_of(g: Graph, name: str) -> Optional[Callable]:
    n = g.nodes[name]
    fn = n.meta.get("tile_fn")
    if fn is None and n.meta.get("elementwise"):
        fn = n.fn
    return fn


def plan_region(g: Graph, region: Region,
                warn: Callable[[str], None]) -> Optional[RegionPlan]:
    """Derive the blocked emission plan for a region, or None when the
    region must fall back to gather emission (reason passed to ``warn``)."""
    if len(region.outputs) != 1:
        warn(f"region {region.name}: {len(region.outputs)} output memories; "
             "tile emission needs exactly 1 — using gather fallback")
        return None
    out_compute, out_mem, out_access = region.outputs[0]
    if out_access is None:
        warn(f"region {region.name}: output access unknown")
        return None

    tile_fns = {}
    for c in region.computes:
        fn = _tile_fn_of(g, c)
        if fn is None:
            warn(f"region {region.name}: compute {c} has no per-tile body "
                 "(meta['tile_fn']); using gather fallback")
            return None
        if not region.bindings[c]:
            warn(f"region {region.name}: compute {c} has no operands")
            return None
        tile_fns[c] = fn

    out_block = blocked_access(out_access, g.nodes[out_mem].shape)
    if out_block is None:
        warn(f"region {region.name}: output access is not block-affine")
        return None

    blocks: Dict[Tuple[str, int], BlockedAccess] = {}
    extents: Dict[str, int] = dict(out_block.grid)
    extra_syms: List[str] = []
    for c in region.computes:
        for k, src in enumerate(region.bindings[c]):
            if src[0] != "mem":
                continue
            if src[2] is None:
                warn(f"region {region.name}: operand {src[1]} of {c} has "
                     "no access pattern")
                return None
            acc = blocked_access(src[2], g.nodes[src[1]].shape)
            if acc is None:
                warn(f"region {region.name}: operand {src[1]} of {c} is not "
                     "block-affine")
                return None
            for s, e in acc.grid:
                if extents.setdefault(s, e) != e:
                    warn(f"region {region.name}: grid extent mismatch on "
                         f"{s}: {extents[s]} vs {e}")
                    return None
                if s not in dict(out_block.grid) and s not in extra_syms:
                    extra_syms.append(s)
            blocks[(c, k)] = acc

    # canonical grid: output order first, reduction symbols innermost
    grid = tuple(out_block.grid) + tuple((s, extents[s]) for s in extra_syms)
    reduce_syms = tuple(extra_syms)
    plan = RegionPlan(region=region, grid=grid, reduce_syms=reduce_syms,
                      blocks=blocks, out_compute=out_compute,
                      out_mem=out_mem, out_block=out_block,
                      tile_fns=tile_fns, mode=region.mode)
    _apply_temporal(plan, region.pump, warn)
    plan.pallas_ok = _pallas_expressible(g, plan)
    return plan


def _apply_temporal(plan: RegionPlan, factor: int,
                    warn: Callable[[str], None]) -> None:
    """Realize pump factor M as the innermost ``_pump`` grid axis."""
    if factor <= 1:
        return
    if plan.mode == "T":
        if not plan.grid:
            warn(f"region {plan.region.name}: no grid dimension to pump")
            return
        sym, ext = plan.grid[-1]
        if ext % factor:
            warn(f"region {plan.region.name}: innermost grid extent {ext} "
                 f"({sym}) not divisible by pump factor {factor}; temporal "
                 "axis dropped")
            return
        plan.blocks = {k: split_temporal(a, sym, factor)
                       for k, a in plan.blocks.items()}
        plan.out_block = split_temporal(plan.out_block, sym, factor)
        grid = [(s, e // factor if s == sym else e) for s, e in plan.grid]
        plan.grid = tuple(grid) + ((PUMP_SYM, factor),)
        if sym in plan.reduce_syms:
            plan.reduce_syms = plan.reduce_syms + (PUMP_SYM,)
    else:   # mode R: narrow the output-carrying block dimension
        out = plan.out_block
        d_out = max((d for d, b in enumerate(out.block) if b > 1),
                    default=None)
        if d_out is None or out.block[d_out] % factor:
            warn(f"region {plan.region.name}: mode-R output block not "
                 f"divisible by pump factor {factor}; temporal axis dropped")
            return
        b_wide = out.block[d_out]
        dep = frozenset(out.offsets[d_out].symbols())
        plan.out_block = narrow_block(out, d_out, factor)
        narrowed = {}
        for key, acc in plan.blocks.items():
            new = acc
            for d in reversed(range(len(acc.block))):
                if acc.block[d] == b_wide \
                        and frozenset(acc.offsets[d].symbols()) == dep:
                    new = narrow_block(acc, d, factor)
                    break
            narrowed[key] = new
        plan.blocks = narrowed
        plan.grid = tuple(plan.grid) + ((PUMP_SYM, factor),)
    plan.pump = factor


def _pallas_expressible(g: Graph, plan: RegionPlan) -> bool:
    """True when every access has a block-unit index map and the output
    tiling covers its memory (pallas output buffers start uninitialized)."""
    if plan.out_block.block_unit_offsets() is None:
        return False
    covered = 1
    for b in plan.out_block.block:
        covered *= b
    for s, e in plan.grid:
        if s not in plan.reduce_syms:
            covered *= e
    if covered != int(np.prod(g.nodes[plan.out_mem].shape)):
        return False
    return all(a.block_unit_offsets() is not None
               for a in plan.blocks.values())


# ---------------------------------------------------------------- emission --
def _affine_eval(a: Affine, env: Mapping[str, Any]):
    out = a.const
    for s, c in a.terms:
        out = out + c * env[s]
    return out


def _run_tiles(plan: RegionPlan, get_block: Callable[[str, int], Any]) -> Any:
    """Evaluate the region's compute chain for one grid point;
    ``get_block(compute, operand_idx)`` supplies memory operand blocks."""
    tiles: Dict[str, Any] = {}
    for c in plan.region.computes:
        bound = {}
        for k, src in enumerate(plan.region.bindings[c]):
            if src[0] == "mem":
                bound[f"in{k}"] = get_block(c, k)
            else:
                bound[f"in{k}"] = tiles[src[1]]
        r = plan.tile_fns[c](**bound)
        tiles[c] = r["out0"] if isinstance(r, dict) else r
    return tiles[plan.out_compute]


def emit_blockloop(g: Graph, plan: RegionPlan) -> Callable:
    """Tier ``blockloop``: the pallas schedule as a fused ``fori_loop`` with
    element-unit ``dynamic_slice`` blocks — the jit fallback on CPU."""
    grid = plan.grid
    sizes = [e for _, e in grid]
    total = int(np.prod(sizes)) if sizes else 1
    out_shape = g.nodes[plan.out_mem].shape
    out_block = plan.out_block

    def region_fn(mems: Dict[str, Any]) -> Any:
        def body(step, buf):
            env: Dict[str, Any] = {}
            rem = step
            for (sym, ext) in reversed(grid):
                env[sym] = rem % ext
                rem = rem // ext

            def get_block(c, k):
                acc = plan.blocks[(c, k)]
                mem = mems[plan.region.bindings[c][k][1]]
                starts = tuple(_affine_eval(a, env) for a in acc.offsets)
                return jax.lax.dynamic_slice(mem, starts, acc.block)

            tile = _run_tiles(plan, get_block)
            tile = jnp.reshape(tile, out_block.block).astype(buf.dtype)
            starts = tuple(_affine_eval(a, env) for a in out_block.offsets)
            if plan.reduce_syms:
                first = functools.reduce(
                    jnp.logical_and,
                    [env[s] == 0 for s in plan.reduce_syms])
                prev = jax.lax.dynamic_slice(buf, starts, out_block.block)
                tile = jnp.where(first, tile, prev + tile)
            return jax.lax.dynamic_update_slice(buf, tile, starts)

        init = mems[plan.out_mem]
        return jax.lax.fori_loop(0, total, body, init)

    return region_fn


def emit_pallas(g: Graph, plan: RegionPlan, interpret: bool) -> Callable:
    """Tier ``pallas``: one ``pl.pallas_call`` for the whole region, block
    specs and index maps derived from the symbolic access patterns."""
    from jax.experimental import pallas as pl

    grid_sizes = tuple(e for _, e in plan.grid)
    syms = [s for s, _ in plan.grid]
    red_axes = [i for i, (s, _) in enumerate(plan.grid)
                if s in plan.reduce_syms]

    mem_order: List[Tuple[str, int]] = []    # (compute, operand idx), flat
    for c in plan.region.computes:
        for k, src in enumerate(plan.region.bindings[c]):
            if src[0] == "mem":
                mem_order.append((c, k))

    def index_map_for(acc: BlockedAccess):
        offs = acc.block_unit_offsets()

        def index_map(*gids):
            env = dict(zip(syms, gids))
            return tuple(_affine_eval(a, env) for a in offs)

        return index_map

    in_specs = [pl.BlockSpec(plan.blocks[key].block,
                             index_map_for(plan.blocks[key]))
                for key in mem_order]
    out_spec = pl.BlockSpec(plan.out_block.block,
                            index_map_for(plan.out_block))
    out_node = g.nodes[plan.out_mem]

    def kernel(*refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        blocks = {key: r[...] for key, r in zip(mem_order, in_refs)}
        tile = _run_tiles(plan, lambda c, k: blocks[(c, k)])
        tile = jnp.reshape(tile, plan.out_block.block).astype(o_ref.dtype)
        if red_axes:
            first = functools.reduce(
                jnp.logical_and, [pl.program_id(a) == 0 for a in red_axes])

            @pl.when(first)
            def _init():
                o_ref[...] = tile

            @pl.when(jnp.logical_not(first))
            def _acc():
                o_ref[...] += tile
        else:
            o_ref[...] = tile

    def region_fn(mems: Dict[str, Any]) -> Any:
        args = [mems[plan.region.bindings[c][k][1]] for c, k in mem_order]
        return pl.pallas_call(
            kernel,
            grid=grid_sizes,
            in_specs=in_specs,
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(out_node.shape, out_node.dtype),
            interpret=interpret,
        )(*args)

    return region_fn


def emit_gather(g: Graph, region: Region) -> Callable:
    """Tier ``gather``: region-level fallback — one fused gather →
    compute-chain → scatter, addresses frozen from the access patterns."""
    idx_in: Dict[Tuple[str, int], np.ndarray] = {}
    for c in region.computes:
        if g.nodes[c].fn is None:
            raise LoweringError(
                f"compute module {c!r} has no fn body to lower")
        if len(g.out_edges(c)) > 1:
            raise LoweringError(
                f"compute module {c!r} has multiple outputs; the fused "
                "region lowering binds out0 only — use backend='jax'")
        for k, src in enumerate(region.bindings[c]):
            if src[0] == "mem":
                if src[2] is None:
                    raise LoweringError(
                        f"operand {k} of {c} has no access pattern")
                idx_in[(c, k)] = _indices(src[2], g.nodes[src[1]].shape)
    idx_out = {}
    for c, mem, access in region.outputs:
        idx_out[(c, mem)] = scatter_indices(access, g.nodes[mem].shape,
                                            where=f"{c}->{mem}")

    def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
        tiles: Dict[str, Any] = {}
        for c in region.computes:
            bound = {}
            for k, src in enumerate(region.bindings[c]):
                if src[0] == "mem":
                    flat = jnp.reshape(mems[src[1]], (-1,))
                    bound[f"in{k}"] = jnp.take(flat, idx_in[(c, k)])
                else:
                    bound[f"in{k}"] = tiles[src[1]]
            r = g.nodes[c].fn(**bound)
            tiles[c] = r["out0"] if isinstance(r, dict) else r
        outs = {}
        for c, mem, _access in region.outputs:
            target = mems[mem]
            vals = jnp.reshape(jnp.asarray(tiles[c]), (-1,)) \
                .astype(target.dtype)
            flat = jnp.reshape(target, (-1,))
            outs[mem] = jnp.reshape(flat.at[idx_out[(c, mem)]].set(vals),
                                    target.shape)
        return outs

    return region_fn


# ------------------------------------------------------------------ driver --
def lower_pallas(g: Graph, jit: bool = True, pallas_mode: str = "auto",
                 warn: Optional[Callable[[str], None]] = None,
                 emission: Optional[dict] = None
                 ) -> Callable[[Mapping[str, Any]], Dict[str, jax.Array]]:
    """Lower ``g`` through the fused-region pallas backend.

    ``pallas_mode``: ``'auto'`` emits real ``pl.pallas_call`` kernels only
    when a TPU is attached (CPU gets the ``blockloop`` jit fallback),
    ``'interpret'`` forces ``pl.pallas_call(interpret=True)`` for pallas-
    expressible regions (validation path), ``'fallback'`` never emits
    pallas calls.  ``emission`` (a dict) receives per-region provenance.
    """
    if pallas_mode not in ("auto", "interpret", "fallback"):
        raise ValueError(f"unknown pallas_mode {pallas_mode!r}")
    g.validate()
    warn = warn or (lambda msg: None)
    on_tpu = any(d.platform == "tpu" for d in jax.devices())
    use_pallas = pallas_mode == "interpret" or \
        (pallas_mode == "auto" and on_tpu)
    # 'interpret' is a validation contract: force the interpreter even on
    # TPU; 'auto' interprets only when no TPU can compile the kernel
    interpret = pallas_mode == "interpret" or not on_tpu

    regions = partition_regions(g)
    emitted: List[Tuple[Region, str, Callable]] = []
    for region in regions:
        notes: List[str] = []
        plan = plan_region(g, region, notes.append)
        for n in notes:
            warn(n)
        if plan is not None and use_pallas and plan.pallas_ok:
            tier = "pallas"
            fn = emit_pallas(g, plan, interpret=interpret)
        elif plan is not None:
            tier = "blockloop"
            fn = emit_blockloop(g, plan)
        else:
            tier = "gather"
            fn = emit_gather(g, region)
        if emission is not None:
            emission[region.name] = {
                "tier": tier,
                "pump": plan.pump if plan is not None else 1,
                "mode": region.mode,
                "grid": [list(d) for d in plan.grid] if plan else None,
                "reduce": list(plan.reduce_syms) if plan else None,
            }
        emitted.append((region, tier, fn))

    def run_fn(inputs: Mapping[str, Any]) -> Dict[str, jax.Array]:
        mems: Dict[str, jax.Array] = {}
        for n in g.nodes.values():
            if n.kind != NodeKind.MEMORY:
                continue
            if n.name in inputs:
                mems[n.name] = jnp.asarray(inputs[n.name], dtype=n.dtype)
            else:
                mems[n.name] = jnp.zeros(n.shape, dtype=n.dtype)
        for region, tier, fn in emitted:
            if tier == "gather":
                mems.update(fn(mems))
            else:
                # single-output tile emission
                out_mem = region.outputs[0][1]
                mems[out_mem] = fn(mems)
        return mems

    return jax.jit(run_fn) if jit else run_fn
