"""Pallas emission backend: fused region lowering.

Where :mod:`.lowering` schedules the transformed graph node by node (every
Reader/Writer a flat HBM gather/scatter, every adapter a value-identity
loop), this backend partitions the graph into **fused compute regions** —
the maximal ``Memory → Reader → … → Writer → Memory`` chains between memory
containers, with Sync boundaries realized by the Pallas pipeline itself —
and emits each region as *one* blocked kernel.  The paper's pump factor M is
realized structurally: as the **innermost temporal grid axis** of the
region's grid, not as an in-kernel loop.

    Mode T: the innermost grid dimension (extent G) splits into G/M wide
            transactions × M narrow beats — offsets rewritten by the exact
            substitution ``g -> g*M + _pump``.
    Mode R: the output-carrying block dimension narrows by M and the ``_pump``
            axis walks its M sub-tiles; operand blocks narrowed only where
            they share the output's grid symbol.

Each region is emitted at the highest tier its structure admits:

``pallas``     a real ``pl.pallas_call``: every access has a *block-unit*
               index map (offsets divide by the block), every compute a
               per-tile body (``meta['tile_fn']``), and the output tiling
               covers the memory.  Used on TPU; on CPU only when forced
               (``pallas_mode='interpret'``), since interpret mode exists
               for validation, not speed.
``blockloop``  a structurally identical fused ``fori_loop`` over the same
               grid with element-unit ``dynamic_slice`` blocks — the
               ``jax.jit`` fallback of the pallas emission on CPU.  Handles
               overlapping halo windows pallas block indexing cannot.
``gather``     region-level fallback: one gather → compute-chain → scatter
               per region (still fused; no per-node barriers or gearbox
               loops).  Used when computes lack a tile form (e.g. the
               dependency-carrying floyd-warshall pivot loop).

Grid dimensions absent from the output access (plus the temporal axis when
it splits one of them) are *reduction* dimensions: the emitted kernel
zero-initializes the output tile on their first visit and accumulates with
``+`` thereafter — computes marked ``meta['reduce']='add'`` return partial
contributions per grid step.

Sequential-carry regions (a compute with ``meta['carry']``, e.g. flash
attention's online softmax or the SSD inter-chunk state) get a *carry-aware*
emission: the carry axis stays the innermost sequential grid dimension, the
loop-carried state threads through the fused loop (``blockloop`` carries it
in the ``fori_loop`` state; ``pallas`` keeps it in VMEM scratch with
``pl.when`` init/finalize — exactly the hand-written flash-attention
schedule, now derived), and the region may write *multiple* output memories
(the attention tile plus its running max/denominator).  Mode T splits the
carry axis into wide transactions × M dependent beats; mode R narrows the
block dimensions labelled by the compute's ``meta['axes']`` correspondence
and runs each sub-tile through its own full sweep.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.executor import _toposort
from repro.core.ir import CarrySpec, Graph, NodeKind
from repro.core.symbolic import (Affine, BlockedAccess, blocked_access,
                                 narrow_block, split_temporal)
from repro.testing import faults

from .lowering import (LoweringError, _indices, carry_sequence_apply,
                       scatter_indices)

PUMP_SYM = "_pump"
_PASS_THROUGH = (NodeKind.STREAM, NodeKind.SYNC, NodeKind.ISSUER,
                 NodeKind.PACKER, NodeKind.READER, NodeKind.WRITER)


# ------------------------------------------------------------ region graph --
@dataclasses.dataclass
class Region:
    """One fused region: the modules between memory containers."""

    name: str
    members: List[str]                       # non-memory node names
    computes: List[str]                      # topo order
    # per compute, operand sources in edge order:
    #   ("mem", memory name, AccessPattern) | ("comp", upstream compute name)
    bindings: Dict[str, List[Tuple]]
    # (compute, memory, AccessPattern) writes out of the region
    outputs: List[Tuple[str, str, Any]]
    pump: int = 1
    mode: str = "T"


def _trace_to_source(g: Graph, edge) -> Tuple:
    """Walk an in-edge backwards through pass-through modules to its origin:
    a memory (with the reader's access pattern) or an upstream compute."""
    e = edge
    while True:
        src = g.nodes[e.src]
        if src.kind == NodeKind.MEMORY:
            return ("mem", src.name, e.access)
        if src.kind == NodeKind.COMPUTE:
            return ("comp", src.name)
        ins = g.in_edges(src.name)
        if len(ins) != 1:
            raise LoweringError(
                f"pass-through module {src.name} has {len(ins)} inputs")
        e = ins[0]


def _trace_to_sink(g: Graph, edge) -> Optional[Tuple]:
    """Walk an out-edge forward to a memory write; None when it feeds a
    downstream compute inside the region instead."""
    e = edge
    while True:
        dst = g.nodes[e.dst]
        if dst.kind == NodeKind.MEMORY:
            return (dst.name, e.access)
        if dst.kind == NodeKind.COMPUTE:
            return None
        outs = g.out_edges(dst.name)
        if len(outs) != 1:
            raise LoweringError(
                f"pass-through module {dst.name} has {len(outs)} outputs")
        e = outs[0]


def partition_regions(g: Graph) -> List[Region]:
    """Split ``g`` into fused regions: connected components of the module/
    stream subgraph, with memory containers as the region boundaries."""
    # union-find over non-memory nodes
    parent: Dict[str, str] = {n.name: n.name for n in g.nodes.values()
                              if n.kind != NodeKind.MEMORY}

    def root(n: str) -> str:
        while parent[n] != n:
            parent[n] = parent[parent[n]]
            n = parent[n]
        return n

    for e in g.edges:
        if e.src in parent and e.dst in parent:
            parent[root(e.src)] = root(e.dst)

    groups: Dict[str, List[str]] = {}
    for n in parent:
        groups.setdefault(root(n), []).append(n)

    order = _toposort(g)
    pos = {n: i for i, n in enumerate(order)}
    regions = []
    for members in groups.values():
        members.sort(key=pos.__getitem__)
        computes = [n for n in members
                    if g.nodes[n].kind == NodeKind.COMPUTE]
        if not computes:
            continue   # dangling adapters with no compute: nothing to emit
        bindings: Dict[str, List[Tuple]] = {}
        outputs: List[Tuple[str, str, Any]] = []
        for c in computes:
            bindings[c] = [_trace_to_source(g, e) for e in g.in_edges(c)]
            for e in g.out_edges(c):
                sink = _trace_to_sink(g, e)
                if sink is not None:
                    outputs.append((c, sink[0], sink[1]))
        pump = max((g.nodes[c].pump for c in computes), default=1)
        mode = next((g.nodes[c].meta.get("pump_mode") for c in computes
                     if g.nodes[c].meta.get("pump_mode")), "T")
        regions.append(Region(name=computes[0], members=members,
                              computes=computes, bindings=bindings,
                              outputs=outputs, pump=pump, mode=mode))

    # schedule regions by memory dataflow, not by node position: a region
    # reading memory m must run after every region writing m (the node-level
    # toposort guarantees this order exists)
    writers: Dict[str, List[int]] = {}
    for i, r in enumerate(regions):
        for _c, mem, _a in r.outputs:
            writers.setdefault(mem, []).append(i)
    deps: Dict[int, set] = {i: set() for i in range(len(regions))}
    for i, r in enumerate(regions):
        for srcs in r.bindings.values():
            for src in srcs:
                if src[0] == "mem":
                    deps[i].update(j for j in writers.get(src[1], ())
                                   if j != i)
    ordered: List[Region] = []
    done: set = set()
    while len(done) < len(regions):
        ready = sorted(
            (i for i in deps if i not in done and deps[i] <= done),
            key=lambda i: pos[regions[i].computes[0]])
        if not ready:   # pragma: no cover - node toposort forbids cycles
            raise LoweringError("cyclic memory dependency between regions")
        for i in ready:
            done.add(i)
            ordered.append(regions[i])
    return ordered


# ------------------------------------------------------------- region plan --
@dataclasses.dataclass
class RegionPlan:
    """A tile-emittable region: unified grid + blocked views per operand."""

    region: Region
    grid: Tuple[Tuple[str, int], ...]        # outermost → innermost
    reduce_syms: Tuple[str, ...]             # grid syms absent from output
    blocks: Dict[Tuple[str, int], BlockedAccess]   # (compute, operand idx)
    # (compute, memory, blocked view) per region output, primary first
    outputs: List[Tuple[str, str, BlockedAccess]]
    tile_fns: Dict[str, Callable]
    pump: int = 1                            # realized temporal factor
    mode: str = "T"
    pallas_ok: bool = True                   # block-unit maps + full coverage
    # sequential-carry emission (single-compute regions only)
    carry: Optional[CarrySpec] = None
    carry_syms: Tuple[str, ...] = ()         # carry axis (+ mode-T _pump)
    carry_narrow: Dict[int, Tuple[int, int]] = \
        dataclasses.field(default_factory=dict)   # state idx -> (dim, M)
    outer_syms: Tuple[str, ...] = ()         # step syms excluding the axis

    # single-output convenience views (primary output)
    @property
    def out_compute(self) -> str:
        return self.outputs[0][0]

    @property
    def out_mem(self) -> str:
        return self.outputs[0][1]

    @property
    def out_block(self) -> BlockedAccess:
        return self.outputs[0][2]


def _tile_fn_of(g: Graph, name: str) -> Optional[Callable]:
    n = g.nodes[name]
    fn = n.meta.get("tile_fn")
    if fn is None and n.meta.get("elementwise"):
        fn = n.fn
    return fn


def plan_region(g: Graph, region: Region,
                warn: Callable[[str], None]) -> Optional[RegionPlan]:
    """Derive the blocked emission plan for a region, or None when the
    region must fall back to gather emission (reason passed to ``warn``)."""
    carry: Optional[CarrySpec] = None
    if len(region.computes) == 1:
        carry = g.nodes[region.computes[0]].meta.get("carry")
    elif any(g.nodes[c].meta.get("carry") for c in region.computes):
        warn(f"region {region.name}: carry compute in a multi-compute "
             "region; using gather fallback")
        return None
    multi_out = len(region.outputs) > 1
    if multi_out and carry is None and len(region.computes) > 1:
        warn(f"region {region.name}: {len(region.outputs)} output memories "
             "from a multi-compute region; tile emission needs a single "
             "compute (or a carry compute) — using gather fallback")
        return None
    if any(a is None for _c, _m, a in region.outputs):
        warn(f"region {region.name}: output access unknown")
        return None

    tile_fns = {}
    for c in region.computes:
        fn = _tile_fn_of(g, c)
        if fn is None and not (carry is not None and c == region.computes[0]):
            warn(f"region {region.name}: compute {c} has no per-tile body "
                 "(meta['tile_fn']); using gather fallback")
            return None
        if not region.bindings[c]:
            warn(f"region {region.name}: compute {c} has no operands")
            return None
        tile_fns[c] = fn

    def step_syms(c: str) -> Tuple[str, ...]:
        dom = g.nodes[c].domain
        return dom.symbols if dom is not None else ()

    outputs: List[Tuple[str, str, BlockedAccess]] = []
    for c, mem, acc in region.outputs:
        ba = blocked_access(acc, g.nodes[mem].shape, protect=step_syms(c))
        if ba is None:
            warn(f"region {region.name}: output access to {mem} is not "
                 "block-affine")
            return None
        outputs.append((c, mem, ba))
    out_block = outputs[0][2]

    blocks: Dict[Tuple[str, int], BlockedAccess] = {}
    extents: Dict[str, int] = dict(out_block.grid)
    extra_syms: List[str] = []
    for c in region.computes:
        for k, src in enumerate(region.bindings[c]):
            if src[0] != "mem":
                continue
            if src[2] is None:
                warn(f"region {region.name}: operand {src[1]} of {c} has "
                     "no access pattern")
                return None
            acc = blocked_access(src[2], g.nodes[src[1]].shape,
                                 protect=step_syms(c))
            if acc is None:
                warn(f"region {region.name}: operand {src[1]} of {c} is not "
                     "block-affine")
                return None
            for s, e in acc.grid:
                if extents.setdefault(s, e) != e:
                    warn(f"region {region.name}: grid extent mismatch on "
                         f"{s}: {extents[s]} vs {e}")
                    return None
                if s not in dict(out_block.grid) and s not in extra_syms:
                    extra_syms.append(s)
            blocks[(c, k)] = acc

    # canonical grid: output order first, extra symbols innermost
    grid = tuple(out_block.grid) + tuple((s, extents[s]) for s in extra_syms)
    reduce_syms = tuple(extra_syms)
    carry_syms: Tuple[str, ...] = ()
    outer_syms: Tuple[str, ...] = ()
    if multi_out and carry is None:
        # multi-output map (e.g. the SSD decode step's y + new state): every
        # output must be written exactly once per grid point, so reduction
        # symbols and grid mismatches between the outputs both disqualify
        # tile emission
        if extra_syms:
            warn(f"region {region.name}: multi-output region with reduction "
                 f"symbols {extra_syms}; using gather fallback")
            return None
        for _c, mem, ba in outputs[1:]:
            if tuple(ba.grid) != tuple(out_block.grid):
                warn(f"region {region.name}: output {mem} grid "
                     f"{ba.grid_symbols} differs from the region grid "
                     f"{out_block.grid_symbols}; using gather fallback")
                return None
    if carry is not None:
        # mixed carry+reduction first: naming the extra reduction symbols is
        # strictly more actionable than the generic innermost-axis message
        # (a serving-path regression to the gather tier must be diagnosable
        # from PipelineReport.warnings alone)
        mixed = [s for s in extra_syms if s != carry.axis]
        if mixed:
            warn(f"region {region.name}: mixed carry+reduction grid — "
                 f"carry axis {carry.axis!r} with extra reduction symbols "
                 f"{mixed}; using gather fallback")
            return None
        if not grid or grid[-1][0] != carry.axis:
            warn(f"region {region.name}: carry axis {carry.axis!r} is not "
                 "the innermost grid dimension; using gather fallback")
            return None
        carry_syms = (carry.axis,)
        reduce_syms = ()
        dom = g.nodes[region.computes[0]].domain
        outer_syms = tuple(s for s in dom.symbols if s != carry.axis)

    # full coverage is a *pre-temporal* property (the temporal rewrite
    # below moves extents between grid and block but never the product)
    covered = all(ba.covers(g.nodes[mem].shape) for _c, mem, ba in outputs)
    plan = RegionPlan(region=region, grid=grid, reduce_syms=reduce_syms,
                      blocks=blocks, outputs=outputs, tile_fns=tile_fns,
                      mode=region.mode, carry=carry, carry_syms=carry_syms,
                      outer_syms=outer_syms)
    _apply_temporal(g, plan, region.pump, warn)
    plan.pallas_ok = covered and _block_unit_ok(plan)
    return plan


def _append_pump(plan: RegionPlan, factor: int) -> None:
    """Insert the mode-R ``_pump`` grid axis.  For carry regions it goes
    *outside* the carry symbols (each sub-tile runs its own full sweep —
    interleaving sub-tiles inside a sweep would tear the carried state);
    otherwise innermost, walking the output sub-tiles per grid step."""
    if plan.carry_syms:
        idx0 = min(i for i, (s, _e) in enumerate(plan.grid)
                   if s in plan.carry_syms)
        plan.grid = plan.grid[:idx0] + ((PUMP_SYM, factor),) \
            + plan.grid[idx0:]
    else:
        plan.grid = tuple(plan.grid) + ((PUMP_SYM, factor),)


def _narrow_labelled(g: Graph, plan: RegionPlan, factor: int,
                     warn: Callable[[str], None]) -> bool:
    """Mode-R narrowing via the compute's declared axis correspondence
    (``meta['axes']``): narrow every block dimension labelled with the
    compute's ``narrow`` axis — output(s), operands and carry state alike.
    Exact by construction: a dimension is narrowed because the compute says
    it corresponds, not because its size or grid symbol happens to match.
    """
    comp = plan.out_compute
    axes = g.nodes[comp].meta.get("axes")
    name = axes.get("narrow") if axes else None
    if not name:
        return False
    out_maps, in_maps = axes.get("outs", ()), axes.get("ins", ())
    carry_maps = axes.get("carry", ())

    def dim_of(mapping) -> Optional[int]:
        hits = [d for d, nm in mapping.items() if nm == name]
        return hits[0] if hits else None

    d0 = dim_of(out_maps[0]) if out_maps else None
    if d0 is None or plan.outputs[0][2].block[d0] % factor:
        warn(f"region {plan.region.name}: mode-R axis {name!r} not "
             f"divisible by pump factor {factor}; temporal axis dropped")
        return True     # handled (by dropping), do not fall back
    new_outs = []
    for oi, (c, mem, ba) in enumerate(plan.outputs):
        d = dim_of(out_maps[oi]) if oi < len(out_maps) else None
        new_outs.append((c, mem, narrow_block(ba, d, factor)
                         if d is not None else ba))
    plan.outputs = new_outs
    narrowed = {}
    for (c, k), acc in plan.blocks.items():
        d = dim_of(in_maps[k]) if c == comp and k < len(in_maps) else None
        narrowed[(c, k)] = narrow_block(acc, d, factor) \
            if d is not None else acc
    plan.blocks = narrowed
    for si, mapping in enumerate(carry_maps):
        d = dim_of(mapping)
        if d is not None:
            plan.carry_narrow[si] = (d, factor)
    _append_pump(plan, factor)
    plan.pump = factor
    return True


def _apply_temporal(g: Graph, plan: RegionPlan, factor: int,
                    warn: Callable[[str], None]) -> None:
    """Realize pump factor M as the innermost ``_pump`` grid axis."""
    if factor <= 1:
        return
    if plan.mode == "T":
        if not plan.grid:
            warn(f"region {plan.region.name}: no grid dimension to pump")
            return
        sym, ext = plan.grid[-1]
        if ext % factor:
            warn(f"region {plan.region.name}: innermost grid extent {ext} "
                 f"({sym}) not divisible by pump factor {factor}; temporal "
                 "axis dropped")
            return
        try:
            plan.blocks = {k: split_temporal(a, sym, factor)
                           for k, a in plan.blocks.items()}
            plan.outputs = [(c, mem, split_temporal(ba, sym, factor))
                            for c, mem, ba in plan.outputs]
        except ValueError as err:    # e.g. a group-indexed (table) symbol
            warn(f"region {plan.region.name}: cannot split {sym}: {err}; "
                 "temporal axis dropped")
            return
        grid = [(s, e // factor if s == sym else e) for s, e in plan.grid]
        plan.grid = tuple(grid) + ((PUMP_SYM, factor),)
        if sym in plan.reduce_syms:
            plan.reduce_syms = plan.reduce_syms + (PUMP_SYM,)
        if sym in plan.carry_syms:
            # the M beats of one wide transaction continue the sweep
            plan.carry_syms = plan.carry_syms + (PUMP_SYM,)
        plan.pump = factor
        return
    # ---- mode R: narrow the output-carrying block dimension(s) -------------
    if _narrow_labelled(g, plan, factor, warn):
        return
    if plan.carry is not None:
        warn(f"region {plan.region.name}: carry region without a mode-R "
             "axis correspondence (meta['axes']); temporal axis dropped")
        return
    out = plan.out_block
    d_out = max((d for d, b in enumerate(out.block) if b > 1),
                default=None)
    if d_out is None or out.block[d_out] % factor:
        warn(f"region {plan.region.name}: mode-R output block not "
             f"divisible by pump factor {factor}; temporal axis dropped")
        return
    b_wide = out.block[d_out]
    dep = out.offsets[d_out]
    c0, mem0, _ = plan.outputs[0]
    plan.outputs = [(c0, mem0, narrow_block(out, d_out, factor))]
    narrowed = {}
    for key, acc in plan.blocks.items():
        new = acc
        for d in reversed(range(len(acc.block))):
            # dataflow correspondence: the operand dimension walks the
            # same offset expression as the output dimension being
            # narrowed (symbol-set matching is not enough — see the
            # mode-R regression tests)
            if acc.block[d] == b_wide and acc.offsets[d] == dep:
                new = narrow_block(acc, d, factor)
                break
        narrowed[key] = new
    plan.blocks = narrowed
    _append_pump(plan, factor)
    plan.pump = factor


def _block_unit_ok(plan: RegionPlan) -> bool:
    """True when every access (operands and outputs) has a block-unit index
    map — the post-temporal half of pallas expressibility."""
    return all(ba.block_unit_offsets() is not None
               for _c, _m, ba in plan.outputs) \
        and all(a.block_unit_offsets() is not None
                for a in plan.blocks.values())


# ---------------------------------------------------------------- emission --
def _affine_eval(a: Affine, env: Mapping[str, Any]):
    out = a.const
    for s, c in a.terms:
        out = out + c * env[s]
    for s, t in a.tables:
        # group-indexed lookup: static table, traced (grid) index
        out = out + jnp.asarray(np.asarray(t, dtype=np.int32))[env[s]]
    return out


def _carry_predicates(plan: RegionPlan, env: Mapping[str, Any]):
    """(first, last, step, idx-kwargs) for one grid point of a carry plan."""
    exts = dict(plan.grid)
    first = functools.reduce(
        jnp.logical_and, [env[s] == 0 for s in plan.carry_syms])
    last = functools.reduce(
        jnp.logical_and,
        [env[s] == exts[s] - 1 for s in plan.carry_syms])
    step = 0
    for s in plan.carry_syms:
        step = step * exts[s] + env[s]
    kwargs = {}
    if plan.carry.pass_idx:
        kwargs["idx"] = dict(
            step=step,
            outer=tuple(env[s] for s in plan.outer_syms),
            pump=env.get(PUMP_SYM, 0) if PUMP_SYM not in plan.carry_syms
            else 0)
    return first, last, kwargs


def _run_tiles(plan: RegionPlan, get_block: Callable[[str, int], Any]) -> Any:
    """Evaluate the region's compute chain for one grid point;
    ``get_block(compute, operand_idx)`` supplies memory operand blocks."""
    tiles: Dict[str, Any] = {}
    for c in plan.region.computes:
        bound = {}
        for k, src in enumerate(plan.region.bindings[c]):
            if src[0] == "mem":
                bound[f"in{k}"] = get_block(c, k)
            else:
                bound[f"in{k}"] = tiles[src[1]]
        r = plan.tile_fns[c](**bound)
        tiles[c] = r["out0"] if isinstance(r, dict) else r
    return tiles[plan.out_compute]


def emit_blockloop(g: Graph, plan: RegionPlan) -> Callable:
    """Tier ``blockloop``: the pallas schedule as a fused ``fori_loop`` with
    element-unit ``dynamic_slice`` blocks — the jit fallback on CPU.  Carry
    plans thread the loop-carried state through the ``fori_loop`` carry and
    may write several output memories; region functions return
    ``{memory name: array}``."""
    grid = plan.grid
    sizes = [e for _, e in grid]
    total = int(np.prod(sizes)) if sizes else 1

    def unflatten(step) -> Dict[str, Any]:
        env: Dict[str, Any] = {}
        rem = step
        for (sym, ext) in reversed(grid):
            env[sym] = rem % ext
            rem = rem // ext
        return env

    def make_get_block(mems, env):
        def get_block(c, k):
            acc = plan.blocks[(c, k)]
            mem = mems[plan.region.bindings[c][k][1]]
            starts = tuple(_affine_eval(a, env) for a in acc.offsets)
            return jax.lax.dynamic_slice(mem, starts, acc.block)
        return get_block

    def write_block(buf, ba: BlockedAccess, env, tile):
        tile = jnp.reshape(tile, ba.block).astype(buf.dtype)
        starts = tuple(_affine_eval(a, env) for a in ba.offsets)
        return jax.lax.dynamic_update_slice(buf, tile, starts)

    if plan.carry is not None:
        spec = plan.carry
        mems_order = [mem for _c, mem, _ba in plan.outputs]
        n_step_out = spec.n_step_outs(len(plan.outputs))

        def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
            init_state = tuple(
                jnp.asarray(a)
                for a in spec.init_arrays(jnp, narrow=plan.carry_narrow))
            bufs0 = tuple(mems[m] for m in mems_order)

            def body(step, st):
                carry, bufs = st
                env = unflatten(step)
                first, last, kwargs = _carry_predicates(plan, env)
                carry = tuple(jnp.where(first, ini, cur)
                              for ini, cur in zip(init_state, carry))
                get_block = make_get_block(mems, env)
                blocks = [get_block(plan.out_compute, k)
                          for k in range(
                              len(plan.region.bindings[plan.out_compute]))]
                carry2, souts = spec.step_fn(carry, *blocks, **kwargs)
                new_bufs = list(bufs)
                for k in range(n_step_out):
                    _c, _m, ba = plan.outputs[k]
                    new_bufs[k] = write_block(bufs[k], ba, env,
                                              souts[f"out{k}"])
                if spec.final_fn is not None:
                    fouts = spec.final_fn(carry2)
                    for k in range(n_step_out, len(plan.outputs)):
                        _c, _m, ba = plan.outputs[k]
                        new_bufs[k] = jnp.where(
                            last,
                            write_block(bufs[k], ba, env, fouts[f"out{k}"]),
                            bufs[k])
                return carry2, tuple(new_bufs)

            _carry, bufs = jax.lax.fori_loop(0, total, body,
                                             (init_state, bufs0))
            return dict(zip(mems_order, bufs))

        return region_fn

    if len(plan.outputs) > 1:
        # multi-output map: one tile_fn call per grid point writes every
        # output block (no reduction symbols by plan construction)
        mems_order = [mem for _c, mem, _ba in plan.outputs]
        comp = plan.out_compute
        n_ops = len(plan.region.bindings[comp])

        def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
            def body(step, bufs):
                env = unflatten(step)
                get_block = make_get_block(mems, env)
                r = plan.tile_fns[comp](
                    **{f"in{k}": get_block(comp, k) for k in range(n_ops)})
                return tuple(
                    write_block(buf, ba, env, r[f"out{k}"])
                    for k, (buf, (_c, _m, ba))
                    in enumerate(zip(bufs, plan.outputs)))

            bufs = jax.lax.fori_loop(0, total, body,
                                     tuple(mems[m] for m in mems_order))
            return dict(zip(mems_order, bufs))

        return region_fn

    out_mem, out_block = plan.out_mem, plan.out_block

    def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
        def body(step, buf):
            env = unflatten(step)
            tile = _run_tiles(plan, make_get_block(mems, env))
            tile = jnp.reshape(tile, out_block.block).astype(buf.dtype)
            starts = tuple(_affine_eval(a, env) for a in out_block.offsets)
            if plan.reduce_syms:
                first = functools.reduce(
                    jnp.logical_and,
                    [env[s] == 0 for s in plan.reduce_syms])
                prev = jax.lax.dynamic_slice(buf, starts, out_block.block)
                tile = jnp.where(first, tile, prev + tile)
            return jax.lax.dynamic_update_slice(buf, tile, starts)

        init = mems[out_mem]
        return {out_mem: jax.lax.fori_loop(0, total, body, init)}

    return region_fn


def emit_pallas(g: Graph, plan: RegionPlan, interpret: bool) -> Callable:
    """Tier ``pallas``: one ``pl.pallas_call`` for the whole region, block
    specs and index maps derived from the symbolic access patterns.  Carry
    plans keep their state in VMEM scratch with ``pl.when``-gated sweep
    init/finalize — the hand-written flash-attention schedule, derived."""
    from jax.experimental import pallas as pl

    grid_sizes = tuple(e for _, e in plan.grid)
    syms = [s for s, _ in plan.grid]
    red_axes = [i for i, (s, _) in enumerate(plan.grid)
                if s in plan.reduce_syms]

    mem_order: List[Tuple[str, int]] = []    # (compute, operand idx), flat
    for c in plan.region.computes:
        for k, src in enumerate(plan.region.bindings[c]):
            if src[0] == "mem":
                mem_order.append((c, k))

    def index_map_for(acc: BlockedAccess):
        offs = acc.block_unit_offsets()

        def eval_scalar(a: Affine, env):
            # pallas index maps must not capture constant arrays, so
            # group-indexed tables unroll to a select-sum over scalar
            # comparisons instead of a gather (tables are small: per-head
            # or per-tile ids)
            out = a.const
            for s, c in a.terms:
                out = out + c * env[s]
            for s, t in a.tables:
                for j, v in enumerate(t):
                    if v:
                        out = out + v * (env[s] == j)
            return out

        def index_map(*gids):
            env = dict(zip(syms, gids))
            return tuple(eval_scalar(a, env) for a in offs)

        return index_map

    in_specs = [pl.BlockSpec(plan.blocks[key].block,
                             index_map_for(plan.blocks[key]))
                for key in mem_order]
    out_specs = [pl.BlockSpec(ba.block, index_map_for(ba))
                 for _c, _m, ba in plan.outputs]
    out_shapes = [jax.ShapeDtypeStruct(g.nodes[mem].shape,
                                       g.nodes[mem].dtype)
                  for _c, mem, _ba in plan.outputs]
    mems_order = [mem for _c, mem, _ba in plan.outputs]
    n_out = len(plan.outputs)

    if plan.carry is not None:
        from jax.experimental.pallas import tpu as pltpu

        spec = plan.carry
        n_step_out = spec.n_step_outs(n_out)
        state_shapes = []
        for i, entry in enumerate(spec.state):
            shape = entry[0]
            if i in plan.carry_narrow:
                d, factor = plan.carry_narrow[i]
                shape = tuple(s // factor if j == d else s
                              for j, s in enumerate(shape))
            state_shapes.append((shape, entry[1]))
        scratch_shapes = [pltpu.VMEM(shape, jnp.dtype(dt))
                          for shape, dt in state_shapes]
        # scalar fills, not captured init arrays: a pallas kernel body must
        # not close over constant arrays
        fills = [float(entry[2]) if len(entry) > 2 else 0.0
                 for entry in spec.state]

        def kernel(*refs):
            in_refs = refs[:len(mem_order)]
            out_refs = refs[len(mem_order):len(mem_order) + n_out]
            st_refs = refs[len(mem_order) + n_out:]
            env = {s: pl.program_id(i) for i, s in enumerate(syms)}
            first, last, kwargs = _carry_predicates(plan, env)

            @pl.when(first)
            def _init():
                for ref, fill in zip(st_refs, fills):
                    ref[...] = jnp.full(ref.shape, fill, ref.dtype)

            blocks = [r[...] for r in in_refs]
            carry = tuple(r[...] for r in st_refs)
            carry2, souts = spec.step_fn(carry, *blocks, **kwargs)
            for ref, val in zip(st_refs, carry2):
                ref[...] = val
            for k in range(n_step_out):
                out_refs[k][...] = jnp.reshape(
                    souts[f"out{k}"],
                    plan.outputs[k][2].block).astype(out_refs[k].dtype)
            if spec.final_fn is not None:
                fouts = spec.final_fn(carry2)

                @pl.when(last)
                def _finish():
                    for k in range(n_step_out, n_out):
                        out_refs[k][...] = jnp.reshape(
                            fouts[f"out{k}"],
                            plan.outputs[k][2].block).astype(
                                out_refs[k].dtype)

        def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
            args = [mems[plan.region.bindings[c][k][1]] for c, k in mem_order]
            outs = pl.pallas_call(
                kernel,
                grid=grid_sizes,
                in_specs=in_specs,
                out_specs=out_specs,
                out_shape=out_shapes,
                scratch_shapes=scratch_shapes,
                interpret=interpret,
            )(*args)
            return dict(zip(mems_order, outs))

        return region_fn

    if n_out > 1:
        # multi-output map: no reduction symbols (plan construction), every
        # out_ref written per grid point
        comp = plan.out_compute
        n_ops = len(plan.region.bindings[comp])

        def kernel(*refs):
            in_refs, out_refs = refs[:len(mem_order)], refs[len(mem_order):]
            blocks = {key: r[...] for key, r in zip(mem_order, in_refs)}
            r = plan.tile_fns[comp](
                **{f"in{k}": blocks[(comp, k)] for k in range(n_ops)})
            for k, ref in enumerate(out_refs):
                ref[...] = jnp.reshape(
                    r[f"out{k}"], plan.outputs[k][2].block).astype(ref.dtype)

        def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
            args = [mems[plan.region.bindings[c][k][1]] for c, k in mem_order]
            outs = pl.pallas_call(
                kernel,
                grid=grid_sizes,
                in_specs=in_specs,
                out_specs=out_specs,
                out_shape=out_shapes,
                interpret=interpret,
            )(*args)
            return dict(zip(mems_order, outs))

        return region_fn

    def kernel(*refs):
        in_refs, o_ref = refs[:-1], refs[-1]
        blocks = {key: r[...] for key, r in zip(mem_order, in_refs)}
        tile = _run_tiles(plan, lambda c, k: blocks[(c, k)])
        tile = jnp.reshape(tile, plan.out_block.block).astype(o_ref.dtype)
        if red_axes:
            first = functools.reduce(
                jnp.logical_and, [pl.program_id(a) == 0 for a in red_axes])

            @pl.when(first)
            def _init():
                o_ref[...] = tile

            @pl.when(jnp.logical_not(first))
            def _acc():
                o_ref[...] += tile
        else:
            o_ref[...] = tile

    def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
        args = [mems[plan.region.bindings[c][k][1]] for c, k in mem_order]
        out = pl.pallas_call(
            kernel,
            grid=grid_sizes,
            in_specs=in_specs,
            out_specs=out_specs[0],
            out_shape=out_shapes[0],
            interpret=interpret,
        )(*args)
        return {mems_order[0]: out}

    return region_fn


def emit_gather(g: Graph, region: Region) -> Callable:
    """Tier ``gather``: region-level fallback — one fused gather →
    compute-chain → scatter, addresses frozen from the access patterns.
    Multi-output computes scatter each named output; carry computes run
    the ``fori_loop`` sequence form shared with the per-node lowering."""
    carry_fns: Dict[str, Callable] = {}
    idx_in: Dict[Tuple[str, int], np.ndarray] = {}
    for c in region.computes:
        if g.nodes[c].meta.get("carry") is not None:
            carry_fns[c] = carry_sequence_apply(g, g.nodes[c])
        elif g.nodes[c].fn is None:
            raise LoweringError(
                f"compute module {c!r} has no fn body to lower")
        for k, src in enumerate(region.bindings[c]):
            if src[0] == "mem":
                if src[2] is None:
                    raise LoweringError(
                        f"operand {k} of {c} has no access pattern")
                idx_in[(c, k)] = _indices(src[2], g.nodes[src[1]].shape)
    # per compute: (out-edge position, sink memory, scatter indices) —
    # keyed by edge position so output name binding (out0, out1, ...)
    # matches the executor's edge-order convention
    idx_out: Dict[str, List[Tuple[int, str, np.ndarray]]] = {}
    for c in region.computes:
        for kpos, e in enumerate(g.out_edges(c)):
            sunk = _trace_to_sink(g, e)
            if sunk is not None:
                mem, access = sunk
                idx_out.setdefault(c, []).append(
                    (kpos, mem,
                     scatter_indices(access, g.nodes[mem].shape,
                                     where=f"{c}->{mem}")))

    def region_fn(mems: Dict[str, Any]) -> Dict[str, Any]:
        tiles: Dict[str, Any] = {}
        results: Dict[str, Dict[str, Any]] = {}
        for c in region.computes:
            bound = {}
            for k, src in enumerate(region.bindings[c]):
                if src[0] == "mem":
                    flat = jnp.reshape(mems[src[1]], (-1,))
                    bound[f"in{k}"] = jnp.take(flat, idx_in[(c, k)])
                else:
                    bound[f"in{k}"] = tiles[src[1]]
            if c in carry_fns:
                r = carry_fns[c](bound)
            else:
                r = g.nodes[c].fn(**bound)
            if not isinstance(r, dict):
                r = {"out0": r}
            results[c] = r
            tiles[c] = r["out0"]
        outs = {}
        for c, sinks in idx_out.items():
            for kpos, mem, idx in sinks:
                target = outs.get(mem, mems[mem])
                vals = jnp.reshape(jnp.asarray(results[c][f"out{kpos}"]),
                                   (-1,)).astype(target.dtype)
                flat = jnp.reshape(target, (-1,))
                outs[mem] = jnp.reshape(flat.at[idx].set(vals), target.shape)
        return outs

    return region_fn


# ------------------------------------------------------------------ driver --
def lower_pallas(g: Graph, jit: bool = True, pallas_mode: str = "auto",
                 warn: Optional[Callable[[str], None]] = None,
                 emission: Optional[dict] = None
                 ) -> Callable[[Mapping[str, Any]], Dict[str, jax.Array]]:
    """Lower ``g`` through the fused-region pallas backend.

    ``pallas_mode``: ``'auto'`` emits real ``pl.pallas_call`` kernels only
    when a TPU is attached (CPU gets the ``blockloop`` jit fallback),
    ``'interpret'`` forces ``pl.pallas_call(interpret=True)`` for pallas-
    expressible regions (validation path), ``'fallback'`` never emits
    pallas calls.  ``emission`` (a dict) receives per-region provenance.
    """
    if pallas_mode not in ("auto", "interpret", "fallback"):
        raise ValueError(f"unknown pallas_mode {pallas_mode!r}")
    faults.check("emission.lower", graph=g.name)
    g.validate()
    warn = warn or (lambda msg: None)
    on_tpu = any(d.platform == "tpu" for d in jax.devices())
    use_pallas = pallas_mode == "interpret" or \
        (pallas_mode == "auto" and on_tpu)
    # 'interpret' is a validation contract: force the interpreter even on
    # TPU; 'auto' interprets only when no TPU can compile the kernel
    interpret = pallas_mode == "interpret" or not on_tpu

    regions = partition_regions(g)
    emitted: List[Tuple[Region, str, Callable]] = []
    for region in regions:
        notes: List[str] = []
        plan = plan_region(g, region, notes.append)
        for n in notes:
            warn(n)
        if plan is not None and use_pallas and plan.pallas_ok:
            tier = "pallas"
            fn = emit_pallas(g, plan, interpret=interpret)
        elif plan is not None:
            tier = "carryloop" if plan.carry is not None else "blockloop"
            fn = emit_blockloop(g, plan)
        else:
            tier = "gather"
            fn = emit_gather(g, region)
        # per-region tier decision is a first-class observable: the tier mix
        # (how much of a model emits at which tier) lands in the metrics
        # snapshot, and a downgrade carries its reason — a serving-path
        # regression to the slow tier must be attributable from telemetry
        # alone, not only from a PipelineReport someone kept around
        obs.count(f"emission.tier.{tier}", graph=g.name, region=region.name)
        if notes:
            obs.count("emission.degraded", graph=g.name,
                      region=region.name, tier=tier, why="; ".join(notes))
        if emission is not None:
            emission[region.name] = {
                "tier": tier,
                "pump": plan.pump if plan is not None else 1,
                "mode": region.mode,
                "grid": [list(d) for d in plan.grid] if plan else None,
                "reduce": list(plan.reduce_syms) if plan else None,
                "carry": list(plan.carry_syms) if plan else None,
                "outputs": [mem for _c, mem, _a in region.outputs],
                # degradation provenance: why this region did not emit at a
                # higher tier (mirrors the PipelineReport warning strings)
                "why": list(notes),
            }
        emitted.append((region, tier, fn))

    def run_fn(inputs: Mapping[str, Any]) -> Dict[str, jax.Array]:
        mems: Dict[str, jax.Array] = {}
        for n in g.nodes.values():
            if n.kind != NodeKind.MEMORY:
                continue
            if n.name in inputs:
                mems[n.name] = jnp.asarray(inputs[n.name], dtype=n.dtype)
            else:
                mems[n.name] = jnp.zeros(n.shape, dtype=n.dtype)
        for _region, _tier, fn in emitted:
            mems.update(fn(mems))
        return mems

    # chaos seam: lets tests simulate a compiled kernel that runs but
    # produces garbage (NaNs) or dies at execution time — a no-op (the
    # original run_fn) unless fault rules are installed at lowering time
    run_fn = faults.wrap("emission.exec", run_fn, graph=g.name)
    return jax.jit(run_fn) if jit else run_fn
