"""``repro.compiler`` — pass pipeline, lowering backends, persistent cache.

The back half of the paper's §3 workflow: where ``repro.core`` defines the
IR and the two rewrite rules, this package *drives* them as registered passes
(:mod:`.passes`, :mod:`.pipeline`), compiles the transformed graph to an
executable jax callable (per-node :mod:`.lowering` or the fused-region
Pallas emission in :mod:`.pallas_backend`), and memoizes both the autotune
decision and the compiled kernel across calls and processes (:mod:`.cache`).

    from repro import compiler
    kern = compiler.compile(graph, factor=2, mode="T", backend="pallas")
    out = kern({"x": x, "y": y})          # == repro.core.executor.run(...)
    kern.report.summary()                 # pass provenance + cache state

``compile`` is served in O(1) for repeated requests: an in-process memo
returns the compiled kernel outright, and the JSON disk cache replays the
pipeline plan (chosen pump factor — including a measured-runtime autotune
winner from ``autotune='measure'``) in fresh processes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.ir import Graph, NodeKind, PumpSpec
from repro.core.pump_plan import VMEM_BYTES, plan_kernel_pump
from repro.testing import faults

from .cache import (CompileCache, QuarantinePolicy, default_cache,
                    graph_fingerprint, request_key)
from .lowering import CompiledKernel, LoweringError, lower
from .pallas_backend import lower_pallas, partition_regions
from .passes import (PASS_REGISTRY, FifoDepthPass, FusionReport, GraphPass,
                     MultipumpPass, StreamFusionPass, StreamingPass,
                     make_pass, register_pass)
from .pipeline import PassRecord, Pipeline, PipelineReport
from .registry import (BucketPolicy, PlanRegistry, default_registry,
                       set_default_registry)

# The formal degradation ladder (docs/robustness.md).  The first three rungs
# are emission tiers *inside* the pallas backend — lower_pallas already picks
# per region and falls through pallas → blockloop/carryloop → gather when a
# region can't be planned.  The cross-layer rungs are what this module and
# the plan registry own: a pallas-backend failure degrades to the per-node
# jax lowering (compile_degraded), and a jax failure degrades to the plain-
# jnp direct functions the registry wrappers / engine carry.  Every step
# down is counted (``degrade.compile`` / ``registry.fallback`` /
# ``engine.degraded``) with the reason, never silent.
DEGRADATION_LADDER = ("pallas", "blockloop", "gather", "jax", "direct")


class PlanQuarantined(RuntimeError):
    """Raised by :func:`compile` when the request's plan key is inside its
    quarantine backoff window — the caller must degrade a rung instead of
    re-paying a known-bad compile."""

    def __init__(self, msg: str, *, qkey: str = "", entry: dict = None):
        super().__init__(msg)
        self.qkey = qkey
        self.entry = entry or {}


class AutotuneError(RuntimeError):
    """Every autotune candidate failed to build or measure."""

    def __init__(self, msg: str, *, failures: dict = None):
        super().__init__(msg)
        self.failures = failures or {}


# memo value: (kernel, plan) — the plan is re-used to write-through to a
# caller-supplied persistent cache that hasn't seen this request yet
_KERNEL_MEMO: Dict[Tuple, Tuple[CompiledKernel, dict]] = {}
_MEMO_HITS: Dict[Tuple, int] = {}


def clear_memo() -> None:
    """Drop all in-process compiled kernels (test isolation hook)."""
    _KERNEL_MEMO.clear()
    _MEMO_HITS.clear()


def forget(cache_key: str) -> int:
    """Purge every in-process memo entry compiled under ``cache_key`` (all
    backends).  The memo is populated *before* post-compile validation can
    run — a kernel that later flunks the registry's spot-check must not be
    memo-served on the retry, so validation failures call this."""
    stale = [mk for mk in _KERNEL_MEMO if mk[0] == cache_key]
    for mk in stale:
        _KERNEL_MEMO.pop(mk, None)
        _MEMO_HITS.pop(mk, None)
    return len(stale)


def _cell_sig(value) -> str:
    """Value-identifying signature of one closure cell.  repr() is not
    value-identifying for large arrays (elided middle), so array buffers are
    hashed.  Everything else falls back to repr: reprs that embed the object
    id (the common case for callables) miss safely across rebuilds; a custom
    object with a value-blind repr could still alias — documented limit."""
    tobytes = getattr(value, "tobytes", None)
    if callable(tobytes):
        h = hashlib.sha256(tobytes()).hexdigest()[:16]
        return f"<array {getattr(value, 'shape', ())} " \
               f"{getattr(value, 'dtype', '?')} {h}>"
    return repr(value)


def _fn_signature(g: Graph) -> Tuple:
    """Behavioral identity of compute bodies — structural fingerprints ignore
    fn objects, so the in-process memo adds this to avoid serving a kernel
    whose graph matches structurally but computes something else.  Covers the
    code location *and* the captured state (closure cells, defaults): two
    instantiations of the same lambda with different captured values must not
    collide.  A repr that isn't value-identifying only causes a safe memo
    miss."""
    sig = []
    for c in sorted(g.computes(), key=lambda n: n.name):
        carry = c.meta.get("carry")
        fns = [("fn", c.fn), ("tile_fn", c.meta.get("tile_fn"))]
        if carry is not None:
            sig.append((c.name, "carry", carry.signature()))
            fns += [("carry_step", carry.step_fn),
                    ("carry_final", carry.final_fn)]
        for label, fn in fns:
            if fn is None:
                sig.append((c.name, label, None))
                continue
            code = getattr(fn, "__code__", None)
            try:
                cells = tuple(
                    _cell_sig(cell.cell_contents)
                    for cell in getattr(fn, "__closure__", None) or ())
            except ValueError:  # unresolved cell: fall back to object id
                cells = (f"<cell id={id(fn)}>",)
            sig.append((c.name, label, getattr(fn, "__module__", ""),
                        getattr(fn, "__qualname__", repr(fn)),
                        getattr(code, "co_firstlineno", -1),
                        repr(getattr(fn, "__defaults__", None)), cells))
    return tuple(sig)


def _estimate_sig(estimate) -> Optional[Tuple]:
    if estimate is None:
        return None
    return (estimate.block_bytes_in, estimate.block_bytes_out,
            estimate.flops_per_block, estimate.fixed_overhead_s)


def measure_request_key(graph: Graph, estimate=None, *, factor="auto",
                        mode: str = "T", autotune="measure") -> str:
    """The persistent-cache key :func:`compile` assigns this request under
    the plan registry's measured-autotune path (``factor='auto'``,
    ``autotune='measure'``, default budgets).  The offline tuner
    (:mod:`repro.tune`) uses it to enumerate and dedupe work, and to key
    published artifact entries so a replica's replay compile hits them
    without re-deriving anything."""
    return request_key(graph, factor=factor, mode=mode,
                       vmem_budget=VMEM_BYTES, max_factor=16,
                       estimate=_estimate_sig(estimate), autotune=autotune)


def _valid_plan(plan) -> bool:
    """A usable cached plan must at least replay an integer pump factor —
    anything else (truncated write, hand-edited JSON, schema drift) is
    treated as a miss so a corrupted cache degrades to a cold compile
    instead of crashing the build."""
    if not isinstance(plan, dict):
        return False
    try:
        int(plan["factor"])
    except (KeyError, TypeError, ValueError):
        return False
    return True


AUTOTUNE_CANDIDATES = (1, 2, 4, 8)
# relative runtime band within which measured candidates count as tied
AUTOTUNE_TIE_BAND = 0.05


def _build(graph: Graph, *, factor, mode, vmem_budget, max_factor, estimate,
           backend, jit, pallas_mode) -> CompiledKernel:
    """One pipeline run + lowering (no caching layers)."""
    pipe = Pipeline.default(factor=factor, mode=mode,
                            vmem_budget=vmem_budget, max_factor=max_factor,
                            estimate=estimate)
    out_graph, report = pipe.run(graph)
    spec = PumpSpec(factor=report.factor, mode=mode, vmem_budget=vmem_budget)

    warn = report.warn
    fn = None
    if backend == "jax":
        fn = lower(out_graph, jit=jit, warn=warn)
    elif backend == "pallas":
        report.emission = {}
        fn = lower_pallas(out_graph, jit=jit, pallas_mode=pallas_mode,
                          warn=warn, emission=report.emission)
    elif backend == "reference":
        from repro.core import executor

        def fn(inputs, _g=out_graph):
            return executor.run(_g, dict(inputs))

    return CompiledKernel(graph=out_graph, spec=spec, report=report, fn=fn,
                          backend=backend)


def _trace_state_clean() -> bool:
    """True when no jax trace is active.  Measured autotune must not run
    inside a trace: the candidate executions there are re-traced per call
    (orders of magnitude slower) and the recorded timings are meaningless,
    yet would be persisted as a cross-process plan."""
    try:
        from jax import core as _core
        return bool(_core.trace_state_clean())
    except Exception:  # pragma: no cover — future jax API drift
        return True


def _measure_inputs(graph: Graph) -> Dict[str, np.ndarray]:
    """Synthetic operands for autotune timing: zeros for every memory that
    nothing in the graph writes (the external inputs)."""
    return {n.name: np.zeros(n.shape, dtype=n.dtype)
            for n in graph.nodes.values()
            if n.kind == NodeKind.MEMORY and not graph.in_edges(n.name)}


# wall-clock budget for measuring ONE autotune candidate (compile + repeats).
# A candidate that blows through it keeps whatever timings it banked so far —
# a slow-but-finite candidate still competes; the budget bounds warmup tail
# latency, it does not disqualify.
AUTOTUNE_CANDIDATE_BUDGET_S = 10.0


def _time_kernel(fn, inputs, repeats: int = 5,
                 budget_s: Optional[float] = None) -> float:
    """Best-of-N wall time in µs (first call compiles and is discarded).
    Five repeats: the candidate factors on the carry kernels sit within a
    few percent of each other on CPU, and best-of-3 let scheduler noise
    flip the persisted winner between otherwise identical processes.
    ``budget_s`` caps the total wall clock spent here; once exceeded the
    best timing banked so far is returned early (at least one timed run
    always happens)."""
    import jax
    t_start = time.perf_counter()
    jax.block_until_ready(fn(inputs))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(inputs))
        best = min(best, (time.perf_counter() - t0) * 1e6)
        if budget_s is not None and time.perf_counter() - t_start > budget_s:
            obs.count("compile.measure_budget_hit")
            break
    return best


def compile(graph: Graph, *, factor="auto", mode: str = "T",
            vmem_budget: int = VMEM_BYTES, max_factor: int = 16,
            estimate=None, backend: str = "jax", jit: bool = True,
            pallas_mode: str = "auto", autotune=None,
            cache=None, memoize: bool = True) -> CompiledKernel:
    """Run the pass pipeline on ``graph`` and lower the result.

    ``factor`` is an explicit pump factor M (1 = stream-only) or ``'auto'``
    to let the multipump pass autotune it (from ``estimate`` when given).
    ``backend`` is ``'jax'`` (per-node jit lowering), ``'pallas'`` (fused-
    region Pallas emission; see :mod:`.pallas_backend` and ``pallas_mode``),
    ``'reference'`` (numpy executor, the differential-testing oracle) or
    ``'none'`` (plan only).  ``autotune='measure'`` times the candidate pump
    factors ``{1, 2, 4, 8}`` on the lowered executable and keeps the winner;
    the measured plan persists in the cache, so a repeat compile replays it
    without re-measuring.  ``cache`` is a :class:`CompileCache`, ``None``
    for the default persistent cache, or ``False`` to disable disk caching;
    ``memoize=False`` also bypasses the in-process kernel memo.
    """
    if backend not in ("jax", "pallas", "reference", "none"):
        raise ValueError(f"unknown backend {backend!r}")
    if autotune not in (None, "measure"):
        raise ValueError(f"unknown autotune policy {autotune!r}")
    if autotune == "measure" and backend not in ("jax", "pallas"):
        raise ValueError("autotune='measure' needs an executable backend "
                         "('jax' or 'pallas')")
    if cache is None:
        cache = default_cache()
    elif cache is False:
        cache = None

    # the plan (chosen factor) is backend/jit-independent, so those stay out
    # of the persistent key — autopump's backend='none' plans are reused by
    # jax-backend compiles of the same graph; the memo key adds them because
    # the memoized artifact (the compiled callable) is backend-specific.
    # autotune IS part of the key: a measured winner and a capacity-model
    # guess for the same request must not collide.
    key = request_key(graph, factor=factor, mode=mode,
                      vmem_budget=vmem_budget, max_factor=max_factor,
                      estimate=_estimate_sig(estimate), autotune=autotune)
    if cache is not None:
        # quarantine gate: a (plan, backend) pair that recently failed
        # compile or validation is not retried inside its backoff window —
        # the caller degrades a rung instead (compile_degraded does this
        # automatically).  The backend is part of the quarantine key because
        # a NaN pallas kernel does not indict the jax lowering of the same
        # plan.
        qkey = f"{key}:{backend}"
        q = cache.quarantined(qkey)
        if q is not None:
            obs.count("cache.quarantine_skip", graph=graph.name,
                      backend=backend, reason=q.get("reason", ""))
            raise PlanQuarantined(
                f"plan {key[:12]}… backend={backend} is quarantined "
                f"({q.get('reason', 'unknown')}, fail #{q.get('fails', 0)}) — "
                f"backoff window open", qkey=qkey, entry=q)
    memo_key = (key, backend, jit, pallas_mode, _fn_signature(graph))
    if memoize and memo_key in _KERNEL_MEMO:
        kern, plan = _KERNEL_MEMO[memo_key]
        if cache is not None and key not in cache:
            cache.put(key, plan)   # write-through to a fresh persistent cache
        _MEMO_HITS[memo_key] = _MEMO_HITS.get(memo_key, 0) + 1
        obs.count("compile.memo_hit", graph=graph.name, backend=backend)
        # fresh report view per hit: the original compile's provenance
        # record must not be rewritten retroactively
        report = dataclasses.replace(kern.report, served_from="memory",
                                     cache_hits=_MEMO_HITS[memo_key])
        return dataclasses.replace(kern, report=report)
    with obs.span("compiler.compile", cat="compile", graph=graph.name,
                  backend=backend, autotune=autotune or "none",
                  factor=str(factor), mode=mode) as _cspan:
        try:
            return _compile_cold(graph, factor=factor, mode=mode,
                                 vmem_budget=vmem_budget,
                                 max_factor=max_factor,
                                 estimate=estimate, backend=backend, jit=jit,
                                 pallas_mode=pallas_mode, autotune=autotune,
                                 cache=cache, memoize=memoize, key=key,
                                 memo_key=memo_key, cspan=_cspan)
        except Exception as e:
            # stamp the request identity so degradation handlers can
            # quarantine / forget the exact failing plan without recomputing
            # the key (best-effort: some exotic exceptions reject attrs)
            try:
                e.compile_cache_key = key
                e.compile_backend = backend
            except Exception:
                pass
            raise


def _compile_cold(graph: Graph, *, factor, mode, vmem_budget, max_factor,
                  estimate, backend, jit, pallas_mode, autotune, cache,
                  memoize, key, memo_key, cspan) -> CompiledKernel:
    """The non-memo-hit path of :func:`compile` (span-bracketed)."""

    build = lambda f: _build(graph, factor=f, mode=mode,   # noqa: E731
                             vmem_budget=vmem_budget, max_factor=max_factor,
                             estimate=estimate, backend=backend, jit=jit,
                             pallas_mode=pallas_mode)

    persist = True
    plan = cache.get(key) if cache is not None else None
    if plan is not None and not _valid_plan(plan):
        obs.count("cache.corrupt", key=key, graph=graph.name)
        plan = None         # corrupted entry: fall back to a cold compile
    if plan is not None:
        # replay the cached decision: no autotune search, no factor probing,
        # no re-measurement
        obs.count("compile.replay", graph=graph.name, backend=backend,
                  factor=int(plan["factor"]))
        kern = _build(graph, factor=int(plan["factor"]), mode=mode,
                      vmem_budget=vmem_budget, max_factor=max_factor,
                      estimate=None, backend=backend, jit=jit,
                      pallas_mode=pallas_mode)
        served = "disk"
        if plan.get("autotune"):
            kern.report.autotune = dict(plan["autotune"], replayed=True)
    elif autotune == "measure" and not _trace_state_clean():
        # replaying a measured plan under a trace is fine (no timing runs,
        # handled above); *measuring* is not — compile with the requested
        # factor policy instead, and do NOT persist or memoize the result
        # under the measure key, so an eager context (registry warmup) can
        # still produce the real measured plan later
        obs.count("compile.measure_in_trace", graph=graph.name)
        kern = build(factor)
        served = None
        persist = False
        kern.report.warn(
            "autotune='measure' requested inside an active jax trace: "
            "in-trace timings are meaningless — compiled without "
            "measurement; measure from an eager context (e.g. plan-registry "
            "warmup) to persist a real measured plan")
    elif autotune == "measure":
        obs.count("compile.measure", graph=graph.name, backend=backend)
        inputs = _measure_inputs(graph)
        timings: Dict[int, float] = {}
        kernels: Dict[int, CompiledKernel] = {}
        failures: Dict[int, str] = {}
        with obs.span("compiler.autotune", cat="compile", graph=graph.name,
                      backend=backend) as aspan:
            for cand in AUTOTUNE_CANDIDATES:
                if cand > max_factor:
                    continue
                with obs.span("compiler.autotune.candidate", cat="compile",
                              graph=graph.name, factor=cand) as csp:
                    # one candidate failing (bad lowering at that factor, a
                    # measurement timeout) must not sink the search — the
                    # surviving candidates still yield a valid winner
                    try:
                        faults.check("compile.measure", graph=graph.name,
                                     factor=cand)
                        k = build(cand)
                        achieved = k.spec.factor  # legality may clamp it
                        if achieved in timings:
                            csp.set(achieved=achieved, skipped="duplicate")
                            continue
                        t = _time_kernel(k.fn, inputs,
                                         budget_s=AUTOTUNE_CANDIDATE_BUDGET_S)
                        kernels[achieved] = k
                        timings[achieved] = t
                        csp.set(achieved=achieved, best_us=round(t, 1))
                    except Exception as e:
                        failures[cand] = repr(e)
                        obs.count("compile.measure_failed", graph=graph.name,
                                  factor=str(cand), error=type(e).__name__)
                        csp.set(failed=type(e).__name__)
            aspan.set(failed_candidates=len(failures))
        if not timings:
            raise AutotuneError(
                f"autotune='measure' on {graph.name!r}: every candidate "
                f"failed — {failures}", failures=failures)
        # statistical ties go to the smallest factor: candidates within the
        # noise band of the best are indistinguishable by measurement, and
        # persisting an arbitrary exotic winner costs VMEM/beats for nothing
        # (and flips between otherwise identical processes).  Genuine
        # multi-pump wins exceed the band and are kept.
        best_t = min(timings.values())
        winner = min(f for f, t in timings.items()
                     if t <= best_t * (1.0 + AUTOTUNE_TIE_BAND))
        kern = kernels[winner]
        served = None
        kern.report.autotune = {
            "policy": "measure", "winner": winner, "backend": backend,
            "timings_us": {str(f): round(t, 1) for f, t in timings.items()},
            "replayed": False,
        }
        if failures:
            kern.report.autotune["failed"] = {str(f): err for f, err
                                              in failures.items()}
            kern.report.warn(
                f"autotune: {len(failures)} candidate(s) failed "
                f"measurement and were excluded from the search")
    else:
        obs.count("compile.build", graph=graph.name, backend=backend)
        kern = build(factor)
        served = None

    report = kern.report
    report.cache_key = key
    report.served_from = served
    report.cache_hits = 1 if served else 0
    cspan.set(served=served or "build", achieved_factor=kern.spec.factor)

    if plan is None:
        plan = {"factor": kern.spec.factor, "mode": mode,
                "graph": graph.name,
                "passes": [[r.name, r.applied] for r in report.records]}
        if report.autotune:
            plan["autotune"] = {k: v for k, v in report.autotune.items()
                                if k != "replayed"}
        if cache is not None and persist:
            cache.put(key, plan)
    if memoize and persist:
        _KERNEL_MEMO[memo_key] = (kern, plan)
    return kern


def compile_degraded(graph: Graph, *, backend: str = "pallas",
                     autotune=None, cache=None,
                     **kw) -> CompiledKernel:
    """:func:`compile`, walking the cross-backend rungs of
    :data:`DEGRADATION_LADDER` instead of raising.

    Tries the requested backend first; on failure (or an open quarantine
    window) records the failing rung in the quarantine ledger, counts
    ``degrade.compile`` with the reason, and steps down: pallas → per-node
    jax lowering → jax without measured autotune.  The intra-pallas tiers
    (blockloop/gather) degrade inside :func:`~.pallas_backend.lower_pallas`
    before any of this triggers.  Raises only when every rung fails — the
    caller's last rung (the registry wrappers' / engine's plain-jnp direct
    functions) is below this function.
    """
    store = default_cache() if cache is None else (cache or None)
    rungs = [(backend, autotune)]
    if backend != "jax":
        rungs.append(("jax", autotune))
    if autotune is not None:
        rungs.append(("jax", None))
    last = None
    degraded_from = None
    for b, at in rungs:
        try:
            kern = compile(graph, backend=b, autotune=at, cache=cache, **kw)
        except PlanQuarantined as e:
            # already quarantined — skip the rung without re-recording
            last = e
            degraded_from = (b, "quarantined")
            obs.count("degrade.compile", graph=graph.name, frm=b,
                      reason="quarantined")
            continue
        except Exception as e:
            last = e
            reason = type(e).__name__
            degraded_from = (b, reason)
            obs.count("degrade.compile", graph=graph.name, frm=b,
                      reason=reason)
            qkey = getattr(e, "compile_cache_key", None)
            if store is not None and qkey:
                store.record_failure(f"{qkey}:{b}", reason)
            continue
        if degraded_from is not None:
            frm, why = degraded_from
            kern.report.warn(
                f"degraded compile: backend={frm} failed ({why}); "
                f"served by backend={b}"
                + ("" if at == autotune else " without measured autotune"))
        return kern
    raise last


def plan_pump(block_bytes_in: int, block_bytes_out: int,
              flops_per_block: float, mode: str = "T", max_factor: int = 16,
              vmem_budget: int = VMEM_BYTES, axis: int = 0,
              cache=None) -> PumpSpec:
    """Persistently-cached pump-factor planning for the kernel layer.

    Same contract as :func:`repro.core.pump_plan.plan_kernel_pump`, but the
    chosen factor is stored in the compile cache so every benchmark/serve
    process after the first skips the capacity-model search.
    """
    if cache is None:
        cache = default_cache()
    elif cache is False:
        cache = None
    key = None
    if cache is not None:
        import hashlib
        import json
        key = "pump:" + hashlib.sha256(json.dumps(
            [block_bytes_in, block_bytes_out, flops_per_block, mode,
             max_factor, vmem_budget, axis], sort_keys=True).encode()
        ).hexdigest()
        entry = cache.get(key)
        if entry is not None:
            return PumpSpec(factor=int(entry["factor"]), mode=mode, axis=axis,
                            vmem_budget=vmem_budget)
    spec = plan_kernel_pump(block_bytes_in, block_bytes_out, flops_per_block,
                            mode=mode, max_factor=max_factor,
                            vmem_budget=vmem_budget, axis=axis)
    if cache is not None:
        cache.put(key, {"factor": spec.factor})
    return spec


__all__ = [
    "compile", "compile_degraded", "plan_pump", "clear_memo", "forget",
    "AUTOTUNE_CANDIDATES", "AUTOTUNE_CANDIDATE_BUDGET_S",
    "DEGRADATION_LADDER", "PlanQuarantined", "AutotuneError",
    "Pipeline", "PipelineReport", "PassRecord",
    "GraphPass", "PASS_REGISTRY", "register_pass", "make_pass",
    "StreamingPass", "StreamFusionPass", "MultipumpPass", "FifoDepthPass",
    "FusionReport",
    "CompileCache", "QuarantinePolicy", "default_cache",
    "graph_fingerprint", "request_key", "measure_request_key",
    "CompiledKernel", "LoweringError", "lower",
    "lower_pallas", "partition_regions",
    "BucketPolicy", "PlanRegistry", "default_registry",
    "set_default_registry",
]
