"""Persistent compile/autotune cache.

Keyed by a content hash of the *structure* of a graph (nodes, edges, access
patterns, shapes) plus the compile parameters (including the ``autotune``
policy); the stored value is the pipeline *plan*::

    {"factor": 2, "mode": "T", "graph": "matmul",
     "passes": [["streaming", true], ...],
     "autotune": {"policy": "measure", "winner": 2, "backend": "pallas",
                  "timings_us": {"1": ..., "2": ...}}}   # measured runs only

— most importantly the chosen pump factor, so a repeated
``compile``/``autopump`` in a fresh process skips the autotune search,
legality probing, *and* any runtime re-measurement (``autotune='measure'``
replays the stored winner).  Entries live in one JSON file (default
``~/.cache/repro/compile_cache.json``, overridable with ``$REPRO_CACHE_DIR``
or an explicit path), written atomically via rename.

Compute-node ``fn`` bodies are not part of the structural fingerprint (they
are opaque callables); plans are fn-independent, and the in-memory kernel
memo in :mod:`repro.compiler` additionally keys on the fn code location.
All I/O failures degrade to cache-off behaviour instead of raising.

Self-healing store semantics (docs/robustness.md):

* **Atomic writes + cross-process locking** — every write is tmp+rename
  (readers never see a torn file) and the read-merge-write cycle holds an
  ``fcntl`` lock on ``<path>.lock``, so two processes warming the same grid
  merge their entries instead of last-writer-wins clobbering.
* **Quarantine with retry budget + exponential backoff** — a plan that
  fails compilation or flunks the registry's differential/finite spot-check
  is recorded under its content-hash key (suffixed with the backend rung):
  each failure doubles the backoff window (``base_s · 2^(fails-1)``, capped
  at ``cap_s`` once ``budget`` failures are spent), and
  :func:`repro.compiler.compile` skips a quarantined rung inside its window
  (``cache.quarantine_skip``) so the hot path stops re-paying a known-bad
  plan.  A later success clears the entry.
* **Fault injection** — the read / parse / write seams are injection sites
  (``cache.load`` / ``cache.json`` / ``cache.save``; see
  :mod:`repro.testing.faults`), and every degrade they trigger is already a
  counted health event.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import tempfile
import time
from pathlib import Path
from typing import Dict, Optional

try:
    import fcntl
except ImportError:  # pragma: no cover — non-POSIX: lockless best effort
    fcntl = None

from repro import obs
from repro.core.ir import Graph
from repro.core.symbolic import AccessPattern, Affine
from repro.testing import faults


def _affine_sig(a: Affine):
    sig = [list(map(list, a.terms)), a.const]
    if a.tables:        # group-indexed lookups are part of the structure
        sig.append([[s, list(t)] for s, t in a.tables])
    return sig


def _access_sig(acc: Optional[AccessPattern]):
    if acc is None:
        return None
    return {
        "dims": [list(d) for d in acc.domain.dims],
        "exprs": [_affine_sig(e) for e in acc.normalized_exprs()],
        "width": acc.width,
    }


_META_KEYS = ("factor", "pump_mode", "keep", "rate", "reduce", "axes")


def _meta_sig(meta: dict) -> list:
    sig = [[k, repr(meta[k])] for k in _META_KEYS if k in meta]
    carry = meta.get("carry")
    if carry is not None:
        # CarrySpec's repr embeds function objects (unstable across
        # processes); its signature() is the stable structural identity
        sig.append(["carry", repr(carry.signature())])
    return sig


def graph_fingerprint(g: Graph) -> str:
    """Deterministic content hash of the graph structure (not fn bodies)."""
    nodes = []
    for name in sorted(g.nodes):
        n = g.nodes[name]
        nodes.append([
            name, n.kind.value, list(n.shape), n.dtype, n.space.value,
            n.elem_width, n.depth, n.vector_width, n.rate.value, n.pump,
            bool(n.data_dependent_io), _meta_sig(n.meta),
        ])
    edges = [[e.src, e.dst, _access_sig(e.access), e.volume] for e in g.edges]
    blob = json.dumps([g.name, nodes, edges], sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def _env_fingerprint() -> str:
    """Toolchain identity folded into every request key.  Measured-runtime
    plans (``autotune='measure'``) are only as good as the jax build that
    timed them — a winner measured under one version must not be silently
    replayed under another, so the jax version is part of the key and an
    upgrade degrades to a cold re-measure instead of stale replay."""
    try:
        import jax
        return f"jax-{jax.__version__}"
    except Exception:  # pragma: no cover — jax-free planning contexts
        return "jax-none"


def request_key(g: Graph, **params) -> str:
    """Cache key for one compile request: structure hash + parameters +
    toolchain fingerprint (jax version)."""
    blob = json.dumps([graph_fingerprint(g), _env_fingerprint(),
                       sorted(params.items())],
                      sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()


def _default_path() -> Path:
    root = os.environ.get("REPRO_CACHE_DIR")
    if root:
        return Path(root).expanduser() / "compile_cache.json"
    return Path.home() / ".cache" / "repro" / "compile_cache.json"


@dataclasses.dataclass(frozen=True)
class QuarantinePolicy:
    """Backoff schedule for plans that keep failing (docs/robustness.md).

    The n-th recorded failure of a plan key opens a no-retry window of
    ``base_s * 2**(n-1)`` seconds, capped at ``cap_s``; once ``budget``
    failures are spent the window pins at ``cap_s`` (the plan is effectively
    parked until an operator clears it or a success is recorded)."""

    base_s: float = 0.5
    cap_s: float = 300.0
    budget: int = 5

    def window_s(self, fails: int) -> float:
        if fails >= self.budget:
            return self.cap_s
        return min(self.base_s * (2.0 ** max(fails - 1, 0)), self.cap_s)


class CompileCache:
    """JSON-on-disk key→plan store with hit/miss accounting, cross-process
    merge-on-write locking, and a quarantine ledger (schema version 2; a
    version-1 file reads as an empty quarantine)."""

    def __init__(self, path: Optional[os.PathLike | str] = None,
                 quarantine: Optional[QuarantinePolicy] = None):
        self.path = Path(path) if path is not None else _default_path()
        self.quarantine_policy = quarantine or QuarantinePolicy()
        self.hits = 0
        self.misses = 0
        self._entries: Optional[Dict[str, dict]] = None
        self._quarantine: Dict[str, dict] = {}
        # keys whose quarantine entries this process cleared; the merge in
        # _save must not resurrect them from a stale on-disk copy
        self._quarantine_cleared: set = set()

    # -- persistence ---------------------------------------------------------
    @contextlib.contextmanager
    def _lock(self):
        """Cross-process write lock on a `.lock` sibling.  Lock failures
        (exotic filesystems, non-POSIX) degrade to the unlocked best-effort
        behaviour — writes stay atomic either way, the lock only closes the
        read-merge-write race between concurrent writers."""
        if fcntl is None:
            yield
            return
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            lockf = open(self.path.with_suffix(self.path.suffix + ".lock"),
                         "w")
        except OSError:
            yield
            return
        try:
            fcntl.flock(lockf, fcntl.LOCK_EX)
            yield
        finally:
            with contextlib.suppress(OSError):
                fcntl.flock(lockf, fcntl.LOCK_UN)
            lockf.close()

    def _read_disk(self):
        """Fresh read of the on-disk store → (entries, quarantine).  All
        failure modes (missing file, torn write, bitrot, IO error) degrade
        to an empty store; corruption is counted."""
        try:
            faults.check("cache.load", path=str(self.path))
            with open(self.path) as f:
                text = f.read()
            text = faults.mangle("cache.json", text, path=str(self.path))
            data = json.loads(text)
            entries = dict(data.get("entries", {}))
            quarantine = dict(data.get("quarantine", {}))
        except FileNotFoundError:
            return {}, {}            # cold store: expected, not a health event
        except (OSError, ValueError, AttributeError, TypeError) as e:
            # truncated/corrupted/wrong-schema JSON: cold-compile path.
            # The degrade is the contract; the *event* must still be
            # visible — a fleet silently re-measuring every plan because
            # its shared cache file is corrupt is a real failure mode.
            obs.count("cache.corrupt", path=str(self.path), error=repr(e))
            return {}, {}
        return entries, quarantine

    def _load(self) -> Dict[str, dict]:
        if self._entries is None:
            self._entries, self._quarantine = self._read_disk()
            if self._entries:
                # entries stamped under another jax build can never match a
                # current request key (the version is folded into the key),
                # so they are invisible dead weight — count them once per
                # load for fleet-level cache health
                env = _env_fingerprint()
                stale = sum(1 for v in self._entries.values()
                            if isinstance(v, dict)
                            and v.get("env") not in (None, env))
                if stale:
                    obs.count("cache.stale_jax_version", stale,
                              path=str(self.path), env=env)
        return self._entries

    def _save(self, merge: bool = True) -> None:
        try:
            with self._lock():
                entries = self._load()
                quarantine = self._quarantine
                if merge:
                    # re-read under the lock and merge: another process may
                    # have written entries since our load, and plans/ledger
                    # rows are individually valid — union loses nothing
                    disk_entries, disk_quarantine = self._read_disk()
                    entries = {**disk_entries, **entries}
                    quarantine = {**disk_quarantine, **quarantine}
                    for key in self._quarantine_cleared:
                        quarantine.pop(key, None)
                    self._entries, self._quarantine = entries, quarantine
                faults.check("cache.save", path=str(self.path))
                self.path.parent.mkdir(parents=True, exist_ok=True)
                fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                           prefix=self.path.name,
                                           suffix=".tmp")
                with os.fdopen(fd, "w") as f:
                    json.dump({"version": 2, "entries": entries,
                               "quarantine": quarantine}, f)
                os.replace(tmp, self.path)
        except OSError:
            pass  # read-only filesystem etc.: behave as a process-local cache

    # -- store API -----------------------------------------------------------
    def get(self, key: str) -> Optional[dict]:
        entries = self._load()
        entry = entries.get(key)
        if not isinstance(entry, dict):   # absent or corrupted value
            if key in entries:            # present but wrong type: corrupted
                obs.count("cache.corrupt", key=key)
            self.misses += 1
            obs.count("cache.miss")
            return None
        self.hits += 1
        obs.count("cache.hit")
        return dict(entry)

    def put(self, key: str, value: dict) -> None:
        value = dict(value)
        # stamp the toolchain identity so a later load can count entries
        # orphaned by a jax upgrade (see _load's stale scan), and a creation
        # time so prune() can age entries out
        value.setdefault("env", _env_fingerprint())
        value.setdefault("created", time.time())
        self._load()[key] = value
        self._save()

    def put_many(self, values: Dict[str, dict]) -> None:
        """Install a batch of entries under one locked merge-write (the
        artifact-preload path: N ``put`` calls would pay N read-merge-write
        cycles on the shared file)."""
        if not values:
            return
        entries = self._load()
        now = time.time()
        for key, value in values.items():
            value = dict(value)
            value.setdefault("env", _env_fingerprint())
            value.setdefault("created", now)
            entries[key] = value
        self._save()

    def prune(self, max_age_s: Optional[float] = None,
              now: Optional[float] = None) -> Dict[str, int]:
        """Garbage-collect the persistent store under the fcntl lock.

        Three classes of dead weight accumulate forever without this:
        entries stamped under another jax build (their version is folded
        into the request key, so no current request can ever hit them),
        entries older than ``max_age_s`` (when given), and quarantine rows
        whose backoff window has expired (kept by :meth:`quarantined` so
        repeat failures back off harder — but an operator-invoked prune is
        the explicit "forgive history" point).  The whole read-evict-write
        cycle runs inside :meth:`_lock`, so a concurrent writer's fresh
        entries are never lost; evictions are counted via ``obs``
        (``cache.pruned`` per category) and returned."""
        now = now if now is not None else time.time()
        evicted = {"stale_env": 0, "aged": 0, "corrupt": 0, "quarantine": 0}
        env = _env_fingerprint()
        try:
            with self._lock():
                entries, quarantine = self._read_disk()
                keep: Dict[str, dict] = {}
                for key, value in entries.items():
                    if not isinstance(value, dict):
                        evicted["corrupt"] += 1
                    elif value.get("env") not in (None, env):
                        evicted["stale_env"] += 1
                    elif (max_age_s is not None
                          and now - value.get("created", now) > max_age_s):
                        evicted["aged"] += 1
                    else:
                        keep[key] = value
                q_keep: Dict[str, dict] = {}
                for key, value in quarantine.items():
                    if (isinstance(value, dict)
                            and now < value.get("until", 0.0)):
                        q_keep[key] = value
                    else:   # window expired (or row corrupt): GC it
                        evicted["quarantine"] += 1
                if sum(evicted.values()):
                    faults.check("cache.save", path=str(self.path))
                    self.path.parent.mkdir(parents=True, exist_ok=True)
                    fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                               prefix=self.path.name,
                                               suffix=".tmp")
                    with os.fdopen(fd, "w") as f:
                        json.dump({"version": 2, "entries": keep,
                                   "quarantine": q_keep}, f)
                    os.replace(tmp, self.path)
                self._entries, self._quarantine = keep, q_keep
        except OSError:
            return evicted    # read-only store: nothing evicted, no crash
        for kind, n in evicted.items():
            if n:
                obs.count("cache.pruned", n, kind=kind, path=str(self.path))
        return evicted

    def clear(self) -> None:
        self._entries = {}
        self._quarantine = {}
        self._quarantine_cleared = set()
        self._save(merge=False)

    # -- quarantine ledger ---------------------------------------------------
    def quarantined(self, key: str, now: Optional[float] = None
                    ) -> Optional[dict]:
        """The quarantine entry for ``key`` if its backoff window is still
        open, else None.  An expired window does not delete the entry — the
        failure count persists so the *next* failure backs off harder."""
        self._load()
        entry = self._quarantine.get(key)
        if not isinstance(entry, dict):
            return None
        if (now if now is not None else time.time()) < entry.get("until", 0.0):
            return dict(entry)
        return None

    def record_failure(self, key: str, reason: str,
                       now: Optional[float] = None) -> dict:
        """Record one failure of ``key``; opens/extends its backoff window
        per the policy and persists the ledger."""
        self._load()
        now = now if now is not None else time.time()
        entry = self._quarantine.get(key)
        fails = (entry.get("fails", 0) if isinstance(entry, dict) else 0) + 1
        window = self.quarantine_policy.window_s(fails)
        entry = {"fails": fails, "until": now + window, "reason": reason,
                 "last": now}
        self._quarantine[key] = entry
        self._quarantine_cleared.discard(key)
        obs.count("cache.quarantine", key=key, reason=reason,
                  fails=str(fails))
        self._save()
        return dict(entry)

    def record_success(self, key: str) -> None:
        """A key that works again leaves quarantine entirely."""
        self._load()
        if self._quarantine.pop(key, None) is not None:
            self._quarantine_cleared.add(key)
            self._save()

    def quarantine_entries(self) -> Dict[str, dict]:
        self._load()
        return {k: dict(v) for k, v in self._quarantine.items()
                if isinstance(v, dict)}

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._load()),
                "quarantined": len(self._quarantine)}

    def __len__(self) -> int:
        return len(self._load())

    def __contains__(self, key: str) -> bool:
        """Presence probe that does not count toward hit/miss stats."""
        return key in self._load()


_DEFAULT_CACHES: Dict[str, CompileCache] = {}


def default_cache() -> CompileCache:
    """Process-wide cache instance for the default path (env-sensitive)."""
    path = str(_default_path())
    if path not in _DEFAULT_CACHES:
        _DEFAULT_CACHES[path] = CompileCache(path)
    return _DEFAULT_CACHES[path]
