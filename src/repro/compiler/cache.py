"""Persistent compile/autotune cache.

Keyed by a content hash of the *structure* of a graph (nodes, edges, access
patterns, shapes) plus the compile parameters (including the ``autotune``
policy); the stored value is the pipeline *plan*::

    {"factor": 2, "mode": "T", "graph": "matmul",
     "passes": [["streaming", true], ...],
     "autotune": {"policy": "measure", "winner": 2, "backend": "pallas",
                  "timings_us": {"1": ..., "2": ...}}}   # measured runs only

— most importantly the chosen pump factor, so a repeated
``compile``/``autopump`` in a fresh process skips the autotune search,
legality probing, *and* any runtime re-measurement (``autotune='measure'``
replays the stored winner).  Entries live in one JSON file (default
``~/.cache/repro/compile_cache.json``, overridable with ``$REPRO_CACHE_DIR``
or an explicit path), written atomically via rename.

Compute-node ``fn`` bodies are not part of the structural fingerprint (they
are opaque callables); plans are fn-independent, and the in-memory kernel
memo in :mod:`repro.compiler` additionally keys on the fn code location.
All I/O failures degrade to cache-off behaviour instead of raising.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Dict, Optional

from repro import obs
from repro.core.ir import Graph
from repro.core.symbolic import AccessPattern, Affine


def _affine_sig(a: Affine):
    sig = [list(map(list, a.terms)), a.const]
    if a.tables:        # group-indexed lookups are part of the structure
        sig.append([[s, list(t)] for s, t in a.tables])
    return sig


def _access_sig(acc: Optional[AccessPattern]):
    if acc is None:
        return None
    return {
        "dims": [list(d) for d in acc.domain.dims],
        "exprs": [_affine_sig(e) for e in acc.normalized_exprs()],
        "width": acc.width,
    }


_META_KEYS = ("factor", "pump_mode", "keep", "rate", "reduce", "axes")


def _meta_sig(meta: dict) -> list:
    sig = [[k, repr(meta[k])] for k in _META_KEYS if k in meta]
    carry = meta.get("carry")
    if carry is not None:
        # CarrySpec's repr embeds function objects (unstable across
        # processes); its signature() is the stable structural identity
        sig.append(["carry", repr(carry.signature())])
    return sig


def graph_fingerprint(g: Graph) -> str:
    """Deterministic content hash of the graph structure (not fn bodies)."""
    nodes = []
    for name in sorted(g.nodes):
        n = g.nodes[name]
        nodes.append([
            name, n.kind.value, list(n.shape), n.dtype, n.space.value,
            n.elem_width, n.depth, n.vector_width, n.rate.value, n.pump,
            bool(n.data_dependent_io), _meta_sig(n.meta),
        ])
    edges = [[e.src, e.dst, _access_sig(e.access), e.volume] for e in g.edges]
    blob = json.dumps([g.name, nodes, edges], sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def _env_fingerprint() -> str:
    """Toolchain identity folded into every request key.  Measured-runtime
    plans (``autotune='measure'``) are only as good as the jax build that
    timed them — a winner measured under one version must not be silently
    replayed under another, so the jax version is part of the key and an
    upgrade degrades to a cold re-measure instead of stale replay."""
    try:
        import jax
        return f"jax-{jax.__version__}"
    except Exception:  # pragma: no cover — jax-free planning contexts
        return "jax-none"


def request_key(g: Graph, **params) -> str:
    """Cache key for one compile request: structure hash + parameters +
    toolchain fingerprint (jax version)."""
    blob = json.dumps([graph_fingerprint(g), _env_fingerprint(),
                       sorted(params.items())],
                      sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()


def _default_path() -> Path:
    root = os.environ.get("REPRO_CACHE_DIR")
    if root:
        return Path(root).expanduser() / "compile_cache.json"
    return Path.home() / ".cache" / "repro" / "compile_cache.json"


class CompileCache:
    """JSON-on-disk key→plan store with hit/miss accounting."""

    def __init__(self, path: Optional[os.PathLike | str] = None):
        self.path = Path(path) if path is not None else _default_path()
        self.hits = 0
        self.misses = 0
        self._entries: Optional[Dict[str, dict]] = None

    # -- persistence ---------------------------------------------------------
    def _load(self) -> Dict[str, dict]:
        if self._entries is None:
            try:
                with open(self.path) as f:
                    data = json.load(f)
                self._entries = dict(data.get("entries", {}))
            except FileNotFoundError:
                self._entries = {}   # cold store: expected, not a health event
            except (OSError, ValueError, AttributeError, TypeError) as e:
                # truncated/corrupted/wrong-schema JSON: cold-compile path.
                # The degrade is the contract; the *event* must still be
                # visible — a fleet silently re-measuring every plan because
                # its shared cache file is corrupt is a real failure mode.
                obs.count("cache.corrupt", path=str(self.path), error=repr(e))
                self._entries = {}
            else:
                # entries stamped under another jax build can never match a
                # current request key (the version is folded into the key),
                # so they are invisible dead weight — count them once per
                # load for fleet-level cache health
                env = _env_fingerprint()
                stale = sum(1 for v in self._entries.values()
                            if isinstance(v, dict)
                            and v.get("env") not in (None, env))
                if stale:
                    obs.count("cache.stale_jax_version", stale,
                              path=str(self.path), env=env)
        return self._entries

    def _save(self) -> None:
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                       prefix=self.path.name, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump({"version": 1, "entries": self._load()}, f)
            os.replace(tmp, self.path)
        except OSError:
            pass  # read-only filesystem etc.: behave as a process-local cache

    # -- store API -----------------------------------------------------------
    def get(self, key: str) -> Optional[dict]:
        entries = self._load()
        entry = entries.get(key)
        if not isinstance(entry, dict):   # absent or corrupted value
            if key in entries:            # present but wrong type: corrupted
                obs.count("cache.corrupt", key=key)
            self.misses += 1
            obs.count("cache.miss")
            return None
        self.hits += 1
        obs.count("cache.hit")
        return dict(entry)

    def put(self, key: str, value: dict) -> None:
        value = dict(value)
        # stamp the toolchain identity so a later load can count entries
        # orphaned by a jax upgrade (see _load's stale scan)
        value.setdefault("env", _env_fingerprint())
        self._load()[key] = value
        self._save()

    def clear(self) -> None:
        self._entries = {}
        self._save()

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._load())}

    def __len__(self) -> int:
        return len(self._load())

    def __contains__(self, key: str) -> bool:
        """Presence probe that does not count toward hit/miss stats."""
        return key in self._load()


_DEFAULT_CACHES: Dict[str, CompileCache] = {}


def default_cache() -> CompileCache:
    """Process-wide cache instance for the default path (env-sensitive)."""
    path = str(_default_path())
    if path not in _DEFAULT_CACHES:
        _DEFAULT_CACHES[path] = CompileCache(path)
    return _DEFAULT_CACHES[path]
