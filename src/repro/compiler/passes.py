"""Registered graph-rewrite passes over the dataflow IR.

Following DaCe's transformation-registry design, every transformation is a
class with a ``can_apply``/``apply`` protocol registered by name in
:data:`PASS_REGISTRY`; the :class:`~repro.compiler.pipeline.Pipeline` driver
runs a sequence of them and records a per-pass report.  The two passes the
paper describes (streaming extraction, multi-pumping) wrap the rewrite rules
in ``repro.core``; two further passes close the gap to a real compiler:

``stream-fusion``
    After streaming extraction, an intermediate memory written by one module
    and read in the same order by exactly one other module survives as a
    ``Stream -> Writer -> Memory -> Reader -> Stream`` round-trip.  The pass
    collapses the chain into the single producer-side stream, removing the
    memory materialization entirely (de Fine Licht et al.'s "stream
    composition" HLS transformation).

``fifo-depth``
    Sizes every FIFO from the rate mismatch of its endpoints instead of the
    hard-coded depth 2: a stream whose endpoint issues/consumes M beats per
    wide transaction needs M slots per pipeline buffer, so depth = 2·M
    (double buffering × pump factor).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type

from repro.core.ir import Edge, Graph, Node, NodeKind, RateDomain, Space
from repro.core.multipump import (PumpReport, apply_multipump, check_multipump)
from repro.core.pump_plan import VMEM_BYTES, best_pump_factor
from repro.core.streaming import apply_streaming, streamable_subgraph
from repro.core.symbolic import sequence_equivalent


class GraphPass:
    """Protocol: ``can_apply(g) -> (bool, reason)``; ``apply(g) -> (Graph, report)``.

    Instances carry their options; ``apply`` must not mutate its input graph.
    """

    name: str = "abstract"

    def can_apply(self, g: Graph) -> Tuple[bool, str]:
        raise NotImplementedError

    def apply(self, g: Graph) -> Tuple[Graph, object]:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<pass {self.name}>"


PASS_REGISTRY: Dict[str, Type[GraphPass]] = {}


def register_pass(cls: Type[GraphPass]) -> Type[GraphPass]:
    """Class decorator adding a pass to the global registry by ``cls.name``."""
    if cls.name in PASS_REGISTRY and PASS_REGISTRY[cls.name] is not cls:
        raise ValueError(f"duplicate pass name {cls.name!r}")
    PASS_REGISTRY[cls.name] = cls
    return cls


def make_pass(name: str, **options) -> GraphPass:
    if name not in PASS_REGISTRY:
        raise KeyError(f"unknown pass {name!r}; known: {sorted(PASS_REGISTRY)}")
    return PASS_REGISTRY[name](**options)


# ---------------------------------------------------------------- streaming --
@register_pass
class StreamingPass(GraphPass):
    """Memory-to-FIFO extraction (paper §3.2 box ②) as a registered pass."""

    name = "streaming"

    def __init__(self, node_filter: Optional[Callable[[Node], bool]] = None):
        self.node_filter = node_filter

    def can_apply(self, g: Graph) -> Tuple[bool, str]:
        for comp in g.computes():
            for e in g.in_edges(comp.name) + g.out_edges(comp.name):
                other = g.nodes[e.src if e.dst == comp.name else e.dst]
                if other.kind == NodeKind.MEMORY and other.space == Space.HBM:
                    return True, "HBM memory edges present"
        return False, "no HBM memory edges adjacent to compute modules"

    def apply(self, g: Graph):
        return apply_streaming(g, node_filter=self.node_filter)


# ------------------------------------------------------------ stream fusion --
@dataclasses.dataclass
class FusionReport:
    # (upstream stream, removed memory, consumer module) per collapsed chain
    fused: List[Tuple[str, str, str]] = dataclasses.field(default_factory=list)
    rejected: List[Tuple[str, str]] = dataclasses.field(default_factory=list)

    def __repr__(self):  # pragma: no cover
        return f"FusionReport(fused={len(self.fused)}, rejected={len(self.rejected)})"


@register_pass
class StreamFusionPass(GraphPass):
    """Collapse ``... -> Stream -> Writer -> Memory -> Reader -> Stream -> ...``
    into the single upstream stream when the write and read sequences match.

    Memories marked ``meta['keep']`` (externally observed results) are never
    fused away.
    """

    name = "stream-fusion"

    def _chains(self, g: Graph) -> List[Tuple[str, str, str, str, str]]:
        chains = []
        for mem in [n for n in g.nodes.values() if n.kind == NodeKind.MEMORY]:
            if mem.meta.get("keep"):
                continue
            ins, outs = g.in_edges(mem.name), g.out_edges(mem.name)
            if len(ins) != 1 or len(outs) != 1:
                continue
            wr, rd = g.nodes[ins[0].src], g.nodes[outs[0].dst]
            if wr.kind != NodeKind.WRITER or rd.kind != NodeKind.READER:
                continue
            if ins[0].access is None or outs[0].access is None:
                continue
            if not sequence_equivalent(ins[0].access, outs[0].access, mem.shape):
                continue
            we, re = g.in_edges(wr.name), g.out_edges(rd.name)
            if len(we) != 1 or len(re) != 1:
                continue
            s_up, s_dn = g.nodes[we[0].src], g.nodes[re[0].dst]
            if s_up.kind != NodeKind.STREAM or s_dn.kind != NodeKind.STREAM:
                continue
            consumers = g.out_edges(s_dn.name)
            if len(consumers) != 1:
                continue
            chains.append((s_up.name, wr.name, mem.name, rd.name, s_dn.name))
        return chains

    def can_apply(self, g: Graph) -> Tuple[bool, str]:
        n = len(self._chains(g))
        if n:
            return True, f"{n} fusible writer/memory/reader chain(s)"
        return False, "no fusible Stream->Writer->Memory->Reader->Stream chains"

    def apply(self, g: Graph):
        out = g.copy()
        report = FusionReport()
        # fixpoint, one chain per iteration: collapsing a chain can delete a
        # stream another candidate referenced, or expose a new cascade
        while True:
            chains = self._chains(out)
            if not chains:
                break
            s_up, wr, mem, rd, s_dn = chains[0]
            consumer_edge = out.out_edges(s_dn)[0]
            # the fused stream inherits the deeper of the two buffers
            out.nodes[s_up].depth = max(out.nodes[s_up].depth,
                                        out.nodes[s_dn].depth)
            dead = {wr, mem, rd, s_dn}
            # the replacement edge must take the consumer edge's *position*:
            # executors bind compute operands (in0, in1, ...) by edge order
            new_edge = Edge(s_up, consumer_edge.dst, consumer_edge.access,
                            consumer_edge.volume)
            rebuilt = []
            for e in out.edges:
                if e is consumer_edge:
                    rebuilt.append(new_edge)
                elif e.src in dead or e.dst in dead:
                    continue
                else:
                    rebuilt.append(e)
            out.edges = rebuilt
            for name in dead:
                del out.nodes[name]
            report.fused.append((s_up, mem, consumer_edge.dst))
        out.validate()
        return out, report


# -------------------------------------------------------------- multipump --
@register_pass
class MultipumpPass(GraphPass):
    """Temporal vectorization (paper §2/§3.2) with optional factor autotuning.

    ``factor='auto'`` resolves M at apply time: from the capacity model when a
    :class:`~repro.core.pump_plan.KernelEstimate` is supplied, otherwise the
    largest power of two ≤ ``max_factor``; either start value is halved until
    the legality check accepts it (mode-R width divisibility, VMEM budget).
    """

    name = "multipump"

    def __init__(self, factor="auto", mode: str = "T",
                 vmem_budget: int = VMEM_BYTES, max_factor: int = 16,
                 estimate=None, targets: Optional[Sequence[str]] = None):
        self.factor = factor
        self.mode = mode
        self.vmem_budget = vmem_budget
        self.max_factor = max_factor
        self.estimate = estimate
        self.targets = targets

    def _targets(self, g: Graph) -> List[str]:
        if self.targets is not None:
            return list(self.targets)
        return [n for n in streamable_subgraph(g)
                if g.nodes[n].kind == NodeKind.COMPUTE]

    def _resolve(self, g: Graph, targets: Sequence[str]) -> int:
        if isinstance(self.factor, int):
            return self.factor
        if self.estimate is not None:
            m = best_pump_factor(self.estimate, max_factor=self.max_factor,
                                 vmem_budget=self.vmem_budget)
        else:
            m = 1 << (max(self.max_factor, 1).bit_length() - 1)
        while m > 1 and not check_multipump(g, targets, m, self.mode,
                                            self.vmem_budget)[0]:
            m //= 2
        return m

    def can_apply(self, g: Graph) -> Tuple[bool, str]:
        if isinstance(self.factor, int) and self.factor < 2:
            return False, f"factor {self.factor} < 2: nothing to pump"
        targets = self._targets(g)
        if not targets:
            return False, "no fully-streamed compute modules"
        if isinstance(self.factor, int):
            return check_multipump(g, targets, self.factor, self.mode,
                                   self.vmem_budget)
        return True, "factor resolved at apply time"

    def apply(self, g: Graph):
        targets = self._targets(g)
        m = self._resolve(g, targets)
        if m < 2:
            before = g.resources()
            return g, PumpReport(False, self.mode, 1,
                                 "no feasible factor > 1",
                                 resources_before=before,
                                 resources_after=before)
        return apply_multipump(g, targets=targets, factor=m, mode=self.mode,
                               vmem_budget=self.vmem_budget)


# -------------------------------------------------------------- fifo depth --
@dataclasses.dataclass
class DepthReport:
    resized: List[Tuple[str, int, int]] = dataclasses.field(default_factory=list)

    def __repr__(self):  # pragma: no cover
        return f"DepthReport(resized={len(self.resized)})"


def _endpoint_factor(g: Graph, name: str) -> int:
    """Temporal multiplicity a module imposes on an adjacent FIFO."""
    n = g.nodes[name]
    if n.kind in (NodeKind.ISSUER, NodeKind.PACKER):
        return int(n.meta.get("factor", 1))
    if n.kind == NodeKind.COMPUTE and n.rate == RateDomain.FAST:
        return max(1, n.pump)
    if n.kind == NodeKind.SYNC:
        # the CDC FIFO buffers a full wide transaction while the fast side
        # drains M beats: look through to the issuer/packer on the other side
        nbrs = [e.dst for e in g.out_edges(name)] + \
               [e.src for e in g.in_edges(name)]
        return max((int(g.nodes[b].meta.get("factor", 1)) for b in nbrs
                    if g.nodes[b].kind in (NodeKind.ISSUER, NodeKind.PACKER)),
                   default=1)
    return 1


@register_pass
class FifoDepthPass(GraphPass):
    """Size ``Node.depth`` of every stream from the pump-factor mismatch of
    its endpoints: depth = 2 · max(M_producer, M_consumer), minimum 2."""

    name = "fifo-depth"

    def can_apply(self, g: Graph) -> Tuple[bool, str]:
        if g.streams():
            return True, f"{len(g.streams())} stream(s)"
        return False, "graph has no streams"

    def apply(self, g: Graph):
        out = g.copy()
        report = DepthReport()
        for s in out.streams():
            prod = [e.src for e in out.in_edges(s.name)]
            cons = [e.dst for e in out.out_edges(s.name)]
            m = max([_endpoint_factor(out, n) for n in prod + cons] or [1])
            depth = max(2, 2 * m)
            if depth != s.depth:
                report.resized.append((s.name, s.depth, depth))
                s.depth = depth
        return out, report
