"""Graph→JAX lowering backend.

Compiles a (possibly streamed + multi-pumped) dataflow :class:`Graph` into a
``jax.jit``-able callable with the same semantics as the numpy reference
executor (:mod:`repro.core.executor`), which stays around as the differential-
testing oracle.  The lowering is a topological module schedule:

===========  ================================================================
IR node      JAX realization
===========  ================================================================
Memory       input array (or zeros) threaded through functionally
Reader       static gather ``jnp.take`` with addresses precomputed from the
             symbolic access pattern at lowering time
Writer       static scatter ``.at[idx].set``
Sync         ``jax.lax.optimization_barrier`` — value identity, but a real
             scheduling boundary under jit (the Pallas pipeline analogue of
             the paper's clock-domain-crossing synchronizer)
Issuer /     temporal re-chunking: a ``fori_loop`` over the pump factor M
Packer       copying one narrow phase per iteration (value identity — the
             paper's gearbox moves M narrow beats per wide transaction)
Compute      the node's ``fn`` body applied to its FIFO-ordered operand
             sequences; ``fn`` must be numpy/jax polymorphic (operator-based).
             Sequential-carry computes (``meta['carry']``) lower to a
             ``fori_loop`` over the step domain: per-step operand blocks are
             ``dynamic_slice``-cut from the sequences and the loop-carried
             state threads through the loop carry, resetting at each sweep
             of the carry axis (see :func:`carry_sequence_apply`)
Stream       value pass-through (FIFO order is the sequence order)
===========  ================================================================

Scatter targets with duplicate addresses are rejected at lowering time with
:class:`LoweringError` — the reference executor's last-write-wins order is
numpy-specific, and jax ``.at[].set`` makes no ordering guarantee, so a
duplicate-address scatter would silently produce backend-dependent results.
The error message names the offending producer→memory edge.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.executor import _toposort, carry_layout, sink_access
from repro.core.ir import Graph, NodeKind, PumpSpec


class LoweringError(RuntimeError):
    pass


def _temporal_rechunk(seq: jax.Array, factor: int,
                      warn: Optional[Callable[[str], None]] = None,
                      name: str = "") -> jax.Array:
    """Issuer/packer body: re-emit ``seq`` as ``factor`` narrow phases.

    Value-identity on the flattened FIFO sequence (a wide transaction of M·V
    elements is exactly its M consecutive narrow beats), realized as a
    ``fori_loop`` so the temporal iteration survives into the jaxpr.

    A sequence length not divisible by ``factor`` cannot be re-chunked into
    M equal beats; the gearbox degrades to a pass-through (still value-exact)
    and reports the misaligned pump factor through ``warn`` so the
    degradation is visible in the pipeline report instead of silent.
    """
    flat = jnp.reshape(seq, (-1,))
    n = flat.shape[0]
    if factor <= 1:
        return flat
    if n % factor:
        if warn is not None:
            warn(f"temporal-rechunk: {name or 'adapter'} sequence length "
                 f"{n} not divisible by pump factor {factor}; gearbox "
                 f"degraded to pass-through")
        return flat
    chunk = n // factor

    def body(m, out):
        beat = jax.lax.dynamic_slice(flat, (m * chunk,), (chunk,))
        return jax.lax.dynamic_update_slice(out, beat, (m * chunk,))

    return jax.lax.fori_loop(0, factor, body, jnp.zeros_like(flat))


def _indices(access, shape) -> np.ndarray:
    return np.fromiter(access.addresses(shape), dtype=np.int64)


def scatter_indices(access, shape, where: str = "") -> np.ndarray:
    """Freeze a *write* access into an index vector, validating that no
    address is written twice: the reference executor resolves duplicates by
    numpy's last-write-wins scatter order, which jax ``.at[].set`` does not
    guarantee, so a duplicate-address scatter lowers to backend-dependent
    results and is rejected here instead."""
    idx = _indices(access, shape)
    if np.unique(idx).size != idx.size:
        dup = int(idx.size - np.unique(idx).size)
        raise LoweringError(
            f"scatter {where or 'access'} writes {dup} duplicate address(es) "
            f"(e.g. a reduction dimension absent from the output pattern); "
            f"results would be backend-dependent last-write-wins")
    return idx


def _scatter(mem: jax.Array, idx: np.ndarray, seq) -> jax.Array:
    flat = jnp.reshape(mem, (-1,))
    vals = jnp.reshape(jnp.asarray(seq), (-1,)).astype(mem.dtype)
    return jnp.reshape(flat.at[idx].set(vals), mem.shape)


def _unflatten(step, extents):
    """Decompose a flat (possibly traced) index into lexicographic coords."""
    coords = []
    rem = step
    for ext in reversed(extents):
        coords.append(rem % ext)
        rem = rem // ext
    return tuple(reversed(coords))


def carry_sequence_apply(g: Graph, node) -> Callable[[Dict[str, Any]],
                                                     Dict[str, Any]]:
    """Lower one sequential-carry compute to a ``fori_loop`` over its step
    domain, operating on whole FIFO sequences.

    Returns ``run(bound) -> {"out0": seq, ...}`` where ``bound`` maps
    ``in{k}`` to the gathered operand sequences.  Each iteration cuts one
    block per operand out of its sequence, threads the carry state (reset at
    the start of every sweep of the carry axis — the paper's fast-domain
    accumulator staying inside the pumped region), and emits outputs per the
    :class:`~repro.core.ir.CarrySpec` partition: the leading ``step_outs``
    outputs append one block per step and the rest come from
    ``final_fn(state)`` once per sweep.
    """
    spec = node.meta["carry"]
    n_steps, sweep, in_blocks, out_blocks, outer_syms = carry_layout(g, node)
    outer_exts = node.domain.extents[:-1]
    out_edges = g.out_edges(node.name)
    n_out = len(out_edges)
    n_step_out = spec.n_step_outs(n_out)
    out_dtypes = []
    for e in out_edges:
        mem, _acc = sink_access(g, e)
        out_dtypes.append(mem.dtype if mem is not None else "float32")
    out_sizes = [int(np.prod(blk)) if blk is not None else None
                 for blk in out_blocks]
    if any(sz is None for sz in out_sizes):
        raise LoweringError(
            f"carry compute {node.name!r}: output access does not decompose "
            "into a blocked view")
    # per-step outputs emit one block per step; per-sweep (final) outputs
    # emit one block per sweep of the carry axis
    emits = [n_steps if k < n_step_out else n_steps // sweep
             for k in range(n_out)]

    def run(bound: Dict[str, Any]) -> Dict[str, Any]:
        seqs = [jnp.reshape(bound[f"in{k}"], (-1,))
                for k in range(len(in_blocks))]
        per_step = [s.shape[0] // n_steps for s in seqs]
        init_state = tuple(jnp.asarray(a) for a in spec.init_arrays(jnp))
        bufs = tuple(jnp.zeros(emits[k] * out_sizes[k], dtype=out_dtypes[k])
                     for k in range(n_out))

        def body(i, st):
            carry, bufs_t = st
            pos = i % sweep
            first = pos == 0
            carry = tuple(jnp.where(first, ini, cur)
                          for ini, cur in zip(init_state, carry))
            blocks = []
            for k, seq in enumerate(seqs):
                blk = jax.lax.dynamic_slice(seq, (i * per_step[k],),
                                            (per_step[k],))
                if in_blocks[k] is not None:
                    blk = jnp.reshape(blk, in_blocks[k])
                blocks.append(blk)
            kwargs = {}
            if spec.pass_idx:
                kwargs["idx"] = dict(
                    step=pos, outer=_unflatten(i // sweep, outer_exts),
                    pump=0)
            carry2, souts = spec.step_fn(carry, *blocks, **kwargs)
            new_bufs = list(bufs_t)
            for k in range(n_step_out):
                new_bufs[k] = jax.lax.dynamic_update_slice(
                    bufs_t[k],
                    jnp.reshape(souts[f"out{k}"],
                                (-1,)).astype(bufs_t[k].dtype),
                    (i * out_sizes[k],))
            if spec.final_fn is not None:
                fouts = spec.final_fn(carry2)
                j = i // sweep
                last = pos == sweep - 1
                for k in range(n_step_out, n_out):
                    new_bufs[k] = jnp.where(
                        last,
                        jax.lax.dynamic_update_slice(
                            bufs_t[k],
                            jnp.reshape(fouts[f"out{k}"],
                                        (-1,)).astype(bufs_t[k].dtype),
                            (j * out_sizes[k],)),
                        bufs_t[k])
            return carry2, tuple(new_bufs)

        _carry, bufs = jax.lax.fori_loop(0, n_steps, body, (init_state, bufs))
        return {f"out{k}": bufs[k] for k in range(n_out)}

    return run


def lower(g: Graph, jit: bool = True,
          warn: Optional[Callable[[str], None]] = None
          ) -> Callable[[Mapping[str, Any]], Dict[str, jax.Array]]:
    """Lower ``g`` to a callable ``fn(inputs) -> {memory name: array}``.

    ``inputs`` maps memory-node names to arrays (missing memories start as
    zeros, as in the reference executor).  The graph must not be mutated
    after lowering: access-pattern gathers/scatters are frozen here.
    ``warn`` receives human-readable degradation notes (e.g. a pump factor
    that does not divide a sequence length) at lowering/trace time.
    """
    g.validate()
    order = _toposort(g)

    # freeze every symbolic access into a static index vector
    idx_of: Dict[int, np.ndarray] = {}
    for e in g.edges:
        if e.access is None:
            continue
        src, dst = g.nodes[e.src], g.nodes[e.dst]
        if src.kind == NodeKind.MEMORY and dst.kind in (NodeKind.READER,
                                                        NodeKind.COMPUTE):
            idx_of[id(e)] = _indices(e.access, src.shape)
        elif dst.kind == NodeKind.MEMORY and src.kind in (NodeKind.WRITER,
                                                          NodeKind.COMPUTE):
            idx_of[id(e)] = scatter_indices(e.access, dst.shape,
                                            where=f"{e.src}->{e.dst}")

    carry_fns: Dict[str, Callable] = {}
    for comp in g.computes():
        if comp.meta.get("carry") is not None:
            carry_fns[comp.name] = carry_sequence_apply(g, comp)
        elif comp.fn is None:
            raise LoweringError(
                f"compute module {comp.name!r} has no fn body to lower")

    def run_fn(inputs: Mapping[str, Any]) -> Dict[str, jax.Array]:
        mems: Dict[str, jax.Array] = {}
        for n in g.nodes.values():
            if n.kind != NodeKind.MEMORY:
                continue
            if n.name in inputs:
                mems[n.name] = jnp.asarray(inputs[n.name], dtype=n.dtype)
            else:
                mems[n.name] = jnp.zeros(n.shape, dtype=n.dtype)

        edge_val: Dict[int, jax.Array] = {}
        for name in order:
            node = g.nodes[name]
            ins, outs = g.in_edges(name), g.out_edges(name)
            if node.kind == NodeKind.MEMORY:
                continue  # gathers happen at the consumer
            if node.kind == NodeKind.READER:
                e = ins[0]
                flat = jnp.reshape(mems[e.src], (-1,))
                edge_val[id(outs[0])] = jnp.take(flat, idx_of[id(e)])
            elif node.kind == NodeKind.WRITER:
                e = outs[0]
                mems[e.dst] = _scatter(mems[e.dst], idx_of[id(e)],
                                       edge_val[id(ins[0])])
            elif node.kind == NodeKind.SYNC:
                edge_val[id(outs[0])] = jax.lax.optimization_barrier(
                    edge_val[id(ins[0])])
            elif node.kind in (NodeKind.ISSUER, NodeKind.PACKER):
                factor = int(node.meta.get("factor", 1))
                edge_val[id(outs[0])] = _temporal_rechunk(
                    edge_val[id(ins[0])], factor, warn=warn, name=node.name)
            elif node.kind == NodeKind.STREAM:
                edge_val[id(outs[0])] = edge_val[id(ins[0])]
            elif node.kind == NodeKind.COMPUTE:
                bound = {}
                for k, e in enumerate(ins):
                    src = g.nodes[e.src]
                    if src.kind == NodeKind.MEMORY and e.access is not None:
                        flat = jnp.reshape(mems[e.src], (-1,))
                        bound[f"in{k}"] = jnp.take(flat, idx_of[id(e)])
                    else:
                        bound[f"in{k}"] = edge_val[id(e)]
                if name in carry_fns:
                    result = carry_fns[name](bound)
                else:
                    result = node.fn(**bound)
                if not isinstance(result, dict):
                    result = {"out0": result}
                for k, e in enumerate(outs):
                    seq = result[f"out{k}"]
                    if g.nodes[e.dst].kind == NodeKind.MEMORY \
                            and e.access is not None:
                        mems[e.dst] = _scatter(mems[e.dst], idx_of[id(e)], seq)
                    else:
                        edge_val[id(e)] = seq
            else:  # pragma: no cover
                raise LoweringError(f"cannot lower node kind {node.kind}")
        return mems

    # surface adapter degradation warnings eagerly: an abstract trace costs
    # one eval_shape but moves trace-time warnings into the compile report
    # instead of deferring them to the first real call
    if warn is not None and any(
            n.kind in (NodeKind.ISSUER, NodeKind.PACKER)
            and int(n.meta.get("factor", 1)) > 1 for n in g.nodes.values()):
        try:
            jax.eval_shape(run_fn, {
                n.name: jax.ShapeDtypeStruct(n.shape, n.dtype)
                for n in g.nodes.values() if n.kind == NodeKind.MEMORY})
        except Exception:   # probe only; real errors surface on execution
            pass

    return jax.jit(run_fn) if jit else run_fn


@dataclasses.dataclass
class CompiledKernel:
    """The artifact :func:`repro.compiler.compile` returns.

    ``graph`` is the transformed IR, ``spec`` the kernel-layer pump spec,
    ``report`` the pipeline provenance (incl. cache bookkeeping), and ``fn``
    the executable (None when compiled with ``backend='none'``).
    """

    graph: Graph
    spec: PumpSpec
    report: Any
    fn: Optional[Callable]
    backend: str = "jax"

    def __call__(self, inputs: Mapping[str, Any]) -> Dict[str, Any]:
        if self.fn is None:
            raise LoweringError(
                "kernel was compiled with backend='none'; re-compile with "
                "backend='jax' or 'reference' to execute it")
        return self.fn(inputs)
