"""Process-level plan registry: shape-bucketed measured execution plans.

The compile cache (:mod:`.cache`) makes a *repeat* compile O(1), but the
serving layers never see that win when every decode step arrives with a
slightly different shape — each (batch, seq) pair is a distinct graph and a
cold ``autotune='measure'`` search.  The registry closes that gap:

* **Shape bucketing** — batch and sequence dims are padded up to a small
  ladder of buckets (powers of two by default), so the unbounded space of
  serve-time shapes collapses onto a handful of graphs.  Padding is value-
  preserving by construction: attention pads KV only under a causal mask
  (padded keys sit at positions no real query may attend), the SSD scan pads
  timesteps with ``dt=0`` (an identity step for the carried state), and the
  grouped GEMM pads rows with zeros whose outputs are sliced away.
* **Measured plans** — every bucket compiles through
  ``compiler.compile(autotune='measure', backend='pallas')``: the pump
  factor M is chosen from measured runtimes, persisted in the compile cache,
  and replayed (no re-measurement) by every later process.
* **Warm lookup** — an in-process ``{request → CompiledKernel}`` map serves
  steady-state decode in O(1); :meth:`PlanRegistry.warmup` pre-measures the
  whole bucket grid at launch so the first real request is already a hit.

``models/*`` route their kernel hot paths here when
``ModelConfig.kernel_plan == 'measure'`` (the default); the direct
``kernels.ops`` path stays available behind ``kernel_plan='direct'`` as the
differential reference.  A corrupted persistent cache degrades to a cold
compile (the :class:`~repro.compiler.cache.CompileCache` contract); a
lowering failure degrades to the direct ops path with a visible warning.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro import obs
from repro.testing import faults


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _fit_block(block: int, n: int) -> int:
    """Largest block size ≤ ``block`` that divides ``n`` (n ≥ 1)."""
    cand = min(block, n)
    if n % cand:
        cand = math.gcd(n, cand)
    return max(cand, 1)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """How call shapes are rounded up to plan buckets.

    ``seq_min``/``batch_min`` floor the respective ladders; buckets are the
    powers of two above the floor, so a growing decode context touches
    O(log T) plans instead of O(T).  ``row_block`` is the ragged grouped-GEMM
    row tile: each expert's token group pads to a power-of-two multiple of
    it (0 stays 0 — empty experts contribute no tiles at all).
    """
    seq_min: int = 16
    batch_min: int = 1
    row_block: int = 16

    def bucket_seq(self, n: int, multiple: int = 1) -> int:
        b = max(self.seq_min, _next_pow2(max(n, 1)))
        if multiple > 1 and b % multiple:
            b = -(-b // multiple) * multiple
        return b

    def bucket_batch(self, n: int) -> int:
        return max(self.batch_min, _next_pow2(max(n, 1)))

    def bucket_pos(self, pos) -> int:
        """Decode pos bucket: the seq bucket covering slots ``0..pos``.

        Accepts a scalar or a per-slot ``(B,)`` vector of in-flight
        positions (continuous batching) — a ragged batch buckets on its
        *furthest* row, so every lane's prefix fits one shared plan and
        shorter lanes just mask more."""
        import numpy as _np
        return self.bucket_seq(int(_np.max(_np.asarray(pos))) + 1)

    def bucket_group(self, n: int) -> int:
        """Ragged group-size bucket: 0, or a pow2 multiple of row_block."""
        if n <= 0:
            return 0
        tiles = -(-n // self.row_block)
        return self.row_block * _next_pow2(tiles)

    def seq_grid(self, max_len: int, multiple: int = 1) -> List[int]:
        """All seq buckets from the floor up to ``bucket_seq(max_len)``."""
        top = self.bucket_seq(max_len, multiple)
        out, b = [], self.bucket_seq(1, multiple)
        while b < top:
            out.append(b)
            b = self.bucket_seq(b + 1, multiple)
        out.append(top)
        return out


# the S=1 serving fast path: plans keyed by these kernels are counted under
# the "decode" phase so a cold decode bucket is visible at a glance in the
# registry-stats printout (everything else is "prefill" — prefill, scoring
# and benchmark forward plans)
DECODE_KERNELS = frozenset({"decode_attention", "ssd_decode"})


def _phase_of(kernel: str) -> str:
    return "decode" if kernel in DECODE_KERNELS else "prefill"


@dataclasses.dataclass
class RegistryStats:
    """Hit/miss/fallback accounting, split by serving phase.

    Counts mirror into the process-wide obs metrics registry
    (``registry.{phase}.{hit|miss}``, ``registry.fallback.{phase}``) so the
    unified snapshot carries them; the dataclass itself stays the per-
    instance view (tests and benchmarks diff instances around a window, so
    the local counters are not replaced by the global ones).  The active
    default registry additionally publishes ``as_dict()`` as the
    ``plan_registry`` snapshot view.
    """
    hits: int = 0
    misses: int = 0
    measure_s: float = 0.0    # cold measured-autotune compiles
    compile_s: float = 0.0    # replayed / non-measured compiles
    fallbacks: int = 0        # lookups that fell back to the direct path
    # per-phase split of hits/misses/fallbacks (see DECODE_KERNELS)
    phase: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=lambda: {
            "prefill": {"hits": 0, "misses": 0, "fallbacks": 0},
            "decode": {"hits": 0, "misses": 0, "fallbacks": 0}})

    def count(self, kernel: str, hit: bool) -> None:
        ph = _phase_of(kernel)
        bucket = self.phase[ph]
        if hit:
            self.hits += 1
            bucket["hits"] += 1
            obs.count(f"registry.{ph}.hit", kernel=kernel)
        else:
            self.misses += 1
            bucket["misses"] += 1
            obs.count(f"registry.{ph}.miss", kernel=kernel)

    def fallback(self, kernel: str, why: str = "") -> None:
        """A lookup that fell back to the direct path — split per phase so
        a decode-path fallback (the highest-frequency path) is visible at a
        glance instead of buried in a global total."""
        ph = _phase_of(kernel)
        self.fallbacks += 1
        self.phase[ph]["fallbacks"] += 1
        obs.count(f"registry.fallback.{ph}", kernel=kernel, why=why)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": round(self.hit_rate, 4),
                "measure_s": round(self.measure_s, 4),
                "compile_s": round(self.compile_s, 4),
                "fallbacks": self.fallbacks,
                "prefill": dict(self.phase["prefill"]),
                "decode": dict(self.phase["decode"])}


class PlanRegistry:
    """Shape-bucketed front for ``compiler.compile`` on the serving path.

    ``pump`` is ``'measure'`` (measured-runtime autotune, the default),
    ``'auto'`` (capacity model) or an explicit int factor.  ``cache`` is a
    :class:`~repro.compiler.cache.CompileCache`, ``None`` for the default
    persistent cache or ``False`` to disable disk persistence.
    """

    def __init__(self, policy: Optional[BucketPolicy] = None, *,
                 pump="measure", ragged_pump="auto", backend: str = "pallas",
                 cache=None, spot_check: str = "finite"):
        self.policy = policy or BucketPolicy()
        self.pump = pump
        # ragged grouped-GEMM plans are keyed on the per-expert padded-size
        # tuple, which shifts with routing: a measured autotune (seconds of
        # timing runs) on every fresh tuple would land mid-request, so the
        # ragged path defaults to capacity-model planning ('auto', a
        # milliseconds-cold compile).  Set ragged_pump='measure' only when
        # the routing patterns are known and pre-warmed.
        self.ragged_pump = ragged_pump
        self.backend = backend
        self._cache = cache
        # post-compile validation level: 'finite' runs every fresh kernel
        # once on small deterministic inputs and rejects non-finite output
        # (a NaN kernel must be caught at plan time, not inside the jit'd
        # decode step where values can't be branched on); 'diff' adds a
        # differential check against the numpy reference executor; 'off'
        # disables validation.
        self.spot_check = spot_check
        self._plans: Dict[Tuple, Any] = {}
        # wrapper-level fast path: raw call signature -> (plan, padded
        # dims).  The canonical plan key is derived through bucket math +
        # a sorted-kwargs tuple build on every lookup; at steady state
        # that per-call Python cost is the *whole* overhead of the
        # registry path vs a direct kernel call (the measured ~3%
        # prefill_flash gap), so warm wrapper calls memoize the full
        # resolution and skip straight to pad + execute.
        self._lookup: Dict[Tuple, Any] = {}
        # in-trace cold misses on a 'measure' policy are served from the
        # capacity-model plan space (see kernel()); memoized per key so a
        # long trace pays the warn + re-lookup recursion once, not per call
        self._trace_memo: Dict[Tuple, Any] = {}
        self.stats = RegistryStats()

    def _store(self):
        """The persistent CompileCache backing this registry (quarantine
        ledger access), or None when disk caching is disabled."""
        if self._cache is None:
            from .cache import default_cache
            return default_cache()
        return self._cache or None

    # ------------------------------------------------------------- lookup --
    def _request(self, pump=None) -> Tuple[Any, str, Optional[str]]:
        pump = self.pump if pump is None else pump
        if pump == "measure":
            return "auto", "T", "measure"
        if pump == "auto":
            return "auto", "T", None
        return int(pump), "T", None

    def kernel(self, kernel: str, builder_args: Tuple,
               builder_kwargs: Dict[str, Any], pump=None):
        """Compiled kernel for one canonical (bucketed) request — the only
        place the registry talks to the compiler.  ``pump`` overrides the
        registry-wide policy for this request (the ragged path uses it)."""
        pump = self.pump if pump is None else pump
        key = (kernel, tuple(builder_args),
               tuple(sorted(builder_kwargs.items())), pump, self.backend)
        if key in self._plans:
            self.stats.count(kernel, hit=True)
            return self._plans[key]
        from repro import compiler
        if pump == "measure" and not compiler._trace_state_clean():
            # a cold miss inside a jit trace must not run the measured
            # autotune (in-trace timings are garbage and catastrophically
            # slow): serve this lookup from the capacity-model plan space
            # instead, and leave the measure slot empty so warmup()/an
            # eager call can still fill it with a real measured plan.
            # Memoized per key: only the first in-trace miss pays the
            # warning + recursive re-lookup.
            hit = self._trace_memo.get(key)
            if hit is not None:
                self.stats.count(kernel, hit=True)
                return hit
            warnings.warn(
                f"plan registry: cold miss for {kernel}{tuple(builder_args)}"
                " inside a jax trace — using capacity-model planning; call "
                "warmup() at launch to pre-measure this bucket",
                stacklevel=3)
            kern = self.kernel(kernel, builder_args, builder_kwargs,
                               pump="auto")
            self._trace_memo[key] = kern
            return kern
        self.stats.count(kernel, hit=False)
        from repro.core.autopump import BUILDERS
        factor, mode, autotune = self._request(pump)
        with obs.span("registry.compile", cat="serve", kernel=kernel,
                      args=list(builder_args), pump=str(pump)) as sp:
            g, est = BUILDERS[kernel](*builder_args, **builder_kwargs)
            t0 = time.perf_counter()
            # compile through the degradation ladder: a pallas-backend
            # failure (or an open quarantine window on the pallas rung)
            # degrades to the per-node jax lowering instead of raising —
            # the wrapper-level plain-jnp fallback stays the last rung
            kern = compiler.compile_degraded(
                g, factor=factor, mode=mode, estimate=est,
                backend=self.backend, autotune=autotune, cache=self._cache)
            bad = self._spot_check_reason(kern)
            if bad is not None:
                # poisoned kernel (compiles fine, computes garbage): purge
                # the memo so the retry cannot be served the same artifact,
                # quarantine the rung that produced it, degrade once
                obs.count("registry.spotcheck_failed", kernel=kernel,
                          backend=kern.backend, reason=bad)
                ckey = kern.report.cache_key
                if ckey:
                    compiler.forget(ckey)
                store = self._store()
                if store is not None and ckey:
                    store.record_failure(f"{ckey}:{kern.backend}", bad)
                kern = compiler.compile_degraded(
                    g, factor=factor, mode=mode, estimate=est,
                    backend=self.backend, autotune=autotune,
                    cache=self._cache)
                bad2 = self._spot_check_reason(kern)
                if bad2 is not None:
                    raise RuntimeError(
                        f"plan registry: {kernel} failed the {bad!r} "
                        f"spot-check and its degraded recompile failed "
                        f"{bad2!r} — refusing to install the plan")
                kern.report.warn(
                    f"spot-check rejected the first compile ({bad}); "
                    f"serving the degraded recompile (backend="
                    f"{kern.backend})")
            dt = time.perf_counter() - t0
            tuned = kern.report.autotune
            if tuned and not tuned.get("replayed"):
                self.stats.measure_s += dt   # paid the timing runs
                obs.count("registry.measure", kernel=kernel)
            else:
                self.stats.compile_s += dt   # replayed plan / plain compile
                obs.count("registry.replay" if tuned
                          else "registry.plan_compile", kernel=kernel)
            sp.set(factor=kern.spec.factor,
                   measured=bool(tuned and not tuned.get("replayed")))
        if faults.active():
            # chaos seam: simulate a plan that fails/corrupts on the serving
            # path after installation (zero-cost in production: never taken)
            kern = dataclasses.replace(
                kern, fn=faults.wrap("registry.exec", kern.fn, kernel=kernel))
        self._plans[key] = kern
        return kern

    def _spot_check_reason(self, kern) -> Optional[str]:
        """Validate a freshly compiled kernel eagerly; returns the failure
        reason (``exec:*`` / ``nonfinite`` / ``diff:*``) or None.  Skipped
        inside jax traces (can't branch on values there — exactly why the
        check exists at plan time) and when validation is off."""
        from repro import compiler
        if (self.spot_check == "off" or kern.fn is None
                or not compiler._trace_state_clean()):
            return None
        import numpy as np
        inputs = _probe_inputs(kern.graph)
        try:
            out = kern.fn(inputs)
        except Exception as e:  # noqa: BLE001 — any exec failure poisons it
            return f"exec:{type(e).__name__}"
        vals = out.items() if isinstance(out, dict) else [("out", out)]
        for name, a in vals:
            arr = np.asarray(a)
            if arr.dtype.kind == "f" and not np.isfinite(arr).all():
                return "nonfinite"
        if self.spot_check == "diff":
            from repro.core import executor
            ref = executor.run(kern.graph, dict(inputs))
            for name, a in (out.items() if isinstance(out, dict) else []):
                if name in inputs or name not in ref:
                    continue
                got, want = np.asarray(a, np.float64), \
                    np.asarray(ref[name], np.float64)
                if got.shape == want.shape and \
                        not np.allclose(got, want, rtol=1e-2, atol=1e-3):
                    return f"diff:{name}"
        return None

    def plans(self) -> List[Dict[str, Any]]:
        """Summaries of every resident plan (benchmark/report surface)."""
        out = []
        for (kernel, args, kwargs, pump, backend), kern in self._plans.items():
            tuned = kern.report.autotune or {}
            out.append({
                "kernel": kernel, "args": list(args),
                "factor": kern.spec.factor, "mode": kern.spec.mode,
                "pump": pump, "backend": backend,
                "measured": tuned.get("policy") == "measure",
                "replayed": bool(tuned.get("replayed")),
                "served_from": kern.report.served_from,
            })
        return out

    def reset(self) -> None:
        self._plans.clear()
        self._lookup.clear()
        self._trace_memo.clear()
        self.stats = RegistryStats()

    # ----------------------------------------------------------- requests --
    # Canonical (builder_args, builder_kwargs, padded dims) per kernel.
    # Wrappers and warmup() share these so a warmed bucket is a guaranteed
    # hit for the real call.
    def flash_request(self, *, b: int, h: int, hkv: int, s: int, t: int,
                      d: int, causal: bool, dtype: str, bq: int = 128,
                      bkv: int = 128):
        bb = self.policy.bucket_batch(b)
        sb = self.policy.bucket_seq(s)
        bq_e = _fit_block(bq, sb)
        # KV padding is masked out only under causality (padded keys sit at
        # positions ≥ every real query); non-causal keeps the exact length.
        tb = self.policy.bucket_seq(t) if causal else t
        bkv_e = _fit_block(bkv, tb)     # always divides tb
        itemsize = jnp.dtype(dtype).itemsize
        args = (bb, h, sb, tb, d)
        kwargs = dict(bq=bq_e, bkv=bkv_e, hkv=hkv, causal=causal,
                      dtype=dtype, itemsize=itemsize)
        return args, kwargs, (bb, sb, tb)

    def ssd_request(self, *, b: int, l: int, h: int, p: int, n: int,
                    chunk: int, n_groups: int, dtype: str,
                    final_state: bool = False):
        bb = self.policy.bucket_batch(b)
        lb = self.policy.bucket_seq(l)
        chunk_e = _fit_block(chunk, lb)
        itemsize = jnp.dtype(dtype).itemsize
        args = (bb, lb, h, p, n)
        kwargs = dict(chunk=chunk_e, n_groups=n_groups, dtype=dtype,
                      itemsize=itemsize, final_state=bool(final_state))
        return args, kwargs, (bb, lb)

    def decode_request(self, *, b: int, h: int, hkv: int, t: int, d: int,
                       dtype: str, bkv: int = 128):
        """S=1 decode attention bucket: ``t`` is the attended cache prefix
        (pos + 1 when the position is concrete, the full preallocated cache
        length under a jit trace) and buckets on the same pow2 ladder as
        prefill sequence dims — a growing decode context touches O(log T)
        plans, keyed separately from prefill by the kernel name."""
        bb = self.policy.bucket_batch(b)
        tb = self.policy.bucket_seq(t)
        bkv_e = _fit_block(bkv, tb)
        args = (bb, h, tb, d)
        kwargs = dict(bkv=bkv_e, hkv=hkv, dtype=dtype,
                      itemsize=jnp.dtype(dtype).itemsize)
        return args, kwargs, (bb, tb)

    def ssd_decode_request(self, *, b: int, h: int, p: int, n: int,
                           n_groups: int, dtype: str):
        bb = self.policy.bucket_batch(b)
        args = (bb, h, p, n)
        kwargs = dict(n_groups=n_groups, dtype=dtype,
                      itemsize=jnp.dtype(dtype).itemsize)
        return args, kwargs, (bb,)

    def grouped_request(self, *, e: int, d: int, f: int,
                        group_sizes: Sequence[int], dtype: str,
                        bf: int = 128, bd: int = 128):
        bc = self.policy.row_block
        padded = tuple(self.policy.bucket_group(int(sz))
                       for sz in group_sizes)
        bd_e, bf_e = _fit_block(bd, d), _fit_block(bf, f)
        # the execution path (ops.ragged_grouped_gemm_compiled) compiles
        # under the same canonical request — one source of truth, so a
        # warmed key always matches the real call's key
        from repro.kernels.ops import ragged_request_args
        args, kwargs = ragged_request_args(
            e, d, f, padded, bc, bf_e, bd_e, dtype,
            jnp.dtype(dtype).itemsize)
        return args, kwargs, padded

    # ------------------------------------------------------------ wrappers --
    def flash_attention(self, q, k, v, *, causal: bool = False,
                        bq: int = 128, bkv: int = 128):
        """Bucketed flash attention.  q: (B, H, S, D); k/v: (B, Hkv, T, D)."""
        b, h, s, d = q.shape
        hkv, t = k.shape[1], k.shape[2]
        lk = (b, h, hkv, s, t, d, causal, str(q.dtype), bq, bkv)
        hit = self._lookup.get(lk)
        if hit is not None:
            # warm fast path: signature -> installed plan, no bucket math
            kern, bb, sb, tb = hit
            self.stats.count("flash_attention", hit=True)
        else:
            try:
                args, kwargs, (bb, sb, tb) = self.flash_request(
                    b=b, h=h, hkv=hkv, s=s, t=t, d=d, causal=causal,
                    dtype=str(q.dtype), bq=bq, bkv=bkv)
                kern = self.kernel("flash_attention", args, kwargs)
            except Exception as e:  # noqa: BLE001 — serving must not die
                self.stats.fallback("flash_attention", why=str(e))
                warnings.warn(f"plan registry: flash_attention fell back to "
                              f"the direct ops path ({e})", stacklevel=2)
                from repro.kernels.ops import flash_attention as _flash
                return _flash(q, k, v, causal=causal, bq=bq, bkv=bkv)
            from repro import compiler
            if compiler._trace_state_clean():
                # never memoize a traced resolution: an in-trace measure
                # miss serves a capacity plan, and freezing that into the
                # fast path would keep eager calls off the measured plan
                # warmup later installs
                self._lookup[lk] = (kern, bb, sb, tb)
        qp = _pad_axes(q, {0: bb, 2: sb})
        kp = _pad_axes(k, {0: bb, 2: tb})
        vp = _pad_axes(v, {0: bb, 2: tb})
        try:
            out = kern({"q": qp, "k": kp, "v": vp})["o"]
        except Exception as e:  # noqa: BLE001 — exec failure: degrade a rung
            self.stats.fallback("flash_attention", why=f"exec: {e}")
            warnings.warn(f"plan registry: flash_attention kernel execution "
                          f"fell back to the direct ops path ({e})",
                          stacklevel=2)
            from repro.kernels.ops import flash_attention as _flash
            return _flash(q, k, v, causal=causal, bq=bq, bkv=bkv)
        if (bb, sb) == (b, s):
            return out          # exact bucket: skip the slice dispatch
        return out[:b, :, :s, :]

    def ssd_scan(self, x, dt, A, B, C, *, chunk: int = 16,
                 final_state: bool = False):
        """Bucketed SSD scan.  x: (B, L, H, P); dt zero-padding is an
        identity step for the carried state, so L-padding is exact — which
        also makes the ``final_state=True`` form exact: padded steps leave
        the carried state untouched, so the padded sweep's final state *is*
        the real final state.  Returns y, or ``(y, state)`` with
        ``final_state=True`` (state: (B, H, N, P) fp32 — the cached-prefill
        route)."""
        b, l, h, p = x.shape
        grp, n = B.shape[2], B.shape[3]
        try:
            args, kwargs, (bb, lb) = self.ssd_request(
                b=b, l=l, h=h, p=p, n=n, chunk=chunk, n_groups=grp,
                dtype=str(x.dtype), final_state=final_state)
            kern = self.kernel("ssd_scan", args, kwargs)
        except Exception as e:  # noqa: BLE001
            self.stats.fallback("ssd_scan", why=str(e))
            if final_state:
                # ops.ssd_scan(final_state=True) is compiler-only and would
                # re-raise on the same failure; degrade to the sequential
                # jnp recurrence, which does produce the final state
                warnings.warn(f"plan registry: ssd_scan fell back to the "
                              f"plain jnp scan ({e})", stacklevel=2)
                return _ssd_scan_reference(x, dt, A, B, C)
            warnings.warn(f"plan registry: ssd_scan fell back to the direct "
                          f"ops path ({e})", stacklevel=2)
            from repro.kernels.ops import ssd_scan as _ssd
            return _ssd(x, dt, A, B, C, chunk=chunk)
        xp = _pad_axes(x, {0: bb, 1: lb})
        dtp = _pad_axes(dt, {0: bb, 1: lb})
        bp = _pad_axes(B, {0: bb, 1: lb})
        cp = _pad_axes(C, {0: bb, 1: lb})
        try:
            out = kern({"x": xp, "dt": dtp, "a": A, "bmat": bp, "cmat": cp})
        except Exception as e:  # noqa: BLE001 — exec failure: degrade a rung
            self.stats.fallback("ssd_scan", why=f"exec: {e}")
            warnings.warn(f"plan registry: ssd_scan kernel execution fell "
                          f"back to the plain jnp scan ({e})", stacklevel=2)
            y, st = _ssd_scan_reference(x, dt, A, B, C)
            return (y, st) if final_state else y
        y = out["y"]
        if final_state:
            st = out["state"]
            if (bb, lb) == (b, l):
                return y, st
            return y[:b, :l], st[:b]
        if (bb, lb) == (b, l):
            return y            # exact bucket: skip the slice dispatch
        return y[:b, :l]

    def decode_attention(self, q, k_cache, v_cache, pos, *, bkv: int = 128):
        """Kernelized S=1 decode: one query row against the preallocated
        KV cache.  q: (B, H, D); caches: (B, Hkv, T, D); ``pos`` is the
        current write position (scalar or (B,) int32 — valid cache slots
        are 0..pos, enforced by the kernel's symbolic position mask).

        With a *concrete* ``pos`` (eager serving / benchmarks) the cache is
        sliced to the pos bucket before the call, so a decode step costs
        O(bucket(pos)), not O(max_len); a traced ``pos`` (the jit'd engine
        decode step) keys one plan on the full preallocated length and lets
        the mask do the work."""
        import jax
        b, h, d = q.shape
        hkv, t = k_cache.shape[1], k_cache.shape[2]
        try:
            if jnp.ndim(pos):
                # per-slot (B,) positions: a ragged in-flight batch from the
                # continuous-batching scheduler.  Counted so the serving
                # telemetry shows how much decode traffic is ragged.
                obs.count("registry.decode.ragged_pos")
            concrete = not isinstance(pos, jax.core.Tracer)
            # per-row (B,) positions bucket on the furthest row
            # (BucketPolicy.bucket_pos): every row's own mask still cuts
            # its prefix, shorter rows just mask more
            t_req = min(self.policy.bucket_pos(pos), t) if concrete else t
            args, kwargs, (bb, tb) = self.decode_request(
                b=b, h=h, hkv=hkv, t=t_req, d=d, dtype=str(q.dtype), bkv=bkv)
            kern = self.kernel("decode_attention", args, kwargs)
        except Exception as e:  # noqa: BLE001 — serving must not die
            self.stats.fallback("decode_attention", why=str(e))
            warnings.warn(f"plan registry: decode_attention fell back to "
                          f"the plain jnp path ({e})", stacklevel=2)
            return _decode_reference(q, k_cache, v_cache, pos)
        t_keep = min(tb, t)     # bucket ≥ pos+1, so no valid slot is cut
        qp = _pad_axes(q, {0: bb})
        kp = _pad_axes(k_cache[:, :, :t_keep], {0: bb, 2: tb})
        vp = _pad_axes(v_cache[:, :, :t_keep], {0: bb, 2: tb})
        pp = _pad_axes(_pos_vec(pos, b), {0: bb})
        try:
            out = kern({"q": qp, "k": kp, "v": vp, "pos": pp})["o"]
        except Exception as e:  # noqa: BLE001 — exec failure: degrade a rung
            self.stats.fallback("decode_attention", why=f"exec: {e}")
            warnings.warn(f"plan registry: decode_attention kernel execution "
                          f"fell back to the plain jnp path ({e})",
                          stacklevel=2)
            return _decode_reference(q, k_cache, v_cache, pos)
        if bb == b:
            return out
        return out[:b]

    def ssd_decode(self, state, x, dt, A, B, C):
        """Kernelized single-token SSD state update.  state: (B, H, N, P)
        fp32; x: (B, H, P); dt: (B, H) (post-softplus); A: (H,); B/C:
        (B, G, N).  Returns (y, new_state).  Batch padding is exact: padded
        rows carry dt = 0 (identity state step) and are sliced away."""
        b, h, n, p = state.shape
        grp = B.shape[1]
        try:
            args, kwargs, (bb,) = self.ssd_decode_request(
                b=b, h=h, p=p, n=n, n_groups=grp, dtype=str(x.dtype))
            kern = self.kernel("ssd_decode", args, kwargs)
        except Exception as e:  # noqa: BLE001
            self.stats.fallback("ssd_decode", why=str(e))
            warnings.warn(f"plan registry: ssd_decode fell back to the "
                          f"plain jnp path ({e})", stacklevel=2)
            return _ssd_decode_reference(state, x, dt, A, B, C)
        try:
            out = kern({"state": _pad_axes(state, {0: bb}),
                        "x": _pad_axes(x, {0: bb}),
                        "dt": _pad_axes(dt, {0: bb}), "a": A,
                        "bmat": _pad_axes(B, {0: bb}),
                        "cmat": _pad_axes(C, {0: bb})})
        except Exception as e:  # noqa: BLE001 — exec failure: degrade a rung
            self.stats.fallback("ssd_decode", why=f"exec: {e}")
            warnings.warn(f"plan registry: ssd_decode kernel execution fell "
                          f"back to the plain jnp path ({e})", stacklevel=2)
            return _ssd_decode_reference(state, x, dt, A, B, C)
        y, st = out["y"], out["state_out"]
        if bb == b:
            return y, st
        return y[:b], st[:b]

    def grouped_gemm(self, x, w, *, group_sizes: Sequence[int],
                     bf: int = 128, bd: int = 128):
        """Bucketed ragged grouped GEMM.  x: (sum(group_sizes), D) rows
        grouped by expert; w: (E, D, F).  Empty groups emit no tiles."""
        sizes = [int(sz) for sz in group_sizes]
        e, d, f = w.shape
        try:
            args, kwargs, padded = self.grouped_request(
                e=e, d=d, f=f, group_sizes=sizes, dtype=str(x.dtype),
                bf=bf, bd=bd)
            from repro.kernels.ops import ragged_grouped_gemm_compiled
            return ragged_grouped_gemm_compiled(
                x, w, sizes, padded, kwargs["bc"], kwargs["bf"],
                kwargs["bd"],
                kernel_fn=lambda a, kw: self.kernel("grouped_gemm", a, kw,
                                                    pump=self.ragged_pump))
        except Exception as err:  # noqa: BLE001 — serving must not die
            self.stats.fallback("grouped_gemm", why=str(err))
            warnings.warn(f"plan registry: grouped_gemm fell back to "
                          f"per-group matmul ({err})", stacklevel=2)
            # compiler-free reference: one matmul per non-empty group
            outs, off = [], 0
            for ei, sz in enumerate(sizes):
                if sz:
                    outs.append(x[off:off + sz] @ w[ei])
                off += sz
            if not outs:
                return jnp.zeros((0, f), x.dtype)
            return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    # ----------------------------------------------------------- artifact --
    def preload_artifact(self, path) -> Dict[str, Any]:
        """Warm-start from a published plan artifact (:mod:`repro.tune`):
        verify each manifest entry, install the verified plans into this
        registry's backing store, and let the subsequent :meth:`warmup`
        *replay* them — zero autotune measurements on the replica.

        Degrades per entry, never whole-artifact: a ``corrupt`` (hash
        mismatch), ``stale`` (other jax build), ``missing`` (no manifest
        row) or ``invalid`` entry is rejected (``artifact.rejected``) and
        recorded in the store's quarantine ledger under ``<key>:artifact``
        — a suffix :func:`repro.compiler.compile` never gates on, so the
        local re-measure through the existing degradation ladder proceeds
        and only the artifact provenance is marked bad.  An unreadable or
        wrong-schema artifact degrades to an empty preload (full local
        warmup), counted ``artifact.load_failed``."""
        from repro.tune import artifact as artifact_mod
        report: Dict[str, Any] = {"path": str(path), "total": 0,
                                  "verified": 0, "rejected": 0,
                                  "missing": 0, "reasons": {}}
        try:
            doc = artifact_mod.load(path)
        except Exception as e:  # noqa: BLE001 — unreadable artifact:
            # the replica simply tunes locally, as if no artifact existed
            obs.count("artifact.load_failed", path=str(path),
                      error=type(e).__name__)
            report["error"] = repr(e)
            return report
        store = self._store()
        entries = doc["entries"]
        manifest = doc["manifest"]
        report["total"] = len(entries)
        report["missing"] = len(doc.get("missing", []))
        verified: Dict[str, dict] = {}
        for key, plan in entries.items():
            try:
                reason = artifact_mod.verify_entry(key, plan,
                                                   manifest.get(key))
            except Exception as e:  # noqa: BLE001 — injected/exotic
                # verification failure: treat as a rejected entry
                reason = f"verify-error:{type(e).__name__}"
            if reason is None:
                verified[key] = plan
                obs.count("artifact.verified", key=key)
            else:
                report["rejected"] += 1
                report["reasons"][reason] = \
                    report["reasons"].get(reason, 0) + 1
                obs.count("artifact.rejected", key=key, reason=reason)
                if store is not None:
                    store.record_failure(f"{key}:artifact",
                                         f"artifact:{reason}")
        report["verified"] = len(verified)
        if store is not None and verified:
            store.put_many(verified)
        return report

    # ------------------------------------------------------------- warmup --
    def warmup(self, requests) -> List[Dict[str, Any]]:
        """Pre-measure the bucket grid: ``requests`` is an iterable of
        ``(kernel, shape_kwargs)`` descriptors (see
        ``models.transformer.plan_requests``).  Returns one record per
        request: the chosen factor, whether the plan was freshly measured or
        replayed from the persistent cache, and the wall time paid."""
        canon = {"flash_attention": self.flash_request,
                 "ssd_scan": self.ssd_request,
                 "grouped_gemm": self.grouped_request,
                 "decode_attention": self.decode_request,
                 "ssd_decode": self.ssd_decode_request}
        requests = list(requests)
        report = []
        surfaced: List[str] = []
        failed = 0
        with obs.span("registry.warmup", cat="serve",
                      requests=len(requests)) as wspan:
            for kernel, spec in requests:
                t0 = time.perf_counter()
                # per-request isolation: one unplannable bucket (bad shape,
                # exhausted ladder, injected fault) yields a failure record,
                # not an aborted grid — warmup always returns a partial-but-
                # usable report and the surviving buckets still serve hits
                try:
                    args, kwargs, _pads = canon[kernel](**spec)
                    # ragged requests must warm under the same pump policy
                    # the serving wrapper will look them up with
                    pump = self.ragged_pump if kernel == "grouped_gemm" \
                        else None
                    kern = self.kernel(kernel, args, kwargs, pump=pump)
                except Exception as e:  # noqa: BLE001
                    failed += 1
                    obs.count("registry.warmup_failed", kernel=kernel,
                              error=type(e).__name__)
                    report.append({
                        "kernel": kernel, "args": list(spec.values()),
                        "factor": None, "measured": False, "replayed": False,
                        "time_s": round(time.perf_counter() - t0, 4),
                        "tiers": [], "degraded": [], "error": repr(e),
                    })
                    continue
                for msg in kern.report.warnings:
                    if msg not in surfaced:
                        surfaced.append(msg)
                tuned = kern.report.autotune or {}
                emission = kern.report.emission or {}
                # the winner's measured kernel time (µs) rides along —
                # fresh *and* replayed plans carry timings_us, so the
                # engine can seed the scheduler's step-time model from
                # real plan speed (Engine.measured_step_time_ms)
                winner_us = tuned.get("timings_us", {}).get(
                    str(tuned.get("winner")))
                rec = {
                    "kernel": kernel, "args": list(args),
                    "factor": kern.spec.factor,
                    "measured": tuned.get("policy") == "measure",
                    "replayed": bool(tuned.get("replayed")),
                    "winner_us": winner_us,
                    "time_s": round(time.perf_counter() - t0, 4),
                    # per-region emission tiers + the degradation reason
                    # strings, so a warmup record alone answers "did this
                    # bucket emit at the fast tier, and if not, why"
                    "tiers": sorted({v["tier"] for v in emission.values()}),
                    "degraded": sorted({w for v in emission.values()
                                        for w in v.get("why", [])}),
                }
                report.append(rec)
            wspan.set(failed=failed)
        # compile warnings are deduplicated across the whole sweep: the same
        # degradation note recurs for every bucket of a kernel, and launch
        # output should name each unique condition once, not once per compile
        for msg in surfaced:
            warnings.warn(f"plan warmup: {msg}", stacklevel=2)
        return report


def _probe_inputs(g) -> Dict[str, Any]:
    """Small deterministic non-zero operands for the plan spot-check: a
    fixed repeating pattern in [-0.75, 0.75] per external input memory
    (zeros would make the differential check vacuous; integer inputs —
    decode positions — land at 0, which is always a valid position)."""
    import numpy as np
    from repro.core.ir import NodeKind
    out = {}
    for n in g.nodes.values():
        if n.kind != NodeKind.MEMORY or g.in_edges(n.name):
            continue
        size = max(int(np.prod(n.shape)) if n.shape else 1, 1)
        vals = (((np.arange(size) % 7) - 3) / 4.0).reshape(n.shape or ())
        out[n.name] = vals.astype(n.dtype)
    return out


def _pad_axes(arr, targets: Dict[int, int]):
    """Zero-pad ``arr`` up to ``targets[axis]`` on each listed axis."""
    pads = [(0, 0)] * arr.ndim
    dirty = False
    for axis, tgt in targets.items():
        cur = arr.shape[axis]
        if tgt > cur:
            pads[axis] = (0, tgt - cur)
            dirty = True
    return jnp.pad(arr, pads) if dirty else arr


def _pos_vec(pos, b: int):
    """Normalize a scalar/per-row decode position into an int32 (b,)."""
    p = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(jnp.atleast_1d(p), (b,))


def _decode_reference(q, k_cache, v_cache, pos):
    """Plain-jnp decode attention (the registry's loud-failure fallback —
    the same math as ``models.attention.decode_attention``, inlined here to
    keep ``repro.compiler`` free of model-layer imports)."""
    b, h, d = q.shape
    hkv, t = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k_cache.astype(jnp.float32))
    mask = jnp.arange(t)[None, :] <= _pos_vec(pos, b)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    import jax
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)


def _ssd_scan_reference(x, dt, A, B, C):
    """Sequential jnp SSD recurrence with the final state (fallback for the
    ``final_state=True`` registry route — the chunked dual form in the
    kernel computes exactly this per-timestep recurrence)."""
    import jax
    b, l, h, p = x.shape
    n = B.shape[-1]
    hpg = h // B.shape[2]
    Bh = jnp.repeat(B, hpg, axis=2).astype(jnp.float32)      # (b, l, h, n)
    Ch = jnp.repeat(C, hpg, axis=2).astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp          # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(Af[None] * dtt)
        state = state * decay[..., None, None] \
            + (bt * dtt[..., None])[..., :, None] * xt[..., None, :]
        return state, jnp.einsum("bhn,bhnp->bhp", ct, state)

    init = jnp.zeros((b, h, n, p), jnp.float32)
    state, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
         jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
         jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def _ssd_decode_reference(state, x, dt, A, B, C):
    """Plain-jnp single-token SSD step (fallback / differential reference)."""
    h = x.shape[1]
    hpg = h // B.shape[1]
    Bh = jnp.repeat(B, hpg, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, hpg, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    st = state.astype(jnp.float32)
    decay = jnp.exp(A.astype(jnp.float32)[None] * dtf)
    st2 = st * decay[..., None, None] \
        + (Bh * dtf[..., None])[..., :, None] \
        * x.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Ch, st2)
    return y.astype(x.dtype), st2


# --------------------------------------------------------------- singleton --
_DEFAULT: Optional[PlanRegistry] = None

# publish the *active* default registry's stats into every metrics snapshot
# (a view, not a copy: RegistryStats stays the single implementation and the
# snapshot always reflects whichever instance is currently installed)
obs.register_view(
    "plan_registry",
    lambda: _DEFAULT.stats.as_dict() if _DEFAULT is not None else None)


def default_registry() -> PlanRegistry:
    """Process-wide registry the model layers share."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = PlanRegistry()
    return _DEFAULT


def set_default_registry(reg: Optional[PlanRegistry]) -> Optional[PlanRegistry]:
    """Swap the process-wide registry (tests/benchmarks); returns the old."""
    global _DEFAULT
    old, _DEFAULT = _DEFAULT, reg
    return old
