from . import failover
