"""Fault-tolerance runtime: failure detection, restart, elastic re-mesh,
straggler mitigation.

At 1000+-node scale the failure model is: a node (or its host) disappears
mid-step; the step's collectives dead-lock or error; the job controller
restarts the affected slice (or the whole job on a reduced mesh).  This
module implements the *framework side* of that contract:

  - :class:`Heartbeat` — cooperative failure detection for the training
    loop: workers stamp a monotonic step counter; a monitor marks a worker
    dead after ``timeout_s`` without progress (the CPU-container simulation
    of TPU-slice health checks).
  - :func:`run_with_recovery` — the restart loop: run train steps, on
    (injected or real) failure restore the latest valid checkpoint and
    continue; exactly-once data via the pipeline step saved in the
    checkpoint.
  - :func:`elastic_remesh` — rebuild shardings for a *different* data-axis
    degree and re-place a checkpoint onto it (scale 16→8 data shards after
    losing a pod slice, or grow back).
  - Straggler mitigation (design + hook): synchronous SPMD cannot drop a
    slow worker, but the *pump factor* gives a knob: a persistently slow
    host reduces its local pump M (fewer microbatches per sync) while fast
    hosts keep theirs; gradients stay mathematically consistent because the
    accumulated microbatch count is carried with the gradient (weighted
    all-reduce).  ``StragglerPolicy`` computes per-host pump factors from
    step-time EWMAs.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint import manager as ckpt


class FailureInjected(RuntimeError):
    """Raised by tests to simulate a node loss mid-training."""


@dataclasses.dataclass
class Heartbeat:
    timeout_s: float = 300.0
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)
    _step: Dict[int, int] = dataclasses.field(default_factory=dict)

    def stamp(self, worker: int, step: int, now: Optional[float] = None):
        self._last[worker] = now if now is not None else time.time()
        self._step[worker] = step

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [w for w, t in self._last.items() if now - t > self.timeout_s]

    def slowest(self) -> Optional[int]:
        if not self._step:
            return None
        return min(self._step, key=self._step.get)


@dataclasses.dataclass
class StragglerPolicy:
    """Per-host pump-factor rebalancing from step-time EWMAs."""

    base_pump: int = 4
    ewma: float = 0.9
    tolerance: float = 1.3      # hosts slower than 1.3× median get derated
    _t: Dict[int, float] = dataclasses.field(default_factory=dict)

    def observe(self, worker: int, step_time: float):
        prev = self._t.get(worker, step_time)
        self._t[worker] = self.ewma * prev + (1 - self.ewma) * step_time

    def pump_factors(self) -> Dict[int, int]:
        if not self._t:
            return {}
        med = float(np.median(list(self._t.values())))
        out = {}
        for w, t in self._t.items():
            derate = max(1, int(round(t / (med * self.tolerance))))
            out[w] = max(1, self.base_pump // derate)
        return out


def _restore_any(ckpt_root: str, like) -> Optional[tuple]:
    """Restore the newest checkpoint that actually restores, walking
    candidates newest-first.  ``latest_valid`` screens manifests by hash,
    but a checkpoint can still fail *restore* (payload corrupted in a way
    the manifest misses, torn metadata, injected fault) — a recovery loop
    that crashes on its own recovery data has negative value, so a failing
    candidate is counted (``failover.ckpt_skipped``) and the next-older one
    is tried.  Returns ``(tree, extra)`` or None when no candidate
    restores."""
    for s in sorted(ckpt.available_steps(ckpt_root), reverse=True):
        path = os.path.join(ckpt_root, f"step_{s:08d}")
        if not ckpt.verify(path):
            obs.count("failover.ckpt_skipped", step=str(s), why="hash")
            continue
        try:
            return ckpt.restore(path, like)
        except Exception as e:  # noqa: BLE001 — corrupt payload: try older
            obs.count("failover.ckpt_skipped", step=str(s),
                      why=type(e).__name__)
    return None


def run_with_recovery(train_fn: Callable[[Any, int], Any],
                      init_state: Any,
                      n_steps: int,
                      ckpt_root: str,
                      ckpt_every: int = 10,
                      state_to_tree: Callable = lambda s: s,
                      tree_to_state: Callable = lambda t, like: t,
                      max_restarts: int = 3) -> Any:
    """Run ``train_fn(state, step) -> state`` with checkpoint/restart.

    Any exception from ``train_fn`` (including injected failures) triggers
    restore-from-latest-valid and resumption at the checkpointed step; a
    corrupt latest checkpoint falls back to the previous valid one (and
    ultimately to a from-scratch restart) instead of crashing the loop.
    """
    state = init_state
    step = 0
    restarts = 0
    resumed = _restore_any(ckpt_root, state_to_tree(state))
    if resumed is not None:
        tree, extra = resumed
        state = tree_to_state(tree, state)
        step = extra["step"]

    while step < n_steps:
        try:
            state = train_fn(state, step)
            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                ckpt.save(ckpt_root, step, state_to_tree(state),
                          extra={"step": step})
        except Exception:  # noqa: BLE001 — any failure → restore path
            restarts += 1
            obs.count("failover.restart")
            if restarts > max_restarts:
                raise
            restored = _restore_any(ckpt_root, state_to_tree(state))
            if restored is None:
                state, step = init_state, 0
                continue
            tree, extra = restored
            state = tree_to_state(tree, state)
            step = extra["step"]
    return state


def elastic_remesh(ckpt_dir: str, like_tree, new_mesh, spec_fn):
    """Re-place a checkpoint onto a new mesh (different axis sizes).

    ``spec_fn(tree, mesh) -> shardings`` is the same declarative rule table
    used at launch, so re-sharding needs no per-tensor bookkeeping: specs
    are recomputed for the new mesh and arrays are device_put under them.
    """
    shardings = spec_fn(like_tree, new_mesh)
    return ckpt.restore_resharded(ckpt_dir, like_tree, new_mesh, shardings)
