"""``repro.tune`` — fault-tolerant offline autotuning.

Splits plan tuning from serving: an offline worker fleet measures the
(kernel × bucket × pump-factor) grid once, publishes a verified plan
artifact, and every serving replica warm-starts from it with **zero**
autotune measurements (``launch.serve --plan-artifact``).

* :mod:`.grid` — enumerate the warmup grid, dedupe by compile-cache
  content hash (measure one representative per group).
* :mod:`.lease` — file-backed lease ledger: workers claim shards under
  heartbeat-stamped leases; an expired lease (dead worker) is reclaimed.
* :mod:`.worker` — the claim → measure → complete loop.
* :mod:`.artifact` — schema-versioned artifact with a per-entry verified
  manifest; partial-result salvage.

See docs/robustness.md "Artifact lifecycle" for the failure matrix.
"""
from . import artifact, grid, lease, worker
from .artifact import ARTIFACT_SCHEMA, load, publish, verify_entry
from .grid import WorkGroup, WorkItem, enumerate_work, shard_groups
from .lease import LeaseLedger
from .worker import TunerWorker, WorkerReport, run_fleet

__all__ = [
    "artifact", "grid", "lease", "worker",
    "ARTIFACT_SCHEMA", "load", "publish", "verify_entry",
    "WorkGroup", "WorkItem", "enumerate_work", "shard_groups",
    "LeaseLedger", "TunerWorker", "WorkerReport", "run_fleet",
]
