"""File-backed lease ledger: crash-tolerant work partitioning for the tuner.

One JSON file (schema-versioned, written atomically via mkstemp+rename,
mutated only under an ``fcntl`` lock on a ``.lock`` sibling — the exact
self-healing store idioms of :class:`repro.compiler.cache.CompileCache`)
holds one row per shard::

    {"version": 1,
     "shards": {"shard-0": {"state": "pending" | "leased" | "done",
                            "owner": "worker-a", "heartbeat": 1723...,
                            "expires": 1723..., "keys": [...],
                            "attempts": 2}}}

Lease semantics (docs/robustness.md "Artifact lifecycle"):

* **Claim** — a worker atomically flips a ``pending`` shard to ``leased``
  under its id, stamping a heartbeat and an expiry ``ttl_s`` in the future.
* **Heartbeat** — the owner re-stamps expiry between measurements; a
  heartbeat (or completion) by a worker that no longer owns the shard is
  rejected, which is what makes double-publish impossible after a reclaim.
* **Reclaim** — a lease whose expiry has passed is claimable by any worker
  (``tune.lease_reclaimed``): a worker SIGKILLed mid-measurement loses
  nothing but its own wall time — the shard returns to the pool and the
  survivor re-measures it (measurements are idempotent: they land in the
  content-hash-keyed compile cache, so a re-measure of half-done work
  replays the finished half for free).

Every ledger mutation passes the ``tune.lease`` fault-injection site, so a
chaos test can make any claim/heartbeat/complete raise mid-flight; all
ledger I/O failures degrade to "no lease" (the worker retries) rather than
crashing the fleet.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

try:
    import fcntl
except ImportError:  # pragma: no cover — non-POSIX: lockless best effort
    fcntl = None

from repro import obs
from repro.testing import faults

LEDGER_SCHEMA = 1


class LeaseLedger:
    """Shared lease state over one JSON file; safe across processes.

    Every operation is a full read-modify-write under the cross-process
    lock — the ledger file is the only authoritative state, so a worker
    process can die at any instruction without corrupting it."""

    def __init__(self, path: os.PathLike | str, *, ttl_s: float = 30.0):
        self.path = Path(path)
        self.ttl_s = float(ttl_s)

    # -- persistence (CompileCache idioms) -----------------------------------
    @contextlib.contextmanager
    def _lock(self):
        if fcntl is None:
            yield
            return
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            lockf = open(self.path.with_suffix(self.path.suffix + ".lock"),
                         "w")
        except OSError:
            yield
            return
        try:
            fcntl.flock(lockf, fcntl.LOCK_EX)
            yield
        finally:
            with contextlib.suppress(OSError):
                fcntl.flock(lockf, fcntl.LOCK_UN)
            lockf.close()

    def _read(self) -> Dict[str, dict]:
        try:
            with open(self.path) as f:
                data = json.loads(f.read())
            shards = data.get("shards", {})
            return {k: dict(v) for k, v in shards.items()
                    if isinstance(v, dict)}
        except FileNotFoundError:
            return {}
        except (OSError, ValueError, AttributeError, TypeError) as e:
            # a torn/corrupt ledger degrades to "empty" — init_shards can
            # rebuild it and nothing measured is lost (results live in the
            # compile cache, not here); the event is counted, never silent
            obs.count("tune.ledger_corrupt", path=str(self.path),
                      error=repr(e))
            return {}

    def _write(self, shards: Dict[str, dict]) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                   prefix=self.path.name, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump({"version": LEDGER_SCHEMA, "shards": shards}, f)
        os.replace(tmp, self.path)

    # -- ledger API ----------------------------------------------------------
    def init_shards(self, shard_keys: Dict[str, List[str]]) -> None:
        """Ensure one row per shard exists.  Idempotent and merge-safe:
        rows already present (any state — another worker may have finished
        them) are kept, so every worker can call this at startup."""
        with self._lock():
            faults.check("tune.lease", op="init", path=str(self.path))
            shards = self._read()
            dirty = False
            for name, keys in shard_keys.items():
                if name not in shards:
                    shards[name] = {"state": "pending", "owner": None,
                                    "heartbeat": None, "expires": None,
                                    "keys": list(keys), "attempts": 0}
                    dirty = True
            if dirty:
                self._write(shards)

    def claim(self, worker: str,
              now: Optional[float] = None) -> Optional[Tuple[str, List[str]]]:
        """Claim one shard for ``worker``: the first ``pending`` row, else
        the first ``leased`` row whose expiry has passed (a dead worker's
        lease — counted ``tune.lease_reclaimed``).  Returns ``(shard,
        keys)`` or None when nothing is claimable."""
        now = now if now is not None else time.time()
        with self._lock():
            faults.check("tune.lease", op="claim", worker=worker)
            shards = self._read()
            for name in sorted(shards):
                row = shards[name]
                state = row.get("state")
                expired = (state == "leased"
                           and now >= (row.get("expires") or 0.0))
                if state != "pending" and not expired:
                    continue
                if expired:
                    obs.count("tune.lease_reclaimed", shard=name,
                              dead_owner=str(row.get("owner")))
                row.update(state="leased", owner=worker, heartbeat=now,
                           expires=now + self.ttl_s,
                           attempts=int(row.get("attempts", 0)) + 1)
                self._write(shards)
                obs.count("tune.lease_claimed", shard=name, worker=worker)
                return name, list(row.get("keys", []))
        return None

    def heartbeat(self, worker: str, shard: str,
                  now: Optional[float] = None) -> bool:
        """Extend ``worker``'s lease on ``shard``; False when the lease was
        lost (reclaimed by another worker after expiry) — the worker must
        abandon the shard instead of racing the new owner."""
        now = now if now is not None else time.time()
        with self._lock():
            faults.check("tune.lease", op="heartbeat", worker=worker)
            shards = self._read()
            row = shards.get(shard)
            if (not isinstance(row, dict) or row.get("state") != "leased"
                    or row.get("owner") != worker):
                obs.count("tune.lease_lost", shard=shard, worker=worker,
                          op="heartbeat")
                return False
            row.update(heartbeat=now, expires=now + self.ttl_s)
            self._write(shards)
            return True

    def complete(self, worker: str, shard: str,
                 now: Optional[float] = None) -> bool:
        """Mark ``shard`` done.  Rejected unless ``worker`` still owns the
        lease — a worker that stalled past its TTL and lost the shard to a
        reclaim cannot double-publish its result row."""
        now = now if now is not None else time.time()
        with self._lock():
            faults.check("tune.lease", op="complete", worker=worker)
            shards = self._read()
            row = shards.get(shard)
            if (not isinstance(row, dict) or row.get("state") != "leased"
                    or row.get("owner") != worker):
                obs.count("tune.lease_lost", shard=shard, worker=worker,
                          op="complete")
                return False
            row.update(state="done", heartbeat=now, expires=None)
            self._write(shards)
            obs.count("tune.shard_done", shard=shard, worker=worker)
            return True

    def release(self, worker: str, shard: str) -> None:
        """Voluntarily return an owned shard to the pool (worker shutdown
        mid-shard); a lost lease releases nothing."""
        with self._lock():
            shards = self._read()
            row = shards.get(shard)
            if (isinstance(row, dict) and row.get("state") == "leased"
                    and row.get("owner") == worker):
                row.update(state="pending", owner=None, heartbeat=None,
                           expires=None)
                self._write(shards)

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> Dict[str, dict]:
        return self._read()

    def states(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for row in self._read().values():
            s = row.get("state", "?")
            out[s] = out.get(s, 0) + 1
        return out

    def all_done(self) -> bool:
        shards = self._read()
        return bool(shards) and all(r.get("state") == "done"
                                    for r in shards.values())

    def done_keys(self) -> List[str]:
        """Content hashes of every completed shard, in shard order."""
        shards = self._read()
        out: List[str] = []
        for name in sorted(shards):
            if shards[name].get("state") == "done":
                out.extend(shards[name].get("keys", []))
        return out


__all__ = ["LeaseLedger", "LEDGER_SCHEMA"]
