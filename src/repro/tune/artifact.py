"""Verified plan artifact: the tuner's output, the replica's warm start.

One JSON file, written atomically (mkstemp+rename), schema-versioned::

    {"schema": 1, "env": "jax-0.4.x", "created": 1723...,
     "complete": false,                       # partial-result salvage
     "entries":  {<content-hash>: <plan dict, as stored in CompileCache>},
     "manifest": {<content-hash>: {"kernel": ..., "sha256": ...,
                                   "env": ..., "factor": ...,
                                   "timings_us": {...},
                                   "members": [<spec>, ...]}},
     "missing":  [<content-hash>, ...]}       # enumerated but unmeasured

The manifest is the verification surface: each entry carries the sha256 of
its canonical-JSON plan and the jax version that measured it, so a replica
verifies *per entry* — one bitrotted or stale plan is quarantined and
re-measured locally while every other entry still loads with zero
measurements (:meth:`repro.compiler.registry.PlanRegistry.
preload_artifact`).

Partial-result salvage: :func:`publish` never demands completeness — a
tuner fleet killed at 60% publishes the measured 60% (``complete: false``,
the unmeasured keys listed under ``missing``, the event counted
``artifact.salvaged``), and replicas re-measure only the gap.

Fault sites: ``artifact.load`` (read/parse — raising *and* text-mangling
rules both fire there) and ``artifact.verify`` (per-entry verification).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

from repro import obs
from repro.testing import faults

ARTIFACT_SCHEMA = 1


def entry_hash(plan: Dict[str, Any]) -> str:
    """Content hash of one plan entry (canonical JSON, sorted keys)."""
    blob = json.dumps(plan, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()


def _env_fingerprint() -> str:
    from repro.compiler.cache import _env_fingerprint
    return _env_fingerprint()


def publish(store, groups: Sequence, path: os.PathLike | str,
            *, now: Optional[float] = None) -> Dict[str, Any]:
    """Publish the measured plans for ``groups`` from ``store`` (a
    :class:`~repro.compiler.cache.CompileCache`) to ``path``.

    Salvages partials: groups whose representative was never measured (a
    fleet killed mid-run) are listed under ``missing`` and the artifact is
    stamped ``complete: false`` — it is still a valid artifact covering
    everything that *was* measured.  Returns a summary dict."""
    now = now if now is not None else time.time()
    entries: Dict[str, dict] = {}
    manifest: Dict[str, dict] = {}
    missing: List[str] = []
    for group in groups:
        plan = store.get(group.key) if group.key in store else None
        if not isinstance(plan, dict):
            missing.append(group.key)
            continue
        rep = group.representative
        tuned = plan.get("autotune") or {}
        entries[group.key] = plan
        manifest[group.key] = {
            "kernel": rep.kernel,
            "sha256": entry_hash(plan),
            "env": plan.get("env"),
            "factor": plan.get("factor"),
            "timings_us": tuned.get("timings_us", {}),
            "members": [dict(item.spec) for item in group.items],
        }
    complete = not missing
    doc = {"schema": ARTIFACT_SCHEMA, "env": _env_fingerprint(),
           "created": now, "complete": complete, "entries": entries,
           "manifest": manifest, "missing": missing}
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                               suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    if not complete:
        obs.count("artifact.salvaged", len(missing), path=str(path))
    obs.count("artifact.published", path=str(path),
              entries=len(entries), complete=str(complete))
    return {"path": str(path), "entries": len(entries),
            "missing": len(missing), "complete": complete}


def load(path: os.PathLike | str) -> Dict[str, Any]:
    """Read + parse an artifact.  Raises ``ValueError``/``OSError`` on a
    missing, torn, corrupt or wrong-schema file — the *caller* owns the
    degrade (a replica falls back to full local measurement)."""
    path = Path(path)
    faults.check("artifact.load", path=str(path))
    with open(path) as f:
        text = f.read()
    text = faults.mangle("artifact.load", text, path=str(path))
    doc = json.loads(text)
    if not isinstance(doc, dict):
        raise ValueError(f"artifact {path}: not a JSON object")
    schema = doc.get("schema")
    if schema != ARTIFACT_SCHEMA:
        raise ValueError(f"artifact {path}: schema {schema!r} "
                         f"(expected {ARTIFACT_SCHEMA})")
    if not isinstance(doc.get("entries"), dict) \
            or not isinstance(doc.get("manifest"), dict):
        raise ValueError(f"artifact {path}: missing entries/manifest")
    return doc


def verify_entry(key: str, plan: Any, manifest_entry: Any,
                 *, env: Optional[str] = None) -> Optional[str]:
    """Per-entry verification: returns the rejection reason or None.

    ``corrupt`` (hash mismatch vs the manifest), ``stale`` (measured under
    a different jax build than this process), ``missing`` (no manifest row
    for the entry), ``invalid`` (not a replayable plan dict)."""
    faults.check("artifact.verify", key=key)
    if not isinstance(manifest_entry, dict):
        return "missing"
    if not isinstance(plan, dict):
        return "invalid"
    try:
        int(plan["factor"])
    except (KeyError, TypeError, ValueError):
        return "invalid"
    if entry_hash(plan) != manifest_entry.get("sha256"):
        return "corrupt"
    env = env if env is not None else _env_fingerprint()
    if plan.get("env") not in (None, env):
        return "stale"
    return None


__all__ = ["ARTIFACT_SCHEMA", "entry_hash", "publish", "load",
           "verify_entry"]
