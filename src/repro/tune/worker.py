"""Tuner worker: claim shards, measure representatives, survive being shot.

A worker is a loop over the lease ledger::

    claim shard -> for each group: measure representative (heartbeating
    between measurements) -> complete shard -> claim next -> ... until the
    ledger has nothing claimable

Measurements run through a private :class:`PlanRegistry` backed by the
*shared* :class:`CompileCache` store — the same measured-autotune path a
serving replica's warmup uses, so results persist under the content-hash
key with merge-on-write cross-process safety.  Re-measuring a reclaimed
shard is therefore idempotent: keys the dead worker already finished are
replays (no timing runs), only the genuinely unmeasured remainder pays.

Failure handling: a lost heartbeat abandons the shard (the new owner has
it), a ledger I/O fault (``tune.lease`` injection, flaky filesystem)
retries after a backoff, and a failed measurement records the key as
failed but keeps the shard progressing — one unplannable bucket must not
wedge the fleet.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro import obs
from repro.compiler.cache import CompileCache
from repro.compiler.registry import PlanRegistry

from . import grid as grid_mod
from .lease import LeaseLedger


@dataclasses.dataclass
class WorkerReport:
    worker: str
    shards_done: List[str] = dataclasses.field(default_factory=list)
    shards_lost: List[str] = dataclasses.field(default_factory=list)
    measured: int = 0
    replayed: int = 0
    failed: Dict[str, str] = dataclasses.field(default_factory=dict)
    lease_errors: int = 0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class TunerWorker:
    """One fleet member.  ``shards`` is the shard → [WorkGroup] map every
    worker derives deterministically from the config (see
    :func:`repro.tune.grid.shard_groups`)."""

    def __init__(self, worker_id: str, ledger: LeaseLedger,
                 store: CompileCache,
                 shards: Dict[str, List[grid_mod.WorkGroup]], *,
                 backend: str = "pallas", claim_retries: int = 3,
                 retry_sleep_s: float = 0.05,
                 measure_hook=None):
        self.worker_id = worker_id
        self.ledger = ledger
        self.store = store
        self.shards = shards
        self.claim_retries = claim_retries
        self.retry_sleep_s = retry_sleep_s
        # test seam: called before each measurement (two-process tests park
        # a worker here to die mid-lease)
        self._measure_hook = measure_hook
        self._reg = PlanRegistry(pump="measure", backend=backend,
                                 cache=store, spot_check="finite")

    # ------------------------------------------------------------------ run --
    def run(self) -> WorkerReport:
        """Drain the ledger: claim + measure until nothing is claimable.
        Ledger faults degrade to bounded retries, never a crash."""
        rep = WorkerReport(worker=self.worker_id)
        with obs.span("tune.worker", cat="tune", worker=self.worker_id):
            while True:
                claimed = self._claim(rep)
                if claimed is None:
                    break
                shard, keys = claimed
                self._run_shard(rep, shard, keys)
        return rep

    def _claim(self, rep: WorkerReport):
        for attempt in range(self.claim_retries):
            try:
                return self.ledger.claim(self.worker_id)
            except Exception as e:  # noqa: BLE001 — ledger fault: retry
                rep.lease_errors += 1
                obs.count("tune.lease_error", worker=self.worker_id,
                          op="claim", error=type(e).__name__)
                if attempt + 1 < self.claim_retries:
                    time.sleep(self.retry_sleep_s)
        return None

    def _heartbeat(self, rep: WorkerReport, shard: str) -> bool:
        try:
            return self.ledger.heartbeat(self.worker_id, shard)
        except Exception as e:  # noqa: BLE001 — ledger fault ≠ lost lease:
            # the lease may still be ours on disk; keep measuring (results
            # are idempotent either way) and let complete() arbitrate
            rep.lease_errors += 1
            obs.count("tune.lease_error", worker=self.worker_id,
                      op="heartbeat", error=type(e).__name__)
            return True

    def _run_shard(self, rep: WorkerReport, shard: str,
                   keys: List[str]) -> None:
        groups = {g.key: g for g in self.shards.get(shard, [])}
        with obs.span("tune.shard", cat="tune", shard=shard,
                      worker=self.worker_id, keys=len(keys)) as sp:
            for key in keys:
                group = groups.get(key)
                if group is None:     # ledger/grid drift: count, skip
                    obs.count("tune.unknown_key", shard=shard, key=key)
                    continue
                if not self._heartbeat(rep, shard):
                    rep.shards_lost.append(shard)
                    sp.set(lost=True)
                    return            # reclaimed: the new owner has it
                self._measure(rep, group)
            try:
                done = self.ledger.complete(self.worker_id, shard)
            except Exception as e:  # noqa: BLE001 — ledger fault on the
                # final write: the measurements are safely in the store;
                # the shard stays leased and expires back to the pool,
                # where the next claim replays it for free
                rep.lease_errors += 1
                obs.count("tune.lease_error", worker=self.worker_id,
                          op="complete", error=type(e).__name__)
                done = False
            if done:
                rep.shards_done.append(shard)
            else:
                rep.shards_lost.append(shard)
            sp.set(done=done)

    def _measure(self, rep: WorkerReport, group: grid_mod.WorkGroup) -> None:
        """Measure one group representative through the registry's
        measured-autotune path; the result lands in the shared store under
        the group's content hash (every member replays it)."""
        item = group.representative
        if self._measure_hook is not None:
            self._measure_hook(item)
        try:
            kern = self._reg.kernel(item.kernel, item.args,
                                    item.builder_kwargs())
        except Exception as e:  # noqa: BLE001 — one bad bucket ≠ dead fleet
            rep.failed[group.key] = repr(e)
            obs.count("tune.measure_failed", kernel=item.kernel,
                      error=type(e).__name__)
            return
        tuned = kern.report.autotune or {}
        if tuned and not tuned.get("replayed"):
            rep.measured += 1
        else:
            rep.replayed += 1


def run_fleet(cfg, batch: int, max_len: int, *, ledger_path, store_path,
              out_path=None, dtype=None, n_shards: int = 4,
              worker_id: str = "worker-0", ttl_s: float = 30.0,
              backend: str = "pallas",
              measure_hook=None) -> Dict:
    """One worker's end-to-end tuner pass: derive the grid, register the
    shards, drain the ledger, and (when ``out_path`` is given and at least
    one shard is done) publish the artifact — publishing is salvage-aware,
    so a partially-tuned ledger still yields a usable artifact."""
    from . import artifact as artifact_mod
    groups = grid_mod.enumerate_work(cfg, batch, max_len, dtype=dtype)
    shards = grid_mod.shard_groups(groups, n_shards)
    ledger = LeaseLedger(ledger_path, ttl_s=ttl_s)
    for attempt in range(3):
        try:
            ledger.init_shards(grid_mod.shard_keys(shards))
            break
        except Exception as e:  # noqa: BLE001 — ledger fault: bounded retry;
            # even a dead ledger only costs parallelism (claim yields None
            # and publish still salvages whatever the store holds)
            obs.count("tune.lease_error", worker=worker_id, op="init",
                      error=type(e).__name__)
            time.sleep(0.05)
    store = CompileCache(store_path)
    worker = TunerWorker(worker_id, ledger, store, shards, backend=backend,
                         measure_hook=measure_hook)
    rep = worker.run()
    out = {"worker": rep.as_dict(), "ledger": ledger.states(),
           "groups": len(groups),
           "work_items": sum(len(g.items) for g in groups)}
    if out_path is not None:
        out["artifact"] = artifact_mod.publish(store, groups, out_path)
    return out


__all__ = ["TunerWorker", "WorkerReport", "run_fleet"]
