"""Tuning-grid enumeration + content-hash dedupe.

The offline tuner measures exactly the plans a serving replica would warm:
:func:`repro.models.transformer.plan_requests` enumerates the
(kernel × bucket) grid, each request canonicalizes through the same
``PlanRegistry`` request builders the serving wrappers use, and the
compile-cache content hash (:func:`repro.compiler.measure_request_key`)
keys the work.  Two requests that hash to the same key are *the same
measurement* — the grid groups them and the tuner measures one
representative per group, the hash-grouped dedupe structure of DaCe's
distributed cutout tuner (arXiv 2210.04598): results land in the shared
store under the group key, so every member replays the one measurement.

Shards partition the groups round-robin; a shard is the unit of lease in
:mod:`.lease` (one worker owns one shard at a time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro import obs


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One (kernel, bucket) measurement request, canonicalized.

    ``args``/``kwargs`` are the registry-canonical builder arguments (the
    exact key the serving wrapper will look the plan up under) and ``key``
    the compile-cache content hash of the measured-autotune request."""

    kernel: str
    spec: Tuple[Tuple[str, Any], ...]       # the plan_requests shape kwargs
    args: Tuple
    kwargs: Tuple[Tuple[str, Any], ...]
    key: str

    def builder_kwargs(self) -> Dict[str, Any]:
        return dict(self.kwargs)


@dataclasses.dataclass(frozen=True)
class WorkGroup:
    """All work items sharing one content hash: measure ``items[0]`` (the
    representative), and every member is served by the same cache entry."""

    key: str
    items: Tuple[WorkItem, ...]

    @property
    def representative(self) -> WorkItem:
        return self.items[0]


def enumerate_work(cfg, batch: int, max_len: int, *, dtype=None,
                   policy=None) -> List[WorkGroup]:
    """The deduped tuning grid for one serving shape.

    Deterministic in ``(cfg, batch, max_len, dtype)`` — every tuner worker
    re-derives the identical group list from the config, so shards can be
    referenced by index across processes without shipping the work list."""
    from repro.compiler import measure_request_key
    from repro.compiler.registry import PlanRegistry
    from repro.core.autopump import BUILDERS
    from repro.models import transformer

    if not getattr(cfg, "fresh_prefill_kernel", True):
        # mirror the Engine's construction-time normalization: its prefill
        # always builds a fresh cache, so it serves with the flash prefill
        # route on — the tuner must cover that grid or the replica pays
        # the prefill measurements locally
        cfg = dataclasses.replace(cfg, fresh_prefill_kernel=True)
    reg = PlanRegistry(policy)          # bucket math only; never compiles
    canon = {"flash_attention": reg.flash_request,
             "ssd_scan": reg.ssd_request,
             "grouped_gemm": reg.grouped_request,
             "decode_attention": reg.decode_request,
             "ssd_decode": reg.ssd_decode_request}
    groups: Dict[str, List[WorkItem]] = {}
    reqs = transformer.plan_requests(cfg, batch, max_len, dtype=dtype,
                                     policy=reg.policy, cached=True)
    for kernel, spec in reqs:
        args, kwargs, _pads = canon[kernel](**spec)
        g, est = BUILDERS[kernel](*args, **kwargs)
        key = measure_request_key(g, est)
        item = WorkItem(kernel=kernel, spec=tuple(sorted(spec.items())),
                        args=tuple(args),
                        kwargs=tuple(sorted(kwargs.items())), key=key)
        groups.setdefault(key, []).append(item)
    out = [WorkGroup(key=key, items=tuple(items))
           for key, items in groups.items()]
    deduped = sum(len(g.items) - 1 for g in out)
    if deduped:
        obs.count("tune.grid_deduped", deduped)
    obs.count("tune.grid_groups", len(out))
    return out


def shard_groups(groups: List[WorkGroup],
                 n_shards: int) -> Dict[str, List[WorkGroup]]:
    """Round-robin partition of the group list into named shards.  Group
    order is the enumeration order (deterministic), so every worker derives
    the same shard → groups mapping independently."""
    n = max(1, min(int(n_shards), len(groups)) if groups else 1)
    shards: Dict[str, List[WorkGroup]] = {f"shard-{i}": [] for i in range(n)}
    for i, group in enumerate(groups):
        shards[f"shard-{i % n}"].append(group)
    return shards


def shard_keys(shards: Dict[str, List[WorkGroup]]) -> Dict[str, List[str]]:
    """The ledger-facing view: shard name → group content hashes."""
    return {name: [g.key for g in groups] for name, groups in shards.items()}


__all__ = ["WorkItem", "WorkGroup", "enumerate_work", "shard_groups",
           "shard_keys"]
