"""Deterministic fault-injection harness for the compile→serve path.

The robustness contract of this repo — *the optimized path can never be
worse than the unoptimized one, including when it fails* — is only testable
if every failure mode can be triggered on demand, deterministically, in a
unit test.  This module is that trigger.  Production modules thread named
**injection sites** through their failure-prone seams::

    from repro.testing import faults
    ...
    faults.check("cache.load", path=str(self.path))      # may raise
    text = faults.mangle("cache.json", text)             # may corrupt
    fn = faults.wrap("emission.exec", fn, graph=g.name)  # may NaN outputs

With no rules installed (the production state) each site costs one truthy
check of a module-level list — no locks, no RNG, no allocation.  Tests
install :class:`FaultRule`\\ s scoped by a context manager::

    with faults.inject(faults.FaultRule("cache.load", "io_error")):
        ...   # every cache.load site now raises OSError

Rules are matched by ``fnmatch`` pattern over the site name, optionally
filtered by context attributes (``match={"graph": "decode_*"}``), fire
deterministically (``after`` skips the first N matching calls, ``times``
caps total firings) or probabilistically from a **seeded** RNG (``p`` < 1) —
the same seed always yields the same fault schedule.  Every firing counts
``faults.injected`` (with the site and action) through :mod:`repro.obs`, so
a chaos run's injected faults are part of the same metrics snapshot as the
degradations they cause.

Actions
-------
``io_error``      raise :class:`OSError` (cache/file IO sites)
``error``         raise :class:`FaultError` (generic injected failure)
``timeout``       raise :class:`FaultTimeout` (measurement-budget sites)
``truncate``      mangle text/bytes to its first half (torn write)
``garbage``       mangle text to non-JSON bytes (bitrot)
``nan``           wrap: replace array outputs with NaNs (bad compiled kernel)
a callable        escape hatch: called as ``action(site, value, **ctx)`` at
                  mangle/wrap sites, ``action(site, None, **ctx)`` at check
                  sites (raise to inject)

Sites currently threaded (the fault matrix in ``docs/robustness.md`` maps
each to its expected degradation rung):

===================  ======================================================
``cache.load``       persistent plan-store read (``CompileCache._load``)
``cache.json``       raw cache JSON text before parsing (mangle)
``cache.save``       plan-store write (``CompileCache._save``)
``compile.measure``  one autotune candidate measurement (per factor)
``emission.lower``   pallas-backend lowering of one graph
``emission.exec``    execution of a pallas-backend compiled kernel (wrap)
``registry.exec``    plan-registry kernel execution on the serving path
``engine.decode``    one engine decode step (mid-request failure)
``engine.prefill``   one engine whole-prompt prefill step
``engine.prefill_chunk``  one continuation-prefill chunk (chunked prefill /
                     preemption resume)
``sched.slot_free``  scheduler lane reclamation at request completion
``sched.preempt``    scheduler slot preemption (park + requeue)
``sched.evict_rows`` cache-row eviction of a preempted lane
``tune.lease``       one lease-ledger mutation (init/claim/heartbeat/
                     complete — the offline tuner's work partitioning)
``artifact.load``    plan-artifact read/parse (check *and* text mangle)
``artifact.verify``  per-entry artifact manifest verification
===================  ======================================================
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import random
from typing import Any, Callable, Dict, List, Optional, Union


class FaultError(RuntimeError):
    """Generic injected failure (the ``error`` action)."""


class FaultTimeout(TimeoutError):
    """Injected measurement/wall-clock timeout (the ``timeout`` action)."""


@dataclasses.dataclass
class FaultRule:
    """One seeded injection rule.

    ``site`` is an ``fnmatch`` pattern over injection-site names;
    ``action`` one of the named actions above or a callable.  ``after``
    skips the first N matching calls, ``times`` caps how often the rule
    fires (None = unlimited), ``p`` fires probabilistically from a RNG
    seeded with ``seed`` (deterministic schedule), and ``match`` filters on
    site context attributes (fnmatch on ``str(value)`` per key; a context
    missing the key does not match).
    """

    site: str
    action: Union[str, Callable]
    times: Optional[int] = None
    after: int = 0
    p: float = 1.0
    seed: int = 0
    match: Optional[Dict[str, str]] = None
    message: str = ""
    # runtime state (not part of the rule identity)
    fired: int = dataclasses.field(default=0, compare=False)
    seen: int = dataclasses.field(default=0, compare=False)

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def _matches(self, site: str, ctx: Dict[str, Any]) -> bool:
        if not fnmatch.fnmatch(site, self.site):
            return False
        for key, pat in (self.match or {}).items():
            if key not in ctx or not fnmatch.fnmatch(str(ctx[key]), pat):
                return False
        return True

    def should_fire(self, site: str, ctx: Dict[str, Any]) -> bool:
        """Consume one matching call; True when the fault fires on it."""
        if not self._matches(site, ctx):
            return False
        self.seen += 1
        if self.seen <= self.after:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        if self.p < 1.0 and self._rng.random() >= self.p:
            return False
        self.fired += 1
        return True


# Active rules.  Deliberately a plain module-level list: the zero-rule fast
# path at every injection site is `if not faults._RULES: return`.
_RULES: List[FaultRule] = []


def active() -> bool:
    """True when any fault rule is installed."""
    return bool(_RULES)


def install(*rules: FaultRule) -> None:
    _RULES.extend(rules)


def clear() -> None:
    del _RULES[:]


@contextlib.contextmanager
def inject(*rules: FaultRule):
    """Scope ``rules``: installed on entry, removed (only they) on exit."""
    install(*rules)
    try:
        yield rules
    finally:
        for r in rules:
            try:
                _RULES.remove(r)
            except ValueError:       # a nested clear() already dropped it
                pass


def _count(site: str, action: str, **ctx) -> None:
    # local import: obs is cheap but faults must stay importable from
    # anywhere in the package without cycles
    from repro import obs
    obs.count("faults.injected", site=site, action=action,
              **{k: str(v) for k, v in ctx.items()})


def _raise_for(rule: FaultRule, site: str, ctx: Dict[str, Any]) -> None:
    msg = rule.message or f"injected fault at {site}"
    if rule.action == "io_error":
        raise OSError(msg)
    if rule.action == "timeout":
        raise FaultTimeout(msg)
    if rule.action == "error":
        raise FaultError(msg)
    if callable(rule.action):
        rule.action(site, None, **ctx)
        return
    raise FaultError(f"{msg} (action {rule.action!r})")


def check(site: str, **ctx) -> None:
    """Raising injection site: a no-op unless a matching rule fires, in
    which case the rule's exception is raised (``io_error`` / ``timeout`` /
    ``error`` / callable)."""
    if not _RULES:
        return
    for rule in list(_RULES):
        if rule.should_fire(site, ctx):
            _count(site, str(rule.action), **ctx)
            _raise_for(rule, site, ctx)


def mangle(site: str, value, **ctx):
    """Value-corrupting injection site: returns ``value`` unchanged unless a
    matching rule fires, in which case the corrupted value is returned
    (``truncate`` / ``garbage`` / callable).  Raising actions raise."""
    if not _RULES:
        return value
    for rule in list(_RULES):
        if not rule.should_fire(site, ctx):
            continue
        _count(site, str(rule.action), **ctx)
        if rule.action == "truncate":
            return value[: len(value) // 2]
        if rule.action == "garbage":
            return (b"\x00garbage\x00" if isinstance(value, bytes)
                    else "{not json!")
        if callable(rule.action):
            return rule.action(site, value, **ctx)
        _raise_for(rule, site, ctx)
    return value


def wrap(site: str, fn: Callable, **ctx) -> Callable:
    """Output-corrupting injection site for compiled kernels: wraps ``fn``
    so each *call* consults the rules — ``nan`` replaces every array in the
    result (dict of arrays or a single array) with NaNs of the same
    shape/dtype; raising actions raise at call time.  With no rules
    installed at wrap time the original ``fn`` is returned untouched, so
    the production hot path gains no call-level indirection."""
    if not _RULES:
        return fn

    def _nanify(out):
        import jax.numpy as jnp

        def one(a):
            try:
                return jnp.full_like(a, jnp.nan) \
                    if jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating) \
                    else a
            except Exception:   # non-array leaf: leave it alone
                return a
        if isinstance(out, dict):
            return {k: one(v) for k, v in out.items()}
        return one(out)

    def wrapped(*args, **kwargs):
        out = fn(*args, **kwargs)
        for rule in list(_RULES):
            if not rule.should_fire(site, ctx):
                continue
            _count(site, str(rule.action), **ctx)
            if rule.action == "nan":
                return _nanify(out)
            if callable(rule.action):
                return rule.action(site, out, **ctx)
            _raise_for(rule, site, ctx)
        return out

    return wrapped


__all__ = ["FaultRule", "FaultError", "FaultTimeout", "active", "install",
           "clear", "inject", "check", "mangle", "wrap"]
