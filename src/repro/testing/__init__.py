"""``repro.testing`` — test-only infrastructure shipped with the package.

:mod:`.faults` is the deterministic fault-injection harness the chaos suite
(``tests/test_chaos.py``, ``make chaos-smoke``) drives; production code
threads named injection sites through the compile→serve path and this
package decides — by seeded rule — whether a site fires.  With no rules
installed every site is a single falsy attribute check.
"""
from . import faults

__all__ = ["faults"]
