from . import trainer
