"""Training loop: multipumped gradient accumulation, mixed precision,
checkpointing, failure recovery, metrics.

The trainer is the pod-scale consumer of the paper's transformation
(DESIGN.md §2): ``TrainConfig.pump_factor`` M sets how many microbatch
compute iterations (fast domain) feed one gradient synchronization + update
(wide transaction on the slow domain).  ``pump_factor='auto'`` asks
``core.pump_plan.plan_trainer_pump`` for the factor that amortizes the
collective below 10 % of compute.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import obs, optim
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.pump_plan import plan_trainer_pump
from repro.data.pipeline import DataConfig, DataIterator
from repro.checkpoint import manager as ckpt_mod
from repro.launch import mesh as mesh_mod
from repro.launch import sharding as shard_mod
from repro.launch import steps as steps_mod
from repro.models import model as model_mod


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 100
    pump_factor: Any = 1              # int or "auto"
    param_dtype: str = "float32"
    ckpt_root: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: optim.AdamWState
    step: int = 0


def resolve_pump(cfg: ModelConfig, shape: ShapeConfig, mesh, pump) -> int:
    if pump != "auto":
        return int(pump)
    grad_bytes = cfg.param_count() * 4
    tokens = shape.global_batch * shape.seq_len
    step_flops = 6.0 * cfg.active_param_count() * tokens
    return plan_trainer_pump(grad_bytes, step_flops, mesh.devices.size,
                             mesh_mod.dp_degree(mesh))


def make_trainer(cfg: ModelConfig, shape: ShapeConfig,
                 optcfg: optim.AdamWConfig = optim.AdamWConfig(),
                 tcfg: TrainConfig = TrainConfig(),
                 mesh=None, batch_override: Optional[int] = None):
    """Returns (init_fn, step_fn, data_iter).  Host-side driver below."""
    mesh = mesh or mesh_mod.make_host_mesh()
    pump = resolve_pump(cfg, shape, mesh, tcfg.pump_factor)
    pdt = jnp.dtype(tcfg.param_dtype)

    step = steps_mod.make_train_step(cfg, optcfg, pump)
    in_sh, out_sh, _ = steps_mod.train_shardings(cfg, optcfg, mesh, shape,
                                                 pdt, pump)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))

    def init_fn(key) -> TrainState:
        with mesh:
            params = jax.jit(
                lambda k: model_mod.init_params(cfg, k, dtype=pdt),
                out_shardings=in_sh[0])(key)
            opt_state = jax.jit(
                lambda p: optim.init(optcfg, p),
                out_shardings=in_sh[1])(params)
        return TrainState(params, opt_state, 0)

    def step_fn(state: TrainState, batch) -> tuple:
        with mesh:
            params, opt_state, metrics = jitted(state.params, state.opt_state,
                                                batch)
        return TrainState(params, opt_state, state.step + 1), metrics

    data = DataIterator(cfg, shape, DataConfig(seed=tcfg.seed),
                        batch_override=batch_override, pump_factor=pump)
    return init_fn, step_fn, data, pump


def train(cfg: ModelConfig, shape: ShapeConfig,
          optcfg: optim.AdamWConfig = optim.AdamWConfig(),
          tcfg: TrainConfig = TrainConfig(),
          mesh=None, batch_override: Optional[int] = None,
          log=print, heartbeat=None, straggler=None) -> Dict[str, Any]:
    """Full driver: init → (restore) → loop → checkpoint.  Returns metrics.

    ``heartbeat`` (:class:`repro.runtime.failover.Heartbeat`) gets this
    host's step stamped after every update — the liveness signal the
    monitor side reads.  ``straggler``
    (:class:`~repro.runtime.failover.StragglerPolicy`) observes per-step
    wall time and derates this host's pump factor from the EWMAs; the
    derated factor is gauged (``train.pump_derated``) and logged when it
    moves, so a slow host is visible before it stalls the whole mesh.
    """
    init_fn, step_fn, data, pump = make_trainer(
        cfg, shape, optcfg, tcfg, mesh, batch_override)
    state = init_fn(jax.random.PRNGKey(tcfg.seed))
    worker = jax.process_index()
    pump_derated = pump

    if tcfg.ckpt_root:
        latest = ckpt_mod.latest_valid(tcfg.ckpt_root)
        if latest:
            like = {"params": state.params, "opt_state": state.opt_state}
            tree, extra = ckpt_mod.restore(latest, like)
            state = TrainState(tree["params"], tree["opt_state"],
                               extra["step"])
            data.step = extra["data_step"]
            log(f"[trainer] resumed from {latest} at step {state.step}")

    if straggler is not None:
        # the policy derates from the *resolved* pump factor (the CLI may
        # have asked for 'auto', resolved only inside make_trainer)
        straggler.base_pump = pump
    history = []
    t_last = time.time()
    t_step = time.time()
    while state.step < tcfg.n_steps:
        batch = next(data)
        state, metrics = step_fn(state, batch)
        if heartbeat is not None:
            heartbeat.stamp(worker, state.step)
        if straggler is not None:
            now = time.time()
            straggler.observe(worker, now - t_step)
            t_step = now
            derated = straggler.pump_factors().get(worker, pump_derated)
            if derated != pump_derated:
                log(f"[trainer] straggler policy derated pump "
                    f"{pump_derated} -> {derated} (worker {worker})")
                obs.count("train.pump_derate", frm=str(pump_derated),
                          to=str(derated))
                pump_derated = derated
            obs.gauge("train.pump_derated", pump_derated)
        if state.step % tcfg.log_every == 0 or state.step == tcfg.n_steps:
            dt = time.time() - t_last
            t_last = time.time()
            loss = float(metrics["loss"])
            history.append({"step": state.step, "loss": loss,
                            "grad_norm": float(metrics["grad_norm"]),
                            "lr": float(metrics["lr"]), "sec": dt})
            log(f"[trainer] step {state.step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} ({dt:.1f}s) pump={pump}")
        if tcfg.ckpt_root and state.step % tcfg.ckpt_every == 0:
            ckpt_mod.save(tcfg.ckpt_root, state.step,
                          {"params": state.params,
                           "opt_state": state.opt_state},
                          extra={"step": state.step,
                                 "data_step": data.step})
    if tcfg.ckpt_root:
        ckpt_mod.save(tcfg.ckpt_root, state.step,
                      {"params": state.params, "opt_state": state.opt_state},
                      extra={"step": state.step, "data_step": data.step})
    return {"history": history, "final_state": state, "pump": pump}
