"""Deterministic, checkpointable synthetic-token data pipeline.

Production posture without external data deps: batches are generated from a
counter-based PRNG (``jax.random.fold_in(key, step)``), so

  - the stream is *stateless* — any step's batch can be regenerated from
    (seed, step) alone; checkpoint/restore and elastic re-sharding need to
    save only the integer step (exactly-once batch semantics across
    restarts, see runtime/failover.py);
  - each data-parallel host slice derives its shard from its own fold_in,
    i.e. host-sharded feeding without inter-host coordination.

A real deployment swaps ``synthetic_batch`` for a tokenized corpus reader
with the same (seed, step) → batch contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    # structured synthetic text: repeated n-grams so the LM loss can fall
    ngram: int = 8
    vocab_cap: int = 0           # 0 = model vocab


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, dcfg: DataConfig,
                    step: int, *, batch_override: Optional[int] = None,
                    pump_factor: int = 1) -> Dict[str, jax.Array]:
    """Batch for ``step`` — pure function of (seed, step)."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    vocab = dcfg.vocab_cap or cfg.vocab_size
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    # learnable structure: each sequence repeats a random n-gram pattern
    base = jax.random.randint(k1, (b, dcfg.ngram), 0, vocab)
    reps = -(-s // dcfg.ngram)
    tokens = jnp.tile(base, (1, reps))[:, :s]
    noise = jax.random.bernoulli(k2, 0.05, (b, s))
    rand = jax.random.randint(k3, (b, s), 0, vocab)
    tokens = jnp.where(noise, rand, tokens)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-100)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k2, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            k2, (b, cfg.n_vision_tokens, cfg.d_vision), jnp.float32)
    if pump_factor > 1:
        batch = jax.tree.map(
            lambda a: a.reshape((pump_factor, b // pump_factor) + a.shape[1:]),
            batch)
    return batch


class DataIterator:
    """Stateful view over the stateless stream (tracks `step` for ckpt)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 dcfg: DataConfig = DataConfig(), start_step: int = 0,
                 batch_override: Optional[int] = None, pump_factor: int = 1):
        self.cfg, self.shape, self.dcfg = cfg, shape, dcfg
        self.step = start_step
        self.batch_override = batch_override
        self.pump_factor = pump_factor

    def __next__(self):
        b = synthetic_batch(self.cfg, self.shape, self.dcfg, self.step,
                            batch_override=self.batch_override,
                            pump_factor=self.pump_factor)
        self.step += 1
        return b

    def __iter__(self) -> Iterator:
        return self

    def state(self) -> dict:
        return {"step": self.step, "seed": self.dcfg.seed}

    @classmethod
    def from_state(cls, cfg, shape, state: dict, **kw):
        return cls(cfg, shape, DataConfig(seed=state["seed"]),
                   start_step=state["step"], **kw)
