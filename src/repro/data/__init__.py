from . import pipeline
