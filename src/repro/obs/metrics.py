"""Metrics registry: counters, gauges, histograms with percentiles.

The *how-often / how-bad* half of ``repro.obs``.  Unlike the tracer,
metrics are **always on** — counting a cache corruption or recording a
decode-step latency costs an attribute lookup and an append, and fleet
health counters (``cache.corrupt``, ``registry.fallback.*``) must count
whether or not anyone asked for a trace.

The registry deliberately absorbs the repo's pre-existing stat surfaces as
*views* instead of re-implementing them: :class:`RegistryStats` and the
engine's :class:`~repro.launch.steps.StepTimer` register snapshot callbacks
(:meth:`MetricsRegistry.register_view`), and the percentile math every
stats() consumer needs lives in exactly one place (:class:`Histogram`).
``snapshot()`` is a pure-JSON dict — it round-trips through ``json`` and is
embedded verbatim into ``BENCH_compiler.json``/``BENCH_serve.json`` rows so
bench artifacts carry hit rates, emission-tier mix and latency percentiles
per PR.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Callable, Dict, List, Optional

PERCENTILES = (50, 90, 99)


class Counter:
    """Monotonic event counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Value series with nearest-rank percentiles (p50/p90/p99).

    Stores raw samples up to ``max_samples``; past that the series is
    compacted by keeping every other sample (deterministic — no RNG), while
    ``count``/``total`` keep exact tallies over everything ever recorded.
    The serving decode loop records thousands of sub-ms floats per run, so
    the bound exists for long-lived processes, not for correctness at
    benchmark scale.
    """

    __slots__ = ("_values", "count", "total", "min", "max", "max_samples")

    def __init__(self, max_samples: int = 8192):
        self._values: List[float] = []
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.max_samples = max_samples

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self._values.append(v)
        if len(self._values) > self.max_samples:
            self._values = self._values[::2]

    @property
    def values(self) -> List[float]:
        return list(self._values)

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def percentile(self, p: float) -> Optional[float]:
        """Nearest-rank percentile over the retained samples."""
        if not self._values:
            return None
        s = sorted(self._values)
        rank = max(int(round(p / 100.0 * len(s) + 0.5)), 1)
        return s[min(rank, len(s)) - 1]

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "count": self.count, "total": self.total, "mean": self.mean,
            "min": self.min, "max": self.max,
        }
        for p in PERCENTILES:
            out[f"p{p}"] = self.percentile(p)
        return out


class MetricsRegistry:
    """Named metric store + view callbacks, snapshot-exportable as JSON."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._views: Dict[str, Callable[[], Any]] = {}

    # -- metric accessors (create-on-first-use) ------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram())
        return h

    # -- views ---------------------------------------------------------------
    def register_view(self, name: str, fn: Callable[[], Any]) -> None:
        """Register a snapshot callback: ``fn()`` is called (and must return
        a JSON-able value or None) every time :meth:`snapshot` runs.  This is
        how pre-existing stat objects (RegistryStats, StepTimer) join the
        unified snapshot without duplicating their counters here."""
        self._views[name] = fn

    def unregister_view(self, name: str) -> None:
        self._views.pop(name, None)

    # -- export --------------------------------------------------------------
    def snapshot(self, include_views: bool = True) -> Dict[str, Any]:
        """Pure-JSON state dump: ``json.loads(json.dumps(snap)) == snap``."""
        snap: Dict[str, Any] = {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }
        if include_views:
            views = {}
            for name, fn in sorted(self._views.items()):
                try:
                    v = fn()
                except Exception as e:  # noqa: BLE001 — a dead view must not
                    v = {"error": repr(e)}  # take the snapshot down with it
                if v is not None:
                    views[name] = v
            snap["views"] = views
        # normalize through json so embedding the snapshot in a bench
        # artifact can never fail later (tuples→lists, repr for strays)
        return json.loads(json.dumps(snap, default=repr))

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# -------------------------------------------------------------- formatting --
def _fmt_seconds(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.0f}us"


def _fmt_value(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def format_phases(phases: Dict[str, Dict[str, Any]]) -> str:
    """Human lines for ``StepTimer.stats()`` — the serve launcher's report
    formatter (replaces hand-rolled dict dumps)."""
    lines = []
    for phase, st in phases.items():
        warm = st.get("warm") or {}
        lines.append(
            f"{phase:>8}: cold={_fmt_seconds(st.get('compile_s'))} | "
            f"warm mean={_fmt_seconds(warm.get('mean_s'))} "
            f"p50={_fmt_seconds(warm.get('p50_s'))} "
            f"p99={_fmt_seconds(warm.get('p99_s'))} "
            f"best={_fmt_seconds(warm.get('best_s'))} "
            f"over {warm.get('calls', 0)} steps")
    return "\n".join(lines)


def format_snapshot(snap: Dict[str, Any], prefix: str = "") -> str:
    """Readable rendering of a :meth:`MetricsRegistry.snapshot` dict."""
    lines: List[str] = []
    counters = snap.get("counters") or {}
    if counters:
        lines.append(f"{prefix}counters:")
        for k, v in counters.items():
            lines.append(f"{prefix}  {k:<40} {v}")
    gauges = {k: v for k, v in (snap.get("gauges") or {}).items()
              if v is not None}
    if gauges:
        lines.append(f"{prefix}gauges:")
        for k, v in gauges.items():
            lines.append(f"{prefix}  {k:<40} {_fmt_value(v)}")
    hists = snap.get("histograms") or {}
    if hists:
        lines.append(f"{prefix}histograms:")
        for k, h in hists.items():
            unit = _fmt_seconds if k.endswith("_s") else _fmt_value
            lines.append(
                f"{prefix}  {k:<40} n={h.get('count', 0)}"
                f" mean={unit(h.get('mean'))} p50={unit(h.get('p50'))}"
                f" p90={unit(h.get('p90'))} p99={unit(h.get('p99'))}"
                f" max={unit(h.get('max'))}")
    for name, view in (snap.get("views") or {}).items():
        lines.append(f"{prefix}{name}: "
                     + json.dumps(view, default=repr, sort_keys=True))
    return "\n".join(lines)


# ------------------------------------------------------------ process-wide --
_METRICS = MetricsRegistry()


def default_metrics() -> MetricsRegistry:
    return _METRICS


def set_default_metrics(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide registry (tests); returns the old one."""
    global _METRICS
    old, _METRICS = _METRICS, reg
    return old
