"""Span tracer: nested wall-clock spans + instant events, exportable as
Chrome-trace JSON (Perfetto-loadable) or a JSONL event log.

The tracer is the *where-does-the-time-go* half of ``repro.obs``: every
layer that does time-shaped work (pipeline passes, compiles, autotune
measurement, plan-registry lookups, engine warmup/prefill/decode) brackets
it in a span, so one ``Engine.generate()`` call under ``--trace`` yields a
complete nested timeline — TTFT and per-token latency are *derivable from
the spans*, not separately book-kept.

Design constraints:

* **Zero dependencies** — stdlib only, importable from every layer
  (including :mod:`repro.compiler.cache`, the lowest module in the tree).
* **Off by default, near-zero cost when off** — ``span()`` returns a
  shared no-op handle after one attribute check; serving hot paths keep
  their instrumentation permanently and pay ~a dict build per call
  (measured <2% of a decode step — ``BENCH_serve.json:engine.obs_overhead``).
* **Exception-safe nesting** — a span records on ``__exit__`` even when the
  body raises (the error type lands in its attrs), and the thread-local
  stack is popped in all cases, so an exception can never corrupt the
  parent/depth bookkeeping of later spans.

Timestamps are monotonic (``time.perf_counter_ns``) relative to the
tracer's construction, in microseconds — the unit Chrome-trace wants.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class _NullSpan:
    """Shared no-op handle returned when tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


_NULL = _NullSpan()


class _Span:
    """One live span: context manager that records itself on exit."""

    __slots__ = ("_tracer", "name", "cat", "attrs", "_t0", "_tid", "_depth",
                 "_parent")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. the chosen factor)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        tr = self._tracer
        stack = tr._stack()
        self._parent = stack[-1] if stack else None
        self._depth = len(stack)
        self._tid = tr._tid()
        stack.append(self.name)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur_ns = time.perf_counter_ns() - self._t0
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        elif stack:  # defensive: never let a mismatch corrupt later spans
            del stack[self._depth:]
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        tr._record({
            "type": "span", "name": self.name, "cat": self.cat,
            "ts": (self._t0 - tr._epoch) / 1e3, "dur": dur_ns / 1e3,
            "tid": self._tid, "depth": self._depth, "parent": self._parent,
            "args": self.attrs,
        })
        return False


class Tracer:
    """Span/event recorder with Chrome-trace and JSONL export.

    ``enabled=False`` (the default for the process-wide tracer) makes
    ``span()``/``instant()`` no-ops; flip with :func:`enable` or construct a
    private enabled instance (tests do).
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._epoch = time.perf_counter_ns()
        self._records: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: Dict[int, int] = {}

    # -- internals -----------------------------------------------------------
    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _record(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            self._records.append(rec)

    # -- recording API -------------------------------------------------------
    def span(self, name: str, cat: str = "", **attrs):
        """Context manager timing one unit of work; nests via a thread-local
        stack.  Returns a no-op handle when the tracer is disabled."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, cat, attrs)

    def instant(self, name: str, cat: str = "", **attrs) -> None:
        """Point-in-time event (cache hit, fallback, tier decision)."""
        if not self.enabled:
            return
        self._record({
            "type": "event", "name": name, "cat": cat,
            "ts": (time.perf_counter_ns() - self._epoch) / 1e3,
            "tid": self._tid(), "args": attrs,
        })

    def clear(self) -> None:
        with self._lock:
            self._records.clear()

    @property
    def records(self) -> List[Dict[str, Any]]:
        """Finished records (spans appear when they close)."""
        with self._lock:
            return list(self._records)

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [r for r in self.records
                if r["type"] == "span" and (name is None or r["name"] == name)]

    # -- export --------------------------------------------------------------
    def chrome_trace(self, metadata: Optional[dict] = None) -> Dict[str, Any]:
        """The Chrome Trace Event JSON object (open at ui.perfetto.dev or
        chrome://tracing).  Spans become complete ``"X"`` events, instants
        become ``"i"`` events; ``ts``/``dur`` are microseconds."""
        pid = os.getpid()
        events: List[Dict[str, Any]] = []
        for r in self.records:
            if r["type"] == "span":
                events.append({
                    "name": r["name"], "cat": r["cat"] or "repro",
                    "ph": "X", "ts": r["ts"], "dur": r["dur"],
                    "pid": pid, "tid": r["tid"], "args": dict(r["args"]),
                })
            else:
                events.append({
                    "name": r["name"], "cat": r["cat"] or "repro",
                    "ph": "i", "s": "t", "ts": r["ts"],
                    "pid": pid, "tid": r["tid"], "args": dict(r["args"]),
                })
        out = {"traceEvents": events, "displayTimeUnit": "ms"}
        if metadata:
            out["otherData"] = dict(metadata)
        return out

    def write(self, path, metadata: Optional[dict] = None) -> None:
        """Write the Chrome-trace JSON (``default=repr`` keeps arbitrary
        span attrs from breaking the export)."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(metadata), f, default=repr)

    def write_jsonl(self, path) -> None:
        """One raw record per line — the grep/jq-friendly event log."""
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r, default=repr) + "\n")


# ------------------------------------------------------------ process-wide --
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-wide tracer (tests); returns the old one."""
    global _TRACER
    old, _TRACER = _TRACER, tracer
    return old


def enable() -> Tracer:
    _TRACER.enabled = True
    return _TRACER


def disable() -> Tracer:
    _TRACER.enabled = False
    return _TRACER


def tracing_enabled() -> bool:
    return _TRACER.enabled


def span(name: str, cat: str = "", **attrs):
    """Span on the process-wide tracer — the one-liner every layer uses::

        with obs.span("compiler.compile", graph=g.name):
            ...
    """
    return _TRACER.span(name, cat, **attrs)


def instant(name: str, cat: str = "", **attrs) -> None:
    _TRACER.instant(name, cat, **attrs)


def write_trace(path, metadata: Optional[dict] = None) -> None:
    _TRACER.write(path, metadata)
