"""``repro.obs`` — the observability spine: tracing, metrics, profiling.

Every layer of the reproduction reports through this package:

* the **pass pipeline** emits one span per registered pass
  (``compiler.pass``) under a ``compiler.pipeline`` span with graph
  size/factor/mode attributes;
* **``compiler.compile``** spans each request and counts how it was served
  (``compile.memo_hit`` / ``compile.replay`` / ``compile.measure`` /
  ``compile.build``);
* the **compile cache** counts health events (``cache.corrupt``,
  ``cache.stale_jax_version``) that the old code swallowed silently;
* the **pallas backend** counts the per-region emission-tier mix
  (``emission.tier.*``) and records the degradation reason next to the
  tier in ``report.emission``;
* the **plan registry** counts hits/misses/measure/replay per phase and
  fallbacks (``registry.*``), and publishes its stats as a snapshot view;
* the **serve engine** wraps warmup/prefill/per-token decode in spans and
  records TTFT + per-token latency histograms, so one ``generate()`` call
  under ``--trace`` yields a complete nested timeline.

Quick use::

    from repro import obs
    obs.enable()                          # tracing (metrics are always on)
    with obs.span("my.step", n=3):
        ...
    obs.count("my.counter")               # counter + trace instant
    obs.observe("my.latency_s", 0.004)    # histogram sample
    obs.write_trace("trace.json")         # open at ui.perfetto.dev
    obs.snapshot()                        # pure-JSON metrics state

Naming conventions and the Perfetto workflow live in
``docs/observability.md``.
"""
from __future__ import annotations

from typing import Any, Dict

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_metrics, format_phases, format_snapshot,
                      set_default_metrics)
from .profile import profile
from .trace import (Tracer, disable, enable, get_tracer, instant, set_tracer,
                    span, tracing_enabled, write_trace)

__all__ = [
    "Tracer", "get_tracer", "set_tracer", "enable", "disable",
    "tracing_enabled", "span", "instant", "write_trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_metrics",
    "set_default_metrics", "format_snapshot", "format_phases",
    "count", "observe", "gauge", "snapshot", "register_view", "profile",
]


def count(name: str, n: int = 1, **attrs) -> None:
    """Increment counter ``name`` and, when tracing, drop an instant event
    with ``attrs`` at the same point — the one-call form for the "counter
    events" the cache/registry/backend emit."""
    default_metrics().counter(name).inc(n)
    tr = get_tracer()
    if tr.enabled:
        tr.instant(name, **attrs)


def observe(name: str, value: float) -> None:
    """Record one histogram sample (latency, size, ...)."""
    default_metrics().histogram(name).record(value)


def gauge(name: str, value: float) -> None:
    """Set gauge ``name`` to ``value``."""
    default_metrics().gauge(name).set(value)


def snapshot(include_views: bool = True) -> Dict[str, Any]:
    """Process-wide metrics snapshot (pure JSON — see MetricsRegistry)."""
    return default_metrics().snapshot(include_views=include_views)


def register_view(name: str, fn) -> None:
    """Publish an existing stats object into every future snapshot."""
    default_metrics().register_view(name, fn)
