"""Profiling hook: bracket a span with optional ``jax.profiler`` capture.

``obs.profile("prefill", logdir="...")`` is the one-command answer to
"where does the time go *inside* one compiled step" — the span lands in the
obs trace (wall-clock attribution across our own layers) and, when a
``logdir`` is given, a ``jax.profiler`` trace capture brackets the same
window so XLA/TPU-level cost shows up in TensorBoard/Perfetto alongside it.

The jax profiler is strictly optional: import/start/stop failures degrade
to the plain span with a counted ``profile.unavailable`` event — profiling
hooks must never take the serving path down.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

from . import metrics as _metrics
from . import trace as _trace


@contextlib.contextmanager
def profile(name: str = "profile", logdir: Optional[str] = None,
            **attrs) -> Iterator[object]:
    """Span (always) + ``jax.profiler`` trace capture (when ``logdir``).

        with obs.profile("serve.prefill", logdir="/tmp/jaxprof"):
            engine.prefill(tokens)

    View the capture with ``tensorboard --logdir /tmp/jaxprof`` or load the
    generated ``.trace.json.gz`` into ui.perfetto.dev.
    """
    started = False
    if logdir is not None:
        try:
            import jax
            jax.profiler.start_trace(str(logdir))
            started = True
        except Exception as e:  # noqa: BLE001 — profiler absence is not fatal
            _metrics.default_metrics().counter("profile.unavailable").inc()
            _trace.instant("profile.unavailable", error=repr(e))
    span = _trace.get_tracer().span(name, cat="profile",
                                    profiled=started, **attrs)
    try:
        with span as sp:
            yield sp
    finally:
        if started:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception as e:  # noqa: BLE001
                _trace.instant("profile.stop_failed", error=repr(e))
