from . import engine
