from . import engine, scheduler
