"""Serving engine: batched prefill + decode with a pumped KV stream.

Continuous-batching-lite: a request pool is packed into fixed (batch,
max_len) slots; prefill fills each slot's cache, then decode steps advance
all active slots together.  Kernel-scale temporal vectorization shows up in
the attention path (chunked/pumped KV reads); engine-scale, the decode loop
is the fast domain and cache DMA the slow one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as mesh_mod
from repro.models import model as model_mod


@dataclasses.dataclass
class ServeConfig:
    batch: int = 4
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0
    cache_dtype: str = "float32"


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 mesh=None):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.mesh = mesh or mesh_mod.make_host_mesh()
        cdt = jnp.dtype(scfg.cache_dtype)
        self._decode = jax.jit(
            lambda p, c, b: model_mod.decode_step(cfg, p, b, c))
        self._cache_factory = lambda: model_mod.init_cache(
            cfg, scfg.batch, scfg.max_len, cdt)

    def prefill(self, tokens: jax.Array, enc_out=None):
        """tokens: (B, S_prompt) — returns (cache, last_logits)."""
        cache = self._cache_factory()
        batch = {"tokens": tokens}
        if enc_out is not None:
            batch["enc_out"] = enc_out
        with self.mesh:
            logits, cache = self._decode(self.params, cache, batch)
        return cache, logits[:, -1]

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature)

    def generate(self, prompt_tokens: jax.Array, n_new: int,
                 enc_out=None) -> jax.Array:
        """Greedy/temperature generation.  Returns (B, n_new) tokens."""
        cache, last = self.prefill(prompt_tokens, enc_out)
        key = jax.random.PRNGKey(self.scfg.seed)
        toks = []
        cur = self._sample(last, key)[:, None]
        for i in range(n_new):
            toks.append(cur)
            batch = {"tokens": cur.astype(jnp.int32)}
            if enc_out is not None:
                batch["enc_out"] = enc_out
            with self.mesh:
                logits, cache = self._decode(self.params, cache, batch)
            key, sub = jax.random.split(key)
            cur = self._sample(logits[:, -1], sub)[:, None]
        return jnp.concatenate(toks, axis=1)
