"""Serving engine: batched prefill + decode over measured execution plans.

Continuous-batching-lite: a request pool is packed into fixed (batch,
max_len) slots; prefill fills each slot's cache, then decode steps advance
all active slots together.  Kernel-scale temporal vectorization shows up in
the attention path (chunked/pumped KV reads); engine-scale, the decode loop
is the fast domain and cache DMA the slow one.

Two serving-time disciplines live here:

* **Plan warmup** — when the model routes kernels through the plan registry
  (``cfg.kernel_plan == 'measure'``), the engine pre-measures the bucket
  grid at construction (:meth:`Engine.warmup`), so the first real token hits
  a warm measured plan instead of paying an autotune search mid-request.
* **Timing separation** — prefill/decode run through
  :class:`repro.launch.steps.StepTimer`: the first call of each phase
  (tracing + XLA compile + any cold plan measurement) is recorded as compile
  time, steady-state step time accumulates separately, and
  :meth:`Engine.stats` reports both — warmup cost never pollutes the
  steady-state numbers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig
from repro.launch import mesh as mesh_mod
from repro.launch.steps import StepTimer
from repro.models import model as model_mod
from repro.testing import faults


@dataclasses.dataclass
class ServeConfig:
    batch: int = 4
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0
    cache_dtype: str = "float32"
    # pre-measure the plan-registry bucket grid at engine construction
    # (no-op when the model's kernel paths don't route through the registry)
    warmup: bool = True
    # override cfg.kernel_plan for this engine ('measure' | 'direct' | None)
    kernel_plan: Optional[str] = None
    # path to a published plan artifact (repro.tune): warmup verifies and
    # installs its entries first, so every artifact-covered bucket replays
    # with zero autotune measurements (docs/robustness.md "Artifact
    # lifecycle").  None = tune locally at warmup, the classic path.
    plan_artifact: Optional[str] = None
    # host-side non-finite check on each step's logits, degrading the step
    # to the plain-jnp fallback instead of emitting garbage tokens.  Costs a
    # device sync per token, so it is opt-in; chaos runs get it implicitly
    # whenever fault rules are installed.
    nan_guard: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 mesh=None):
        if scfg.kernel_plan and scfg.kernel_plan != cfg.kernel_plan:
            cfg = dataclasses.replace(cfg, kernel_plan=scfg.kernel_plan)
        if not cfg.fresh_prefill_kernel:
            # this engine's prefill always builds a fresh cache (pos == 0),
            # which is exactly the contract the flag requires — enable the
            # kernel prefill route so serving hits the measured plans
            cfg = dataclasses.replace(cfg, fresh_prefill_kernel=True)
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.mesh = mesh or mesh_mod.make_host_mesh()
        cdt = jnp.dtype(scfg.cache_dtype)
        self._decode = jax.jit(
            lambda p, c, b: model_mod.decode_step(cfg, p, b, c))
        self._cache_factory = lambda batch=None: model_mod.init_cache(
            cfg, batch or scfg.batch, scfg.max_len, cdt)
        # the bottom rung of the degradation ladder: a fully compiler-free
        # config (plain-jnp attention/ssm, no plan registry) the engine can
        # re-run any failing step through.  Built lazily — fault-free
        # serving never pays the extra trace/compile.
        self._direct_cfg = dataclasses.replace(
            cfg, kernel_plan="direct", attention_impl="xla_chunked",
            ssm_impl="xla")
        # continuation prefill (chunked prefill / preemption resume): same
        # model, but s > 1 steps into a cache already holding pos > 0
        # tokens — attention must mask over the whole written prefix and
        # the SSM path seeds from cached state, so the flash fresh-prefill
        # route is off and prefill_continuation on.  Traced lazily: plain
        # whole-prompt serving never pays the extra compile.
        self._cont_cfg = dataclasses.replace(
            cfg, prefill_continuation=True, fresh_prefill_kernel=False)
        self._cont_fn: Optional[Any] = None
        self._fallback_fn: Optional[Any] = None
        self._fallback_cont_fn: Optional[Any] = None
        self.degraded_requests = 0
        self._req_degraded = False
        self.timer = StepTimer()
        self.warmup_s = 0.0
        self.warmup_report: List[Dict[str, Any]] = []
        self.artifact_report: Optional[Dict[str, Any]] = None
        # capture the registry once: stats()/warmup() must keep talking to
        # the instance this engine's model layers were warmed against, even
        # if the process default is swapped later (tests/benchmarks do)
        self._reg = None
        if cfg.kernel_plan == "measure":
            from repro.compiler.registry import default_registry
            self._reg = default_registry()
        # publish this engine's timing stats into the unified metrics
        # snapshot (a view over StepTimer, not a copy; the most recently
        # constructed engine owns the slot)
        obs.register_view("serve.engine", self.stats)
        # resolved once: the decode loop records per-token latency straight
        # into the histogram object, skipping the name lookup per step
        self._step_hist = obs.default_metrics().histogram(
            "serve.decode_step_s")
        if scfg.warmup:
            self.warmup()

    # ----------------------------------------------------------- warmup ----
    def _registry(self):
        return self._reg

    def warmup(self) -> List[Dict[str, Any]]:
        """Pre-measure the plan-registry bucket grid for this model/shape.

        Enumerates ``models.transformer.plan_requests`` (one request per
        kernel × sequence bucket up to ``max_len``) and compiles each through
        the registry — cold requests pay the measured autotune here, at
        launch; repeat processes replay winners from the persistent compile
        cache.  Time spent is reported as ``warmup_s``, never as step time.
        """
        reg = self._registry()
        if reg is None:
            return []
        from repro.models import transformer
        leaves = jax.tree.leaves(self.params)
        dtype = str(jnp.result_type(leaves[0].dtype if leaves
                                    else jnp.float32,
                                    self.cfg.activation_dtype))
        t0 = time.perf_counter()
        with obs.span("serve.warmup", cat="serve", batch=self.scfg.batch,
                      max_len=self.scfg.max_len) as sp:
            if self.scfg.plan_artifact:
                # warm start: verified artifact entries land in the plan
                # store first, so the grid below *replays* them — zero
                # measurements for every verified bucket; rejected/missing
                # entries fall through to the local measured path
                self.artifact_report = reg.preload_artifact(
                    self.scfg.plan_artifact)
                sp.set(artifact_verified=self.artifact_report["verified"],
                       artifact_rejected=self.artifact_report["rejected"])
            # cached=True: only the plans this cached serving loop can execute
            reqs = transformer.plan_requests(self.cfg, self.scfg.batch,
                                             self.scfg.max_len, dtype=dtype,
                                             cached=True)
            self.warmup_report = reg.warmup(reqs)
            # warmup is per-request isolated (PlanRegistry.warmup): a failed
            # bucket is a record with an "error" string, not an abort — and
            # the span says how many so launch telemetry shows partial warmup
            sp.set(plans=len(self.warmup_report),
                   failed=sum(1 for r in self.warmup_report if "error" in r))
        self.warmup_s += time.perf_counter() - t0
        return self.warmup_report

    # ------------------------------------------------- step-time estimate --
    def measured_step_time_ms(self) -> Optional[float]:
        """Measured decode-step estimate (ms) for the scheduler's virtual
        clock, or None when nothing has been measured yet.

        Preference order: the p50 of real decode steps this engine has
        already served (the ``serve.decode_step_s`` histogram — at least
        three samples, so one cold compile outlier cannot be the estimate),
        else a floor estimate from the measured plan timings the warmup /
        artifact carried (winner kernel time per decode kernel × the layer
        count that runs it).  The plan-derived floor excludes XLA glue
        around the kernels, so it under-estimates — still far closer to
        real plan speed than a constant, which is the point: deadline-aware
        shedding should reflect what the measured plans can actually do."""
        if self._step_hist.count >= 3:
            p50 = self._step_hist.percentile(50)
            if p50:
                return p50 * 1e3
        # plan-derived floor: worst (largest-bucket) winner per decode
        # kernel, scaled by how many layers run it
        best: Dict[str, float] = {}
        for rec in self.warmup_report:
            us = rec.get("winner_us")
            kern = rec.get("kernel")
            if us and kern in ("decode_attention", "ssd_decode"):
                best[kern] = max(best.get(kern, 0.0), float(us))
        if not best:
            return None
        cfg = self.cfg
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            n_attn = cfg.n_layers // cfg.hybrid_attn_every
        elif cfg.family == "ssm":
            n_attn = 0
        else:
            n_attn = cfg.n_layers
        n_ssm = cfg.n_layers - n_attn if cfg.family in ("ssm", "hybrid") \
            else 0
        ms = (best.get("decode_attention", 0.0) * n_attn
              + best.get("ssd_decode", 0.0) * n_ssm) / 1e3
        return ms or None

    # ------------------------------------------------------------ serving --
    def _fallback(self):
        """The plain-jnp bottom-rung step fn (lazily traced/compiled)."""
        if self._fallback_fn is None:
            obs.count("engine.fallback_build")
            cfg = self._direct_cfg
            self._fallback_fn = jax.jit(
                lambda p, c, b: model_mod.decode_step(cfg, p, b, c))
        return self._fallback_fn

    def _cont(self):
        """Continuation-prefill step fn (lazily traced/compiled)."""
        if self._cont_fn is None:
            cfg = self._cont_cfg
            self._cont_fn = jax.jit(
                lambda p, c, b: model_mod.decode_step(cfg, p, b, c))
        return self._cont_fn

    def _fallback_cont(self):
        """Bottom-rung continuation prefill: plain-jnp paths with the
        continuation masking/state-seeding kept on."""
        if self._fallback_cont_fn is None:
            obs.count("engine.fallback_build", phase="prefill_chunk")
            cfg = dataclasses.replace(
                self._direct_cfg, prefill_continuation=True,
                fresh_prefill_kernel=False)
            self._fallback_cont_fn = jax.jit(
                lambda p, c, b: model_mod.decode_step(cfg, p, b, c))
        return self._fallback_cont_fn

    def _nan_guarded(self) -> bool:
        return self.scfg.nan_guard or faults.active()

    def _run_step(self, phase: str, cache, batch):
        """One guarded model step: the planned path, degrading to the
        plain-jnp fallback on any failure — an exception out of the compiled
        step, an injected ``engine.decode``/``engine.prefill``/
        ``engine.prefill_chunk`` fault, or (guard on) non-finite logits.
        The fallback recomputes from the *pre-step* cache, so a poisoned
        kernel cannot leak NaNs into the carried KV/SSD state.  Raises only
        if the bottom rung itself fails."""
        cont = phase == "prefill_chunk"
        try:
            faults.check(f"engine.{phase}")
            step_fn = self._cont() if cont else self._decode
            with self.mesh:
                logits, new_cache = self.timer.run(
                    phase, step_fn, self.params, cache, batch)
            if self._nan_guarded() and \
                    not bool(jnp.isfinite(logits[:, -1]).all()):
                raise FloatingPointError(
                    f"non-finite logits from the planned {phase} step")
            return logits, new_cache
        except Exception as e:  # noqa: BLE001 — serving must not die
            obs.count("engine.degraded", phase=phase,
                      reason=type(e).__name__)
            self._req_degraded = True
            fb = self._fallback_cont() if cont else self._fallback()
            with self.mesh:
                return self.timer.run(phase, fb, self.params, cache, batch)

    def prefill(self, tokens: jax.Array, enc_out=None):
        """tokens: (B, S_prompt) — returns (cache, last_logits)."""
        cache = self._cache_factory(int(tokens.shape[0]))
        batch = {"tokens": tokens}
        if enc_out is not None:
            batch["enc_out"] = enc_out
        with obs.span("serve.prefill", cat="serve",
                      batch=int(tokens.shape[0]),
                      prompt_len=int(tokens.shape[1])):
            logits, cache = self._run_step("prefill", cache, batch)
        return cache, logits[:, -1]

    def prefill_chunk(self, cache, tokens: jax.Array, enc_out=None):
        """Continuation prefill: advance ``cache`` (scalar-pos, possibly
        already holding tokens) by one chunk of ``tokens`` (B, S_chunk).
        Returns ``(cache, last_logits)``.  At pos == 0 this computes the
        same answer as :meth:`prefill` (without the flash fresh-cache
        route); at pos > 0 the chunk attends over the whole written prefix
        and the SSM scan is seeded from the cached recurrent state — the
        mechanism under the scheduler's chunked prefill and
        preemption-resume paths."""
        batch = {"tokens": tokens}
        if enc_out is not None:
            batch["enc_out"] = enc_out
        with obs.span("serve.prefill_chunk", cat="serve",
                      batch=int(tokens.shape[0]),
                      chunk_len=int(tokens.shape[1])):
            obs.count("engine.prefill_chunk")
            logits, cache = self._run_step("prefill_chunk", cache, batch)
        return cache, logits[:, -1]

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature)

    def _decode_token(self, cache, batch):
        """One instrumented decode step — the serving hot path.

        The tracer-off path is kept deliberately lean (one enabled check,
        one perf_counter pair, one cached-histogram append); its overhead
        vs the uninstrumented step is measured per run by
        ``benchmarks/serve_report.py`` (``engine.obs_overhead``, bar <2%).
        """
        t0 = time.perf_counter()
        tr = obs.get_tracer()
        if tr.enabled:
            with tr.span("serve.decode", cat="serve"):
                logits, cache = self._run_step("decode", cache, batch)
        else:
            logits, cache = self._run_step("decode", cache, batch)
        self._step_hist.record(time.perf_counter() - t0)
        return logits, cache

    def generate(self, prompt_tokens: jax.Array, n_new: int,
                 enc_out=None, return_logits: bool = False):
        """Greedy/temperature generation.  Returns (B, n_new) tokens, or
        with ``return_logits=True`` a ``(tokens, logits)`` pair where
        ``logits`` is the fp32 (n_new, B, V) stack of the distributions each
        returned token was sampled from (the chaos suite's parity surface).

        Completion is the contract: any step failure degrades through
        :meth:`_run_step` to the plain-jnp rung rather than raising, and a
        request that needed any degraded step is counted in
        ``degraded_requests`` / the ``serve.generate`` span."""
        t_start = time.perf_counter()
        self._req_degraded = False
        with obs.span("serve.generate", cat="serve",
                      batch=int(prompt_tokens.shape[0]),
                      prompt_len=int(prompt_tokens.shape[1]),
                      n_new=n_new) as gspan:
            cache, last = self.prefill(prompt_tokens, enc_out)
            key = jax.random.PRNGKey(self.scfg.seed)
            toks = []
            lgs = [last.astype(jnp.float32)]
            cur = self._sample(last, key)[:, None]
            # time-to-first-token: prefill + first sample, host-visible
            ttft = time.perf_counter() - t_start
            obs.observe("serve.ttft_s", ttft)
            gspan.set(ttft_s=round(ttft, 6))
            for i in range(n_new):
                toks.append(cur)
                batch = {"tokens": cur.astype(jnp.int32)}
                if enc_out is not None:
                    batch["enc_out"] = enc_out
                logits, cache = self._decode_token(cache, batch)
                lgs.append(logits[:, -1].astype(jnp.float32))
                key, sub = jax.random.split(key)
                cur = self._sample(logits[:, -1], sub)[:, None]
            obs.count("serve.tokens",
                      n_new * int(prompt_tokens.shape[0]))
            if self._req_degraded:
                self.degraded_requests += 1
                obs.count("serve.degraded_request")
                gspan.set(degraded=True)
        out = jnp.concatenate(toks, axis=1)
        if return_logits:
            return out, jnp.stack(lgs[:n_new])
        return out

    # -------------------------------------------------- continuous batching --
    def serve_stream(self, requests, *, max_slots: Optional[int] = None,
                     collect_logits: bool = False, step_hook=None,
                     prefill_chunk_tokens: Optional[int] = None,
                     preempt_policy: Optional[str] = None,
                     max_queue: Optional[int] = None,
                     deadline_aware: bool = False,
                     step_time_ms: Optional[float] = None,
                     return_shed: bool = False):
        """Serve a *stream* of requests through the continuous-batching
        scheduler (:mod:`repro.serve.scheduler`): ``max_slots`` decode
        lanes over one per-slot-pos cache, FIFO admission of arrivals into
        freed lanes, grouped prefill + batched decode per step.

        ``requests`` is a sequence of :class:`scheduler.Request` (virtual
        arrival steps — use :func:`scheduler.synthetic_workload` for seeded
        traces).  Returns ``[CompletedRequest]`` sorted by rid; each
        request's tokens are identical to running it alone through
        :meth:`generate` (per-request PRNG key chains).  ``max_slots``
        defaults to the engine batch — the decode-plan buckets were warmed
        at that batch, so the default keeps the stream on warm plans.

        Overload controls (see ``docs/serving.md`` § Overload behavior):
        ``prefill_chunk_tokens`` bounds per-step prefill work (long prompts
        admit over several steps), ``preempt_policy`` enables slot
        preemption (``'longest_remaining'`` | ``'lowest_priority'``),
        ``max_queue`` bounds the admission queue (overflow is shed with
        reason ``queue_full``), and ``deadline_aware=True`` sheds requests
        whose ``deadline_ms`` is provably unmeetable.  With
        ``return_shed=True`` the result is ``(completed, shed)``.

        ``step_time_ms`` maps wall-clock deadlines onto the scheduler's
        virtual step clock.  ``None`` (the default) seeds it from measured
        timings (:meth:`measured_step_time_ms` — served-step p50, else the
        warmup/artifact plan timings), falling back to the 1.0 ms constant
        only when nothing has been measured — so ``deadline_unmeetable``
        sheds reflect real plan speed, not a guess.
        """
        from . import scheduler as sched_mod
        if step_time_ms is None:
            measured = self.measured_step_time_ms()
            step_time_ms = measured if measured else 1.0
            obs.count("sched.step_time_seeded",
                      source="measured" if measured else "constant",
                      step_time_ms=round(step_time_ms, 4))
        sched = sched_mod.Scheduler(self, max_slots=max_slots,
                                    collect_logits=collect_logits,
                                    step_hook=step_hook,
                                    prefill_chunk_tokens=prefill_chunk_tokens,
                                    preempt_policy=preempt_policy,
                                    max_queue=max_queue,
                                    deadline_aware=deadline_aware,
                                    step_time_ms=step_time_ms)
        completed = sched.run(requests)
        if return_shed:
            return completed, sorted(sched.shed.values(),
                                     key=lambda s: s.rid)
        return completed

    # ------------------------------------------------------------ reports --
    def stats(self) -> Dict[str, Any]:
        """Timing split: plan warmup vs per-phase compile vs steady-state
        step time, plus plan-registry hit/miss counters when active."""
        reg = self._registry()
        return {
            "warmup_s": round(self.warmup_s, 4),
            "plans_warmed": len(self.warmup_report),
            "warmup_failed": sum(1 for r in self.warmup_report
                                 if "error" in r),
            # fresh measurements paid at warmup: the warm-start assertion
            # surface — an artifact-loaded replica must show 0 here
            "warmup_measured": sum(1 for r in self.warmup_report
                                   if r.get("measured")
                                   and not r.get("replayed")),
            "artifact": self.artifact_report,
            "degraded_requests": self.degraded_requests,
            "phases": self.timer.stats(),
            "registry": reg.stats.as_dict() if reg is not None else None,
        }
