"""Continuous-batching scheduler over the :class:`repro.serve.Engine`.

The serving analogue of the paper's multi-pumping: a fixed resource budget
(``max_slots`` preallocated KV-cache lanes + the warmed decode-plan bucket)
is kept busy at a higher effective rate by interleaving *independent*
requests through it, instead of draining one batch at a time.  The step
loop runs mixed-phase iterations:

    arrivals -> FIFO admission -> grouped prefill -> insert -> batched decode

* **Slot manager** — ``max_slots`` decode lanes over one per-slot-pos cache
  (``models.model.init_cache(per_slot_pos=True)``: the ``pos`` leaf is a
  ``(B,)`` vector, so each cache row advances at its own depth).  Free-list
  allocation with double-alloc/double-free guards; a freed lane keeps
  masked-out garbage until re-admission overwrites it.
* **Admission** — waiting requests are admitted FIFO into freed slots
  between decode steps.  Admitted requests are grouped by *exact* prompt
  length and prefilled on a fresh scalar-pos cache (token-level padding
  would corrupt SSM state / the conv tail — the plan registry does its own
  construction-exact padding internally), then scattered into their lanes
  with :func:`insert_rows`.  The prefill batch pads up to the engine's
  warmed batch size so the grouped prefill still hits the warm plan bucket.
* **Decode** — one jitted ``decode_step`` over the whole slot cache per
  scheduler step.  Free lanes decode garbage harmlessly (their write masks
  are all-false once ``pos`` reaches the cache end and their outputs are
  never read).  Per-request sampling uses per-request PRNG key chains, so
  every request's tokens are bit-identical to running it alone through
  :meth:`Engine.generate`.

Time is *virtual*: arrivals are measured in scheduler steps, so a seeded
:func:`synthetic_workload` replays deterministically — the property the
invariant harness in ``tests/test_scheduler.py`` is built on (no slot
leak/double-allocation, FIFO admission, request conservation after every
step, per-request token parity vs solo generation).

Failure behaviour rides the engine's degradation ladder for free: prefill
and decode route through :meth:`Engine._run_step`, so an injected fault or
a non-finite step re-runs on the plain-jnp rung and the affected in-flight
requests are marked degraded rather than dropped (``sched.slot_free`` is
this module's own fault site: a fault while reclaiming a lane still frees
it and counts the request degraded).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.testing import faults


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request in a stream.

    ``arrival`` is in virtual scheduler steps (deterministic replay);
    ``tokens`` is the (S,) prompt.
    """
    rid: int
    tokens: np.ndarray
    n_new: int
    arrival: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class CompletedRequest:
    """Per-request result + latency accounting for one streamed request."""
    rid: int
    tokens: np.ndarray                      # (n_new,) generated tokens
    arrival: int
    admitted_step: int
    done_step: int
    queue_wait_steps: int                   # admitted_step - arrival
    ttft_s: float                           # arrival -> first token (wall)
    tpot_s: float                           # mean inter-token wall time
    degraded: bool = False
    logits: Optional[np.ndarray] = None     # (n_new, V) fp32 when collected


class SlotManager:
    """Free-list allocator over ``n`` decode lanes with leak guards.

    Double allocation and double free raise immediately — the invariant
    harness runs with these guards live, so a scheduler bug surfaces as a
    hard error inside the trace rather than as silent cache corruption.
    """

    def __init__(self, n: int):
        if n <= 0:
            raise ValueError(f"max_slots must be positive, got {n}")
        self.n = n
        self._free: List[int] = list(range(n - 1, -1, -1))  # pop() -> slot 0
        self.owner: Dict[int, int] = {}

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> int:
        return self.n - len(self._free)

    def alloc(self, rid: int) -> int:
        if not self._free:
            raise RuntimeError("slot allocation with no free slots")
        slot = self._free.pop()
        if slot in self.owner:
            raise RuntimeError(
                f"slot {slot} double-allocated (owned by request "
                f"{self.owner[slot]}, requested by {rid})")
        self.owner[slot] = rid
        return slot

    def free(self, slot: int) -> None:
        if slot not in self.owner:
            raise RuntimeError(f"slot {slot} double-freed (no owner)")
        del self.owner[slot]
        self._free.append(slot)


def synthetic_workload(n_requests: int, *, seed: int = 0,
                       prompt_lens: Sequence[int] = (4, 8),
                       new_tokens: Sequence[int] = (2, 4),
                       arrival_rate: float = 0.5,
                       vocab: int = 100) -> List[Request]:
    """Deterministic synthetic request trace.

    Seeded geometric inter-arrival gaps (mean ``1/arrival_rate - 1`` steps
    between requests) and prompt/completion lengths drawn from the given
    sets — lengths come from a *set* rather than a continuous range so a
    trace touches a bounded number of prefill shapes (one jit trace per
    distinct prompt length).  Same seed, same trace: the test harness
    replays it through both the scheduler and solo generation.
    """
    if not 0.0 < arrival_rate <= 1.0:
        raise ValueError(f"arrival_rate must be in (0, 1], got {arrival_rate}")
    rng = np.random.default_rng(seed)
    reqs, t = [], 0
    for rid in range(n_requests):
        if rid and arrival_rate < 1.0:
            t += int(rng.geometric(arrival_rate)) - 1
        reqs.append(Request(
            rid=rid,
            tokens=rng.integers(0, vocab,
                                size=int(rng.choice(prompt_lens)),
                                dtype=np.int32),
            n_new=int(rng.choice(new_tokens)),
            arrival=t))
    return reqs


def insert_rows(big_cache, small_cache, slots, n_rows: int):
    """Scatter ``n_rows`` prefilled rows of ``small_cache`` into the
    per-slot lanes ``slots`` of ``big_cache``.

    Cache leaves are stacked over layers — ``(n_layers, B, ...)`` (the
    hybrid family adds an ``(n_groups, B, ...)`` ``shared_attn`` group,
    which the same rule covers).  The ``pos`` leaf is the one asymmetric
    case: scalar-per-layer ``(n_layers,)`` in the fresh prefill cache vs
    per-slot ``(n_layers, B)`` in the big cache — each admitted lane's pos
    is set to its prompt length.  ``small_cache`` may carry padding rows
    beyond ``n_rows`` (prefill pads the batch up to the warm plan bucket);
    they are dropped here.
    """
    slots = jnp.asarray(slots, jnp.int32)

    def one(path, big, small):
        last = path[-1]
        key = getattr(last, "key", getattr(last, "name", None))
        if key == "pos":
            return big.at[:, slots].set(small[:, None])
        return big.at[:, slots].set(small[:, :n_rows])

    return jax.tree_util.tree_map_with_path(one, big_cache, small_cache)


@dataclasses.dataclass
class _Lane:
    """In-flight per-slot decode state."""
    req: Request
    key: jax.Array                  # per-request PRNG chain (parity w/ solo)
    cur: int = 0                    # last sampled token (next decode input)
    emitted: List[int] = dataclasses.field(default_factory=list)
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    admitted_step: int = 0
    admit_wall: float = 0.0
    first_tok_wall: float = 0.0
    degraded: bool = False


class Scheduler:
    """The continuous-batching step loop.  Built by
    :meth:`Engine.serve_stream`; usable directly when a test needs to drive
    steps one at a time.

    ``step_hook(state_dict)`` (if given) runs after every scheduler step
    with a snapshot: ``step, occupancy, queue, pending, active, completed,
    admitted`` (rids admitted this step) — the surface the invariant
    harness asserts on.
    """

    def __init__(self, engine, *, max_slots: Optional[int] = None,
                 collect_logits: bool = False,
                 step_hook: Optional[Callable[[Dict[str, Any]], None]] = None):
        from repro.models import model as model_mod
        cfg = engine.cfg
        if cfg.family == "encdec":
            raise ValueError(
                "continuous batching is not supported for the encdec "
                "family (cross-attention caches are per-request)")
        self.engine = engine
        self.max_slots = int(max_slots or engine.scfg.batch)
        self.collect_logits = collect_logits
        self.step_hook = step_hook
        self.slots = SlotManager(self.max_slots)
        cdt = jnp.dtype(engine.scfg.cache_dtype)
        self.cache = model_mod.init_cache(cfg, self.max_slots,
                                          engine.scfg.max_len, cdt,
                                          per_slot_pos=True)
        self.active: Dict[int, _Lane] = {}
        self.queue: deque = deque()
        self.pending: List[Request] = []
        self.completed: Dict[int, CompletedRequest] = {}
        self.step = 0
        self._total = 0

    # ------------------------------------------------------------ helpers --
    def _sample_row(self, logits_row, key) -> int:
        """Sample one token for one lane — same math as
        ``Engine._sample`` on a (1, V) batch, so a streamed request's
        tokens match its solo run exactly (per-request key chain)."""
        eng = self.engine
        if eng.scfg.temperature <= 0.0:
            return int(np.argmax(np.asarray(logits_row)))
        out = jax.random.categorical(
            key, jnp.asarray(logits_row)[None] / eng.scfg.temperature)
        return int(out[0])

    def _finish(self, slot: int, lane: _Lane) -> None:
        """Complete the lane's request and reclaim its slot.  A fault at
        the ``sched.slot_free`` site marks the request degraded but the
        slot is reclaimed regardless — a lane is never leaked."""
        try:
            faults.check("sched.slot_free", slot=slot, rid=lane.req.rid)
        except Exception as e:  # noqa: BLE001 — serving must not die
            obs.count("sched.slot_free_fault", reason=type(e).__name__)
            lane.degraded = True
        self.slots.free(slot)
        del self.active[slot]
        now = time.perf_counter()
        r = lane.req
        n = len(lane.emitted)
        tpot = ((now - lane.first_tok_wall) / (n - 1)) if n > 1 else 0.0
        if lane.degraded:
            self.engine.degraded_requests += 1
            obs.count("serve.degraded_request")
        obs.observe("serve.request_ttft_s",
                    lane.first_tok_wall - lane.admit_wall)
        obs.observe("serve.request_tpot_s", tpot)
        obs.count("serve.stream_tokens", n)
        self.completed[r.rid] = CompletedRequest(
            rid=r.rid, tokens=np.asarray(lane.emitted, np.int32),
            arrival=r.arrival, admitted_step=lane.admitted_step,
            done_step=self.step,
            queue_wait_steps=lane.admitted_step - r.arrival,
            ttft_s=lane.first_tok_wall - lane.admit_wall, tpot_s=tpot,
            degraded=lane.degraded,
            logits=(np.stack(lane.logits).astype(np.float32)
                    if self.collect_logits else None))

    def _admit(self, admitted: List[Request]) -> None:
        """Grouped prefill + insert for this step's admissions."""
        eng = self.engine
        groups: Dict[int, List[Request]] = {}
        for r in admitted:
            groups.setdefault(r.prompt_len, []).append(r)
        for plen, grp in groups.items():
            toks = np.stack([np.asarray(r.tokens, np.int32) for r in grp])
            g = len(grp)
            # pad the prefill batch up to the engine's warmed batch size so
            # the grouped prefill hits the warm plan bucket (rows are
            # independent through attention/SSM/dropless-MoE; the padding
            # rows are dropped before insert)
            pad_to = eng.scfg.batch if g <= eng.scfg.batch else g
            if pad_to > g:
                toks = np.concatenate(
                    [toks, np.repeat(toks[-1:], pad_to - g, axis=0)])
            eng._req_degraded = False
            small, last = eng.prefill(jnp.asarray(toks))
            degraded = eng._req_degraded
            now = time.perf_counter()
            slot_ids = [self.slots.alloc(r.rid) for r in grp]
            self.cache = insert_rows(self.cache, small, slot_ids, g)
            last_h = np.asarray(last[:g], np.float32)
            for i, (r, slot) in enumerate(zip(grp, slot_ids)):
                lane = _Lane(req=r, key=jax.random.PRNGKey(eng.scfg.seed),
                             admitted_step=self.step, admit_wall=now,
                             degraded=degraded)
                tok0 = self._sample_row(last_h[i], lane.key)
                lane.emitted.append(tok0)
                lane.cur = tok0
                lane.first_tok_wall = time.perf_counter()
                if self.collect_logits:
                    lane.logits.append(last_h[i])
                self.active[slot] = lane
                obs.observe("sched.queue_wait_steps",
                            lane.admitted_step - r.arrival)
                if r.n_new <= 1:
                    self._finish(slot, lane)

    def _decode(self) -> None:
        """One batched decode step over every active lane."""
        eng = self.engine
        toks = np.zeros((self.max_slots, 1), np.int32)
        for slot, lane in self.active.items():
            toks[slot, 0] = lane.cur
        eng._req_degraded = False
        logits, self.cache = eng._decode_token(
            self.cache, {"tokens": jnp.asarray(toks)})
        degraded = eng._req_degraded
        rows = np.asarray(logits[:, -1], np.float32)
        for slot, lane in list(self.active.items()):
            if degraded:
                lane.degraded = True
            lane.key, sub = jax.random.split(lane.key)
            tok = self._sample_row(rows[slot], sub)
            lane.emitted.append(tok)
            if self.collect_logits:
                lane.logits.append(rows[slot])
            if len(lane.emitted) >= lane.req.n_new:
                self._finish(slot, lane)
            else:
                lane.cur = tok

    # --------------------------------------------------------------- loop --
    def submit(self, requests: Sequence[Request]) -> None:
        max_len = self.engine.scfg.max_len
        for r in requests:
            if r.prompt_len + r.n_new > max_len:
                raise ValueError(
                    f"request {r.rid}: prompt_len {r.prompt_len} + n_new "
                    f"{r.n_new} exceeds max_len {max_len}")
            if r.n_new < 1:
                raise ValueError(f"request {r.rid}: n_new must be >= 1")
        self.pending.extend(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self._total += len(requests)

    def run_step(self) -> None:
        """One scheduler step: arrivals -> admission -> batched decode."""
        while self.pending and self.pending[0].arrival <= self.step:
            self.queue.append(self.pending.pop(0))
        admitted: List[Request] = []
        while self.queue and len(admitted) < self.slots.free_count:
            # FIFO: always the queue head; a request never overtakes an
            # earlier one into a slot
            admitted.append(self.queue.popleft())
        if admitted:
            self._admit(admitted)
        if self.active:
            self._decode()
        obs.gauge("sched.slot_occupancy", self.slots.occupancy)
        obs.gauge("sched.queue_depth", len(self.queue))
        # conservation: every submitted request is exactly one of
        # not-yet-arrived / queued / in-flight / completed
        accounted = (len(self.pending) + len(self.queue) + len(self.active)
                     + len(self.completed))
        if accounted != self._total:
            raise RuntimeError(
                f"request conservation violated at step {self.step}: "
                f"{accounted} accounted vs {self._total} submitted")
        if self.step_hook is not None:
            self.step_hook({
                "step": self.step,
                "occupancy": self.slots.occupancy,
                "free": self.slots.free_count,
                "queue": [r.rid for r in self.queue],
                "pending": len(self.pending),
                "active": {s: ln.req.rid for s, ln in self.active.items()},
                "admitted": [r.rid for r in admitted],
                "completed": len(self.completed),
            })
        self.step += 1

    def run(self, requests: Sequence[Request]) -> List[CompletedRequest]:
        self.submit(requests)
        if not self.pending:
            return []
        # stall guard: with >=1 active lane every step emits >=1 token, so
        # total steps are bounded by arrivals span + total work + slack
        bound = (max(r.arrival for r in self.pending)
                 + sum(r.n_new for r in self.pending)
                 + len(self.pending) + self.max_slots + 8)
        with obs.span("serve.stream", cat="serve", requests=self._total,
                      max_slots=self.max_slots) as sp:
            while self.pending or self.queue or self.active:
                if self.step > bound:
                    raise RuntimeError(
                        f"scheduler stalled: step {self.step} exceeded "
                        f"bound {bound} with {len(self.completed)}/"
                        f"{self._total} completed")
                self.run_step()
            sp.set(steps=self.step, completed=len(self.completed))
        return [self.completed[rid] for rid in sorted(self.completed)]
