"""Continuous-batching scheduler over the :class:`repro.serve.Engine`.

The serving analogue of the paper's multi-pumping: a fixed resource budget
(``max_slots`` preallocated KV-cache lanes + the warmed decode-plan bucket)
is kept busy at a higher effective rate by interleaving *independent*
requests through it, instead of draining one batch at a time.  The step
loop runs mixed-phase iterations:

    arrivals -> shed sweep -> preemption -> admission -> prefill chunks
             -> batched decode

* **Slot manager** — ``max_slots`` decode lanes over one per-slot-pos cache
  (``models.model.init_cache(per_slot_pos=True)``: the ``pos`` leaf is a
  ``(B,)`` vector, so each cache row advances at its own depth).  Free-list
  allocation with double-alloc/double-free guards; a freed lane keeps
  masked-out garbage until re-admission overwrites it.
* **Admission** — waiting requests are admitted into freed slots between
  decode steps, ordered by ``(-priority, [deadline,] arrival, rid)`` — pure
  FIFO when no request carries a priority or deadline.  Short prompts are
  grouped by *exact* prompt length and prefilled on a fresh scalar-pos
  cache (token-level padding would corrupt SSM state / the conv tail — the
  plan registry does its own construction-exact padding internally), then
  scattered into their lanes with :func:`insert_rows`.  The prefill batch
  pads up to the engine's warmed batch size so the grouped prefill still
  hits the warm plan bucket.
* **Chunked prefill** — with ``prefill_chunk_tokens`` set, a prompt longer
  than the budget is admitted immediately but prefilled over several steps
  on a private scalar-pos side cache (``Engine.prefill_chunk`` — the
  continuation path attends over the whole written prefix and seeds the
  SSM scan from cached state), at most ``prefill_chunk_tokens`` prefill
  tokens per scheduler step across all lanes.  Decode lanes keep stepping
  between chunks, so one long prompt no longer head-of-line-blocks every
  in-flight request.  The finished side cache is scattered into the lane
  in one :func:`insert_rows`, after which the lane decodes normally.
* **Preemption** — with ``preempt_policy`` set, a queued request that
  strictly beats an active lane (higher priority, or — deadline-aware —
  strictly earlier absolute deadline) may evict it: the lane's cache rows
  are zeroed (``sched.evict_rows``), its generated-so-far tokens and PRNG
  chain are parked, and the request is requeued for bit-exact resume by
  recompute (prefill of ``prompt ++ emitted[:-1]`` restores the exact
  cache the next decode step needs — same content, same pos, same key
  chain, so the resumed tokens match the uninterrupted run).  Strictness
  plus a per-request preemption cap makes the policy livelock-free; at
  most one preemption per step keeps traces easy to reason about.
* **Admission control** — ``max_queue`` bounds the queue: an arrival that
  would overflow it is *shed* with reason ``queue_full`` (counted in
  ``sched.shed``, surfaced in :attr:`Scheduler.shed` — never silently
  dropped).  ``deadline_aware=True`` additionally sheds queued requests
  whose ``deadline_ms`` is provably unmeetable even if admitted this very
  step (reason ``deadline_unmeetable``).  Preempted requests were already
  admitted and are never shed — they always complete.
* **Decode** — one jitted ``decode_step`` over the whole slot cache per
  scheduler step.  Free and still-prefilling lanes decode garbage
  harmlessly (their outputs are never read and admission/insert overwrites
  the whole row, ``pos`` included).  Per-request sampling uses per-request
  PRNG key chains, so every request's tokens are bit-identical to running
  it alone through :meth:`Engine.generate`.

Time is *virtual*: arrivals are measured in scheduler steps, so a seeded
:func:`synthetic_workload` replays deterministically — the property the
invariant harness in ``tests/test_scheduler.py`` is built on (no slot
leak/double-allocation, admission order, request conservation after every
step including sheds and preemptions, per-request token parity vs solo
generation).  ``deadline_ms`` maps onto virtual steps through
``step_time_ms`` (default 1.0: one step per millisecond).

Failure behaviour rides the engine's degradation ladder for free: prefill,
prefill chunks and decode route through :meth:`Engine._run_step`, so an
injected fault or a non-finite step re-runs on the plain-jnp rung and the
affected in-flight requests are marked degraded rather than dropped.
``sched.slot_free``, ``sched.preempt`` and ``sched.evict_rows`` are this
module's own fault sites: a fault in any of them marks the request
degraded but the slot bookkeeping still completes — a lane is never
leaked, a preempted request is never lost.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.testing import faults

PREEMPT_POLICIES = ("longest_remaining", "lowest_priority")


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request in a stream.

    ``arrival`` is in virtual scheduler steps (deterministic replay);
    ``tokens`` is the (S,) prompt.  ``priority`` orders admission and
    preemption (higher wins; default 0 keeps pure FIFO).  ``deadline_ms``
    is a completion deadline relative to arrival, interpreted through the
    scheduler's ``step_time_ms``; ``None`` = best-effort.
    """
    rid: int
    tokens: np.ndarray
    n_new: int
    arrival: int = 0
    priority: int = 0
    deadline_ms: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class CompletedRequest:
    """Per-request result + latency accounting for one streamed request."""
    rid: int
    tokens: np.ndarray                      # (n_new,) generated tokens
    arrival: int
    admitted_step: int
    done_step: int
    queue_wait_steps: int                   # admitted_step - arrival
    ttft_s: float                           # arrival -> first token (wall)
    tpot_s: float                           # mean inter-token wall time
    degraded: bool = False
    logits: Optional[np.ndarray] = None     # (n_new, V) fp32 when collected
    preemptions: int = 0                    # times evicted + resumed
    ttft_steps: int = 0                     # arrival -> first token (virtual)


@dataclasses.dataclass(frozen=True)
class ShedRequest:
    """A request rejected by admission control — counted, never silent.

    ``reason`` is one of ``queue_full`` (bounded admission queue overflow)
    or ``deadline_unmeetable`` (even immediate admission could not finish
    before the deadline).  Shed requests never occupied a slot and emitted
    no tokens.
    """
    rid: int
    arrival: int
    shed_step: int
    reason: str
    prompt_len: int
    n_new: int


class SlotManager:
    """Free-list allocator over ``n`` decode lanes with leak guards.

    Double allocation and double free raise immediately — the invariant
    harness runs with these guards live, so a scheduler bug surfaces as a
    hard error inside the trace rather than as silent cache corruption.
    """

    def __init__(self, n: int):
        if n <= 0:
            raise ValueError(f"max_slots must be positive, got {n}")
        self.n = n
        self._free: List[int] = list(range(n - 1, -1, -1))  # pop() -> slot 0
        self.owner: Dict[int, int] = {}

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> int:
        return self.n - len(self._free)

    def alloc(self, rid: int) -> int:
        if not self._free:
            raise RuntimeError("slot allocation with no free slots")
        slot = self._free.pop()
        if slot in self.owner:
            raise RuntimeError(
                f"slot {slot} double-allocated (owned by request "
                f"{self.owner[slot]}, requested by {rid})")
        self.owner[slot] = rid
        return slot

    def free(self, slot: int) -> None:
        if slot not in self.owner:
            raise RuntimeError(f"slot {slot} double-freed (no owner)")
        del self.owner[slot]
        self._free.append(slot)


def synthetic_workload(n_requests: int, *, seed: int = 0,
                       prompt_lens: Sequence[int] = (4, 8),
                       new_tokens: Sequence[int] = (2, 4),
                       arrival_rate: float = 0.5,
                       vocab: int = 100,
                       prompt_len_weights: Optional[Sequence[float]] = None,
                       deadlines_ms: Optional[Sequence] = None,
                       priorities: Optional[Sequence[int]] = None
                       ) -> List[Request]:
    """Deterministic synthetic request trace.

    Seeded inter-arrival gaps and prompt/completion lengths drawn from the
    given sets — lengths come from a *set* rather than a continuous range
    so a trace touches a bounded number of prefill shapes (one jit trace
    per distinct prompt length).  Same seed, same trace: the test harness
    replays it through both the scheduler and solo generation.

    ``arrival_rate <= 1`` keeps the original geometric-gap process (mean
    gap ``1/rate - 1`` steps) bit-identical across releases.  Overload
    shapes use ``arrival_rate > 1``: per-request Bernoulli gaps of mean
    ``1/rate`` steps, i.e. ~``rate`` arrivals per scheduler step — more
    work per step than ``max_slots`` lanes can serve, the regime the
    admission-control machinery is built for.

    The optional knobs draw extra per-request attributes *after* the base
    draws, so a trace generated without them is bit-identical to older
    releases: ``prompt_len_weights`` skews prompt lengths (heavy-tailed
    mixes), ``deadlines_ms`` assigns each request a deadline drawn from
    the given choices (``None`` entries = best-effort), ``priorities``
    likewise.
    """
    if arrival_rate <= 0.0:
        raise ValueError(f"arrival_rate must be positive, got {arrival_rate}")
    if prompt_len_weights is not None \
            and len(prompt_len_weights) != len(prompt_lens):
        raise ValueError("prompt_len_weights must match prompt_lens")
    rng = np.random.default_rng(seed)
    reqs, t = [], 0
    for rid in range(n_requests):
        if rid and arrival_rate < 1.0:
            t += int(rng.geometric(arrival_rate)) - 1
        elif rid and arrival_rate > 1.0:
            t += int(rng.random() < 1.0 / arrival_rate)
        if prompt_len_weights is None:
            plen = int(rng.choice(prompt_lens))
        else:
            plen = int(rng.choice(prompt_lens,
                                  p=np.asarray(prompt_len_weights, float)
                                  / float(np.sum(prompt_len_weights))))
        tokens = rng.integers(0, vocab, size=plen, dtype=np.int32)
        n_new = int(rng.choice(new_tokens))
        deadline = None
        if deadlines_ms is not None:
            pick = deadlines_ms[int(rng.integers(len(deadlines_ms)))]
            deadline = None if pick is None else float(pick)
        priority = 0
        if priorities is not None:
            priority = int(priorities[int(rng.integers(len(priorities)))])
        reqs.append(Request(rid=rid, tokens=tokens, n_new=n_new, arrival=t,
                            priority=priority, deadline_ms=deadline))
    return reqs


def insert_rows(big_cache, small_cache, slots, n_rows: int):
    """Scatter ``n_rows`` prefilled rows of ``small_cache`` into the
    per-slot lanes ``slots`` of ``big_cache``.

    Cache leaves are stacked over layers — ``(n_layers, B, ...)`` (the
    hybrid family adds an ``(n_groups, B, ...)`` ``shared_attn`` group,
    which the same rule covers).  The ``pos`` leaf is the one asymmetric
    case: scalar-per-layer ``(n_layers,)`` in the fresh prefill cache vs
    per-slot ``(n_layers, B)`` in the big cache — each admitted lane's pos
    is set to its prompt length.  ``small_cache`` may carry padding rows
    beyond ``n_rows`` (prefill pads the batch up to the warm plan bucket);
    they are dropped here.
    """
    slots = jnp.asarray(slots, jnp.int32)

    def one(path, big, small):
        last = path[-1]
        key = getattr(last, "key", getattr(last, "name", None))
        if key == "pos":
            return big.at[:, slots].set(small[:, None])
        return big.at[:, slots].set(small[:, :n_rows])

    return jax.tree_util.tree_map_with_path(one, big_cache, small_cache)


@dataclasses.dataclass
class _Lane:
    """In-flight per-slot decode state."""
    req: Request
    key: jax.Array                  # per-request PRNG chain (parity w/ solo)
    cur: int = 0                    # last sampled token (next decode input)
    emitted: List[int] = dataclasses.field(default_factory=list)
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    admitted_step: int = 0
    admit_wall: float = 0.0
    first_tok_wall: float = 0.0
    first_tok_step: int = -1
    degraded: bool = False
    preemptions: int = 0
    # chunked-prefill state: tokens still being written into the private
    # scalar-pos side cache; the lane holds a slot but does not decode
    # until the side cache is complete and scattered in
    prefilling: bool = False
    prefill_toks: Optional[np.ndarray] = None
    prefill_done: int = 0
    side: Any = None


@dataclasses.dataclass
class _QueueItem:
    """One admission-queue entry: a fresh request, or a preempted lane
    parked for resume-by-recompute (``resume`` carries its emitted tokens,
    PRNG chain and latency accounting)."""
    req: Request
    resume: Optional[_Lane] = None


class Scheduler:
    """The continuous-batching step loop.  Built by
    :meth:`Engine.serve_stream`; usable directly when a test needs to drive
    steps one at a time.

    ``step_hook(state_dict)`` (if given) runs after every scheduler step
    with a snapshot: ``step, occupancy, queue, pending, active, completed,
    admitted`` (rids admitted this step), plus the overload surface —
    ``shed`` (total shed so far), ``preempted`` (rids preempted this
    step), ``prefilling`` (slots still mid-chunked-prefill) — the surface
    the invariant harness asserts on.
    """

    def __init__(self, engine, *, max_slots: Optional[int] = None,
                 collect_logits: bool = False,
                 step_hook: Optional[Callable[[Dict[str, Any]], None]] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 preempt_policy: Optional[str] = None,
                 max_queue: Optional[int] = None,
                 deadline_aware: bool = False,
                 step_time_ms: float = 1.0,
                 max_preemptions: int = 2):
        from repro.models import model as model_mod
        cfg = engine.cfg
        if cfg.family == "encdec":
            raise ValueError(
                "continuous batching is not supported for the encdec "
                "family (cross-attention caches are per-request)")
        if preempt_policy is not None and \
                preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(
                f"preempt_policy must be one of {PREEMPT_POLICIES}, "
                f"got {preempt_policy!r}")
        if prefill_chunk_tokens is not None and prefill_chunk_tokens < 1:
            raise ValueError("prefill_chunk_tokens must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if step_time_ms <= 0.0:
            raise ValueError("step_time_ms must be positive")
        self.engine = engine
        self.max_slots = int(max_slots or engine.scfg.batch)
        self.collect_logits = collect_logits
        self.step_hook = step_hook
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.preempt_policy = preempt_policy
        self.max_queue = max_queue
        self.deadline_aware = deadline_aware
        self.step_time_ms = float(step_time_ms)
        self.max_preemptions = int(max_preemptions)
        self.slots = SlotManager(self.max_slots)
        cdt = jnp.dtype(engine.scfg.cache_dtype)
        self.cache = model_mod.init_cache(cfg, self.max_slots,
                                          engine.scfg.max_len, cdt,
                                          per_slot_pos=True)
        # fresh scalar-pos side cache for one chunk-prefilling lane
        self._side_factory = lambda: model_mod.init_cache(
            cfg, 1, engine.scfg.max_len, cdt)
        self.active: Dict[int, _Lane] = {}
        self.queue: List[_QueueItem] = []
        self.pending: List[Request] = []
        self.completed: Dict[int, CompletedRequest] = {}
        self.shed: Dict[int, ShedRequest] = {}
        self.preempt_count = 0
        self.step = 0
        self._total = 0

    # ------------------------------------------------------------ helpers --
    def _sample_row(self, logits_row, key) -> int:
        """Sample one token for one lane — same math as
        ``Engine._sample`` on a (1, V) batch, so a streamed request's
        tokens match its solo run exactly (per-request key chain)."""
        eng = self.engine
        if eng.scfg.temperature <= 0.0:
            return int(np.argmax(np.asarray(logits_row)))
        out = jax.random.categorical(
            key, jnp.asarray(logits_row)[None] / eng.scfg.temperature)
        return int(out[0])

    def _prefill_tokens(self, it: _QueueItem) -> np.ndarray:
        """The token sequence admission must prefill for this entry: the
        prompt, plus — for a preempted resume — every already-emitted
        token except the last (resume-by-recompute: the cache then holds
        exactly what the uninterrupted run's cache held before its next
        decode step, at the same pos; the last emitted token becomes the
        next decode input)."""
        base = np.asarray(it.req.tokens, np.int32).reshape(-1)
        if it.resume is not None and it.resume.emitted:
            return np.concatenate(
                [base, np.asarray(it.resume.emitted[:-1], np.int32)])
        return base

    def _lane_for(self, it: _QueueItem) -> _Lane:
        if it.resume is not None:
            return it.resume
        return _Lane(req=it.req,
                     key=jax.random.PRNGKey(self.engine.scfg.seed))

    def _qkey(self, it: _QueueItem):
        r = it.req
        if self.deadline_aware:
            ds = self._deadline_step(r)
            return (-r.priority, float("inf") if ds is None else ds,
                    r.arrival, r.rid)
        return (-r.priority, r.arrival, r.rid)

    def _enqueue(self, it: _QueueItem) -> None:
        keys = [self._qkey(x) for x in self.queue]
        self.queue.insert(bisect.bisect_right(keys, self._qkey(it)), it)

    def _deadline_step(self, r: Request) -> Optional[int]:
        """Absolute virtual-step deadline, or None for best-effort."""
        if r.deadline_ms is None:
            return None
        return r.arrival + int(np.ceil(r.deadline_ms / self.step_time_ms))

    def _chunks_for(self, n_tokens: int) -> int:
        c = self.prefill_chunk_tokens
        if c is None or n_tokens <= c:
            return 1
        return -(-n_tokens // c)

    def _min_done_step(self, it: _QueueItem) -> int:
        """Earliest possible completion step if admitted *this* step:
        ``chunks`` prefill steps (the last also samples the first token)
        then one decode step per remaining token."""
        chunks = self._chunks_for(len(self._prefill_tokens(it)))
        done = len(it.resume.emitted) if it.resume is not None else 0
        rem = max(it.req.n_new - done, 1)
        return self.step + chunks + rem - 2

    def _remaining_work(self, lane: _Lane) -> int:
        """Tokens of work left in a lane — decode tokens still to emit
        plus prefill tokens still to write (preemption-victim metric)."""
        rem = lane.req.n_new - len(lane.emitted)
        if lane.prefilling:
            rem += len(lane.prefill_toks) - lane.prefill_done
        return rem

    def _shed_request(self, it: _QueueItem, reason: str) -> None:
        r = it.req
        self.shed[r.rid] = ShedRequest(
            rid=r.rid, arrival=r.arrival, shed_step=self.step,
            reason=reason, prompt_len=r.prompt_len, n_new=r.n_new)
        obs.count("sched.shed", reason=reason)
        # counters don't carry attrs (they only reach the tracer), so the
        # reason-named counter is its own metric — the overload report and
        # chaos tests read shed causes from the snapshot by name
        obs.count(f"sched.shed.{reason}")

    def _finish(self, slot: int, lane: _Lane) -> None:
        """Complete the lane's request and reclaim its slot.  A fault at
        the ``sched.slot_free`` site marks the request degraded but the
        slot is reclaimed regardless — a lane is never leaked."""
        try:
            faults.check("sched.slot_free", slot=slot, rid=lane.req.rid)
        except Exception as e:  # noqa: BLE001 — serving must not die
            obs.count("sched.slot_free_fault", reason=type(e).__name__)
            lane.degraded = True
        self.slots.free(slot)
        del self.active[slot]
        now = time.perf_counter()
        r = lane.req
        n = len(lane.emitted)
        tpot = ((now - lane.first_tok_wall) / (n - 1)) if n > 1 else 0.0
        if lane.degraded:
            self.engine.degraded_requests += 1
            obs.count("serve.degraded_request")
        ttft_steps = lane.first_tok_step - r.arrival
        obs.observe("serve.request_ttft_s",
                    lane.first_tok_wall - lane.admit_wall)
        obs.observe("serve.request_tpot_s", tpot)
        obs.observe("sched.ttft_steps", float(ttft_steps))
        obs.count("serve.stream_tokens", n)
        self.completed[r.rid] = CompletedRequest(
            rid=r.rid, tokens=np.asarray(lane.emitted, np.int32),
            arrival=r.arrival, admitted_step=lane.admitted_step,
            done_step=self.step,
            queue_wait_steps=lane.admitted_step - r.arrival,
            ttft_s=lane.first_tok_wall - lane.admit_wall, tpot_s=tpot,
            degraded=lane.degraded,
            logits=(np.stack(lane.logits).astype(np.float32)
                    if self.collect_logits else None),
            preemptions=lane.preemptions, ttft_steps=ttft_steps)

    def _first_token(self, slot: int, lane: _Lane, last_row: np.ndarray,
                     now: float) -> None:
        """Prefill finished for this lane: sample the first token (fresh
        admission) or restore the parked decode input (resume — the
        prefill logits predict a token that was already emitted before
        preemption, so they are discarded)."""
        if lane.emitted:
            lane.cur = lane.emitted[-1]
            return
        tok0 = self._sample_row(last_row, lane.key)
        lane.emitted.append(tok0)
        lane.cur = tok0
        lane.first_tok_wall = time.perf_counter()
        lane.first_tok_step = self.step
        if self.collect_logits:
            lane.logits.append(last_row)
        obs.observe("sched.queue_wait_steps",
                    lane.admitted_step - lane.req.arrival)
        if lane.req.n_new <= 1:
            self._finish(slot, lane)

    # ---------------------------------------------------------- admission --
    def _admit(self, admitted: List[_QueueItem]) -> None:
        """Prefill + insert for this step's admissions: grouped whole-
        prompt prefill for entries within the chunk budget, slot + side-
        cache setup for the rest (their chunks advance in
        :meth:`_advance_chunks`, starting this same step)."""
        eng = self.engine
        budget = self.prefill_chunk_tokens
        direct: List[_QueueItem] = []
        for it in admitted:
            n_tok = len(self._prefill_tokens(it))
            if budget is not None and n_tok > budget:
                slot = self.slots.alloc(it.req.rid)
                lane = self._lane_for(it)
                if it.resume is None:
                    lane.admitted_step = self.step
                    lane.admit_wall = time.perf_counter()
                lane.prefilling = True
                lane.prefill_toks = self._prefill_tokens(it)
                lane.prefill_done = 0
                lane.side = self._side_factory()
                self.active[slot] = lane
            else:
                direct.append(it)
        groups: Dict[int, List[_QueueItem]] = {}
        for it in direct:
            groups.setdefault(len(self._prefill_tokens(it)), []).append(it)
        for plen, grp in groups.items():
            toks = np.stack([self._prefill_tokens(it) for it in grp])
            g = len(grp)
            # pad the prefill batch up to the engine's warmed batch size so
            # the grouped prefill hits the warm plan bucket (rows are
            # independent through attention/SSM/dropless-MoE; the padding
            # rows are dropped before insert)
            pad_to = eng.scfg.batch if g <= eng.scfg.batch else g
            if pad_to > g:
                toks = np.concatenate(
                    [toks, np.repeat(toks[-1:], pad_to - g, axis=0)])
            eng._req_degraded = False
            small, last = eng.prefill(jnp.asarray(toks))
            degraded = eng._req_degraded
            now = time.perf_counter()
            slot_ids = [self.slots.alloc(it.req.rid) for it in grp]
            self.cache = insert_rows(self.cache, small, slot_ids, g)
            last_h = np.asarray(last[:g], np.float32)
            for i, (it, slot) in enumerate(zip(grp, slot_ids)):
                lane = self._lane_for(it)
                if it.resume is None:
                    lane.admitted_step = self.step
                    lane.admit_wall = now
                lane.degraded = lane.degraded or degraded
                self.active[slot] = lane
                self._first_token(slot, lane, last_h[i], now)

    def _advance_chunks(self) -> None:
        """Advance chunk-prefilling lanes, oldest admission first, within
        the per-step ``prefill_chunk_tokens`` token budget.  A lane's
        chunk is always ``min(budget, remaining)`` — the trace shapes stay
        bounded (one full-chunk shape plus one remainder shape per prompt
        length) — and a younger lane never overtakes an older one."""
        budget = self.prefill_chunk_tokens
        lanes = sorted(
            ((s, ln) for s, ln in self.active.items() if ln.prefilling),
            key=lambda sl: (sl[1].admitted_step, sl[0]))
        eng = self.engine
        left = budget
        for slot, lane in lanes:
            total = len(lane.prefill_toks)
            take = min(budget, total - lane.prefill_done)
            if take > left:
                break
            left -= take
            seg = lane.prefill_toks[lane.prefill_done:
                                    lane.prefill_done + take]
            eng._req_degraded = False
            lane.side, last = eng.prefill_chunk(
                lane.side, jnp.asarray(seg[None]))
            lane.degraded = lane.degraded or eng._req_degraded
            lane.prefill_done += take
            obs.count("sched.prefill_chunk")
            if lane.prefill_done == total:
                self.cache = insert_rows(self.cache, lane.side, [slot], 1)
                lane.side = None
                lane.prefilling = False
                lane.prefill_toks = None
                self._first_token(slot, lane,
                                  np.asarray(last[0], np.float32),
                                  time.perf_counter())

    # --------------------------------------------------------- preemption --
    def _maybe_preempt(self) -> List[int]:
        """At most one preemption per step: if no slot is free and the
        queue head *strictly* beats an active lane (higher priority, or —
        deadline-aware — a strictly earlier absolute deadline at equal
        priority), evict the policy-chosen victim.  Strict dominance means
        a victim can never bounce its preemptor back, and the per-request
        cap bounds total preemptions, so the policy cannot livelock."""
        if (self.preempt_policy is None or not self.queue
                or self.slots.free_count > 0):
            return []
        c = self.queue[0].req
        cd = self._deadline_step(c)
        victims: List[tuple] = []
        for slot, lane in self.active.items():
            v = lane.req
            if lane.preemptions >= self.max_preemptions:
                continue
            vd = self._deadline_step(v)
            beats = v.priority < c.priority or (
                self.deadline_aware and v.priority == c.priority
                and cd is not None and (vd is None or cd < vd))
            if beats:
                victims.append((slot, lane))
        if not victims:
            return []
        if self.preempt_policy == "lowest_priority":
            slot, lane = min(
                victims,
                key=lambda sl: (sl[1].req.priority,
                                -self._remaining_work(sl[1]), sl[0]))
        else:  # longest_remaining
            slot, lane = max(
                victims,
                key=lambda sl: (self._remaining_work(sl[1]), -sl[0]))
        self._preempt(slot, lane)
        return [lane.req.rid]

    def _preempt(self, slot: int, lane: _Lane) -> None:
        """Evict a lane: zero its cache rows, park its generated-so-far
        state, requeue for resume.  Both fault sites mark the request
        degraded on injection but the bookkeeping always completes — the
        slot is freed exactly once and the request stays in the system."""
        try:
            faults.check("sched.preempt", slot=slot, rid=lane.req.rid)
        except Exception as e:  # noqa: BLE001 — serving must not die
            obs.count("sched.preempt_fault", reason=type(e).__name__)
            lane.degraded = True
        self._evict_rows(slot, lane)
        self.slots.free(slot)
        del self.active[slot]
        lane.prefilling = False
        lane.prefill_toks = None
        lane.prefill_done = 0
        lane.side = None
        lane.preemptions += 1
        self.preempt_count += 1
        obs.count("sched.preempt", policy=self.preempt_policy)
        self._enqueue(_QueueItem(req=lane.req, resume=lane))

    def _evict_rows(self, slot: int, lane: _Lane) -> None:
        """Zero the lane's rows (pos included) across every cache leaf.
        Correctness only needs the pos reset — a garbage row is never
        read and re-admission overwrites it whole — but zeroing is cheap
        hygiene that keeps post-mortem cache dumps honest."""
        try:
            faults.check("sched.evict_rows", slot=slot, rid=lane.req.rid)
        except Exception as e:  # noqa: BLE001 — serving must not die
            obs.count("sched.evict_rows_fault", reason=type(e).__name__)
            lane.degraded = True
        self.cache = jax.tree.map(
            lambda x: x.at[:, slot].set(jnp.zeros_like(x[:, slot])),
            self.cache)

    # --------------------------------------------------------------- loop --
    def _decode(self) -> None:
        """One batched decode step over every decodable lane (chunk-
        prefilling lanes hold their slot but skip decode; their garbage
        rows advance harmlessly and are overwritten by insert)."""
        eng = self.engine
        decodable = {s: ln for s, ln in self.active.items()
                     if not ln.prefilling}
        if not decodable:
            return
        toks = np.zeros((self.max_slots, 1), np.int32)
        for slot, lane in decodable.items():
            toks[slot, 0] = lane.cur
        eng._req_degraded = False
        logits, self.cache = eng._decode_token(
            self.cache, {"tokens": jnp.asarray(toks)})
        degraded = eng._req_degraded
        rows = np.asarray(logits[:, -1], np.float32)
        for slot, lane in list(decodable.items()):
            if degraded:
                lane.degraded = True
            lane.key, sub = jax.random.split(lane.key)
            tok = self._sample_row(rows[slot], sub)
            lane.emitted.append(tok)
            if self.collect_logits:
                lane.logits.append(rows[slot])
            if len(lane.emitted) >= lane.req.n_new:
                self._finish(slot, lane)
            else:
                lane.cur = tok

    def submit(self, requests: Sequence[Request]) -> None:
        max_len = self.engine.scfg.max_len
        for r in requests:
            if r.prompt_len + r.n_new > max_len:
                raise ValueError(
                    f"request {r.rid}: prompt_len {r.prompt_len} + n_new "
                    f"{r.n_new} exceeds max_len {max_len}")
            if r.n_new < 1:
                raise ValueError(f"request {r.rid}: n_new must be >= 1")
        self.pending.extend(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self._total += len(requests)

    def run_step(self) -> None:
        """One scheduler step: arrivals -> shed sweep -> preemption ->
        admission -> prefill chunks -> batched decode."""
        while self.pending and self.pending[0].arrival <= self.step:
            r = self.pending.pop(0)
            if self.max_queue is not None \
                    and len(self.queue) >= self.max_queue:
                self._shed_request(_QueueItem(req=r), "queue_full")
            else:
                self._enqueue(_QueueItem(req=r))
        if self.deadline_aware:
            # shed sweep: a queued request whose deadline cannot be met
            # even by admitting it right now will never be met — count it
            # out instead of burning slot time on it.  Preempted requests
            # were admitted and are exempt: they always complete.
            keep: List[_QueueItem] = []
            for it in self.queue:
                ds = self._deadline_step(it.req)
                if it.resume is None and ds is not None \
                        and self._min_done_step(it) > ds:
                    self._shed_request(it, "deadline_unmeetable")
                else:
                    keep.append(it)
            self.queue = keep
        preempted = self._maybe_preempt()
        admitted: List[_QueueItem] = []
        while self.queue and len(admitted) < self.slots.free_count:
            # always the queue head: a request never overtakes a
            # better-ranked one into a slot (pure FIFO at equal rank)
            admitted.append(self.queue.pop(0))
        if admitted:
            self._admit(admitted)
        if self.prefill_chunk_tokens is not None:
            self._advance_chunks()
        self._decode()
        obs.gauge("sched.slot_occupancy", self.slots.occupancy)
        obs.gauge("sched.queue_depth", len(self.queue))
        # conservation: every submitted request is exactly one of
        # not-yet-arrived / queued / in-flight / completed / shed
        accounted = (len(self.pending) + len(self.queue) + len(self.active)
                     + len(self.completed) + len(self.shed))
        if accounted != self._total:
            raise RuntimeError(
                f"request conservation violated at step {self.step}: "
                f"{accounted} accounted vs {self._total} submitted")
        if self.step_hook is not None:
            self.step_hook({
                "step": self.step,
                "occupancy": self.slots.occupancy,
                "free": self.slots.free_count,
                "queue": [it.req.rid for it in self.queue],
                "pending": len(self.pending),
                "active": {s: ln.req.rid for s, ln in self.active.items()},
                "admitted": [it.req.rid for it in admitted],
                "completed": len(self.completed),
                "shed": len(self.shed),
                "preempted": preempted,
                "prefilling": sorted(s for s, ln in self.active.items()
                                     if ln.prefilling),
            })
        self.step += 1

    def run(self, requests: Sequence[Request]) -> List[CompletedRequest]:
        self.submit(requests)
        if not self.pending:
            return []
        # stall guard: every step makes progress (a token decodes, a chunk
        # advances, or an admission/shed happens), so total steps are
        # bounded by the arrivals span + per-request work — each request
        # costs up to n_new decode steps plus its prefill chunks, and a
        # preempted request repays its (longer) prefill up to
        # max_preemptions more times
        reqs = self.pending
        work = sum(
            r.n_new
            + self._chunks_for(r.prompt_len + r.n_new)
            * (1 + (self.max_preemptions
                    if self.preempt_policy is not None else 0))
            for r in reqs)
        bound = (max(r.arrival for r in reqs) + work
                 + len(reqs) + self.max_slots + 8)
        with obs.span("serve.stream", cat="serve", requests=self._total,
                      max_slots=self.max_slots) as sp:
            while self.pending or self.queue or self.active:
                if self.step > bound:
                    raise RuntimeError(
                        f"scheduler stalled: step {self.step} exceeded "
                        f"bound {bound} with {len(self.completed)}/"
                        f"{self._total} completed")
                self.run_step()
            sp.set(steps=self.step, completed=len(self.completed),
                   shed=len(self.shed), preemptions=self.preempt_count)
        return [self.completed[rid] for rid in sorted(self.completed)]
