"""Capacity model + pump-factor autotuning (beyond-paper extension).

The paper picks M manually (M=2, bounded by the Vivado 650 MHz cap).  On TPU
the analogous cap is structural: the widened transaction must fit the VMEM
working-set budget, and the effective-rate law says pumping beyond the
compute/DMA balance point only adds stalls.  This module does the napkin math
once, so kernels and the trainer can ask for the best factor instead of a
hand-picked constant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from .ir import PumpSpec, effective_rate

# TPU v5e-class hardware constants (also used by the roofline harness).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
VMEM_BYTES = 64 * 1024 * 1024     # budget we allow a kernel working set
MXU_DIM = 128                     # systolic array edge; align tiles to this
LANE = 128                        # VPU lane count (last-dim tiling)
SUBLANE = 8                       # float32 sublane count


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    """Napkin-math descriptors of one kernel grid step."""

    block_bytes_in: int            # bytes DMA'd HBM->VMEM per grid step
    block_bytes_out: int           # bytes DMA'd VMEM->HBM per grid step
    flops_per_block: float         # useful FLOPs per grid step
    fixed_overhead_s: float = 1e-6 # per-grid-step launch/descriptor overhead

    @property
    def dma_time(self) -> float:
        return (self.block_bytes_in + self.block_bytes_out) / HBM_BW

    @property
    def compute_time(self) -> float:
        return self.flops_per_block / PEAK_FLOPS_BF16

    def step_time(self, pump: int = 1) -> float:
        """Pipeline step time for a pump-M wide transaction (Mode T).

        One wide DMA of M blocks overlaps M compute iterations (Pallas double
        buffering = the paper's synchronizer); the fixed per-transaction
        overhead is paid once per wide transaction instead of once per block —
        this is the long-path win of temporal vectorization.
        """
        dma = pump * self.dma_time + self.fixed_overhead_s
        compute = pump * self.compute_time
        return max(dma, compute)

    def throughput(self, pump: int = 1) -> float:
        """Blocks/sec under the effective-rate law."""
        return pump / self.step_time(pump)


def best_pump_factor(est: KernelEstimate, max_factor: int = 16,
                     vmem_budget: int = VMEM_BYTES) -> int:
    """Search M maximizing modeled throughput subject to VMEM capacity.

    Capacity: double-buffered wide input + output blocks must fit the budget:
        2 * M * (in + out) <= vmem_budget
    """
    best, best_tp = 1, est.throughput(1)
    m = 2
    while m <= max_factor:
        need = 2 * m * (est.block_bytes_in + est.block_bytes_out)
        if need > vmem_budget:
            break
        tp = est.throughput(m)
        if tp > best_tp * 1.001:
            best, best_tp = m, tp
        m *= 2
    return best


def plan_kernel_pump(block_bytes_in: int, block_bytes_out: int,
                     flops_per_block: float,
                     mode: str = "T",
                     max_factor: int = 16,
                     vmem_budget: int = VMEM_BYTES,
                     axis: int = 0) -> PumpSpec:
    est = KernelEstimate(block_bytes_in, block_bytes_out, flops_per_block)
    m = best_pump_factor(est, max_factor=max_factor, vmem_budget=vmem_budget)
    return PumpSpec(factor=m, mode=mode, axis=axis, vmem_budget=vmem_budget)


def plan_trainer_pump(grad_bytes: int, step_flops: float, n_chips: int,
                      dp_degree: int, max_factor: int = 64) -> int:
    """Pod-scale pump factor: microbatches per gradient synchronization.

    The gradient all-reduce over the data axis is the long path (ring
    all-reduce moves 2*(d-1)/d * grad_bytes per chip over ICI).  Compute per
    microbatch is the fast domain.  M amortizes the collective: the per-step
    collective cost is paid once per M microbatches.
    """
    d = max(dp_degree, 2)
    coll_time = 2 * (d - 1) / d * grad_bytes / ICI_BW
    mb_compute = step_flops / n_chips / PEAK_FLOPS_BF16
    if mb_compute <= 0:
        return 1
    # choose smallest M such that collective amortized below 10% of compute
    m = 1
    while m < max_factor and coll_time / m > 0.1 * mb_compute * m:
        m *= 2
    return m


def align_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def mxu_aligned_tile(m: int, n: int, max_m: int = 512, max_n: int = 512
                     ) -> tuple[int, int]:
    """Clamp a compute tile to MXU-friendly multiples of 128."""
    tm = min(align_up(min(m, max_m), MXU_DIM), align_up(m, SUBLANE))
    tn = min(align_up(min(n, max_n), MXU_DIM), align_up(n, LANE))
    return max(tm, SUBLANE), max(tn, LANE)
