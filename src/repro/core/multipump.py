"""The multi-pumping / temporal-vectorization transformation (paper §2, §3.2).

Given a streamed dataflow graph, split it into two *rate domains* and rewrite
the boundary:

  Mode "T" (throughput, paper waveform ②):
      external stream width ×= M, compute width unchanged, compute rate = FAST
      with pump M.  Throughput ×M at equal compute resources.  Legal even for
      computations with internal sequential dependencies — the superclass-of-
      vectorization property.

  Mode "R" (resource, paper waveform ③):
      external width unchanged, compute spatial width ÷= M, compute rate =
      FAST with pump M.  Equal throughput at 1/M compute resources.

At the domain boundary the pass injects the paper's three adapter modules:
``Sync`` (clock-domain crossing — realized on TPU by the Pallas double-
buffered pipeline boundary), ``Issuer`` (wide→narrow) on inputs and
``Packer`` (narrow→wide) on outputs.

Legality (§3.2): the compute modules must not perform data-dependent external
memory I/O; boundary edges must already be streams; in mode R the spatial
width must divide by M; the widened working set must fit the VMEM budget.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

from .ir import (Edge, Graph, Node, NodeKind, PumpSpec, RateDomain, Space,
                 effective_rate)


@dataclasses.dataclass
class PumpReport:
    applied: bool
    mode: str
    factor: int
    reason: str = ""
    boundary_in: int = 0
    boundary_out: int = 0
    resources_before: dict = dataclasses.field(default_factory=dict)
    resources_after: dict = dataclasses.field(default_factory=dict)

    def resource_ratio(self, key: str = "compute_units") -> float:
        b = self.resources_before.get(key, 0)
        a = self.resources_after.get(key, 0)
        return a / b if b else float("nan")


def check_multipump(g: Graph, targets: Sequence[str], factor: int,
                    mode: str = "T",
                    vmem_budget: int = 64 * 1024 * 1024) -> Tuple[bool, str]:
    """Feasibility check — the relaxed auto-vectorizer conditions of §3.2."""
    if factor < 2:
        return False, "pump factor must be >= 2"
    if mode not in ("T", "R"):
        return False, f"unknown mode {mode}"
    for name in targets:
        n = g.nodes.get(name)
        if n is None:
            return False, f"unknown node {name}"
        if n.kind != NodeKind.COMPUTE:
            return False, f"{name} is not a compute module"
        if n.data_dependent_io:
            # The single restriction temporal vectorization keeps: no
            # data-dependent external memory I/O based on previous operations.
            return False, f"{name} performs data-dependent external I/O"
        if n.rate == RateDomain.FAST:
            return False, f"{name} already multi-pumped"
        if mode == "R" and n.vector_width % factor != 0:
            return False, (f"{name} spatial width {n.vector_width} not divisible "
                           f"by pump factor {factor}")
        for e in g.in_edges(name) + g.out_edges(name):
            other = g.nodes[e.src if e.dst == name else e.dst]
            if other.kind == NodeKind.MEMORY and other.space == Space.HBM:
                return False, (f"{name} accesses HBM memory {other.name} directly; "
                               "run the streaming pass first")
    # VMEM capacity: the widened transactions must be buffered (×2 for the
    # double-buffered pipeline = the Sync module).
    widened = 0
    for name in targets:
        n = g.nodes[name]
        for e in g.in_edges(name) + g.out_edges(name):
            s = g.nodes[e.src if e.dst == name else e.dst]
            if s.kind == NodeKind.STREAM:
                widened += 2 * s.elem_width * factor * s.bytes_per_elem()
    if widened > vmem_budget:
        return False, (f"widened working set {widened} B exceeds VMEM budget "
                       f"{vmem_budget} B")
    return True, "ok"


def apply_multipump(g: Graph, targets: Optional[Sequence[str]] = None,
                    factor: int = 2, mode: str = "T",
                    vmem_budget: int = 64 * 1024 * 1024
                    ) -> Tuple[Graph, PumpReport]:
    """Rewrite ``g`` with the temporal-vectorization transformation.

    ``targets`` defaults to every compute module reachable purely through
    streams — the paper's greedy largest-subgraph policy (§3.4).
    Returns (new_graph, report); on infeasibility the graph is returned
    unchanged with ``report.applied == False``.
    """
    from .streaming import streamable_subgraph

    if targets is None:
        targets = [n for n in streamable_subgraph(g)
                   if g.nodes[n].kind == NodeKind.COMPUTE]
    ok, why = check_multipump(g, targets, factor, mode, vmem_budget)
    before = g.resources()
    if not ok:
        return g, PumpReport(False, mode, factor, why,
                             resources_before=before, resources_after=before)

    out = g.copy()
    n_in = n_out = 0
    # a stream may border the pumped region twice (producer and consumer both
    # in ``targets``, e.g. after stream fusion): widen its transactions once
    widened: set = set()
    for name in targets:
        comp = out.nodes[name]
        comp.rate = RateDomain.FAST
        comp.pump = factor
        comp.meta["pump_mode"] = mode
        if mode == "R":
            comp.vector_width //= factor
        # rewrite each boundary stream with sync+issuer / packer+sync chains
        for e in list(out.in_edges(name)):
            s = out.nodes[e.src]
            if s.kind != NodeKind.STREAM:
                continue
            # producer side keeps/sets the wide width
            if mode == "T" and s.name not in widened:
                s.elem_width *= factor
                widened.add(s.name)
            n_in += 1
            sync = out.add(Node(f"sync_in_{s.name}", NodeKind.SYNC,
                                rate=RateDomain.FAST))
            iss = out.add(Node(f"issue_{s.name}", NodeKind.ISSUER,
                               rate=RateDomain.FAST, meta=dict(factor=factor)))
            # suffix by consumer: a stream linking two pumped computes gets
            # an issuer here and a packer on its producer side
            narrow = out.stream(f"{s.name}_narrow_{name}", dtype=s.dtype,
                                elem_width=max(1, s.elem_width // factor))
            narrow.meta = dict(rate="fast")
            # re-route: s -> sync -> issuer -> narrow -> comp
            out.edges.remove(e)
            out.connect(s.name, sync.name)
            out.connect(sync.name, iss.name)
            out.connect(iss.name, narrow.name)
            out.connect(narrow.name, comp.name)
        for e in list(out.out_edges(name)):
            s = out.nodes[e.dst]
            if s.kind != NodeKind.STREAM:
                continue
            if mode == "T" and s.name not in widened:
                s.elem_width *= factor
                widened.add(s.name)
            n_out += 1
            pack = out.add(Node(f"pack_{s.name}", NodeKind.PACKER,
                                rate=RateDomain.FAST, meta=dict(factor=factor)))
            sync = out.add(Node(f"sync_out_{s.name}", NodeKind.SYNC,
                                rate=RateDomain.FAST))
            narrow = out.stream(f"{s.name}_narrow_{name}", dtype=s.dtype,
                                elem_width=max(1, s.elem_width // factor))
            narrow.meta = dict(rate="fast")
            out.edges.remove(e)
            out.connect(comp.name, narrow.name)
            out.connect(narrow.name, pack.name)
            out.connect(pack.name, sync.name)
            out.connect(sync.name, s.name)

    out.validate()
    report = PumpReport(True, mode, factor, "ok", n_in, n_out,
                        resources_before=before,
                        resources_after=out.resources())
    return out, report


def throughput_model(g: Graph, clk0: float = 1.0, clk1: float = 2.0
                     ) -> float:
    """Elements/sec estimate under the effective-rate law (paper §2.1).

    Each compute module contributes width × rate; the slowest stage bounds the
    pipeline.  ``clk0``/``clk1`` are the slow/fast domain issue rates (on TPU:
    wide-DMA transactions/s and compute iterations/s).
    """
    rates = []
    for n in g.computes():
        rate = effective_rate(clk0, clk1, n.pump) if n.rate == RateDomain.FAST \
            else clk0
        width = n.vector_width * (n.pump if n.rate == RateDomain.FAST else 1)
        rates.append(width * rate)
    return min(rates) if rates else 0.0


def pump_spec_for(g: Graph, target: str,
                  vmem_budget: int = 64 * 1024 * 1024) -> PumpSpec:
    """Extract the kernel-layer PumpSpec for a transformed compute module."""
    n = g.nodes[target]
    mode = "T"
    if n.meta.get("pump_mode"):
        mode = n.meta["pump_mode"]
    return PumpSpec(factor=n.pump, mode=mode, vmem_budget=vmem_budget)
