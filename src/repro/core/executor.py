"""Reference executor for the dataflow IR.

Interprets a Graph on numpy arrays so the transformation passes can be
*semantically validated*: streaming extraction and multi-pumping must be
value-preserving (issuer∘packer = identity; FIFO order = memory order).  The
executor is deliberately simple — streams are materialized as full sequences
in FIFO order — because it exists to check transformations, not to be fast.

Three compute flavours are interpreted:

* plain ``fn`` bodies mapping whole FIFO sequences to whole sequences
  (multi-output: ``{"out0": ..., "out1": ...}`` bound in edge order);
* sequential-carry computes (``meta['carry']`` is a
  :class:`~repro.core.ir.CarrySpec`): the step domain is walked in
  lexicographic order, per-step operand *blocks* are cut from the FIFO
  sequences, and the loop-carried state threads through ``step_fn`` —
  resetting at the start of each sweep of the carry axis — with outputs
  emitted per step or per sweep (``final_fn``);
* both may sit behind streams/adapters: the executor resolves each operand's
  block shape by tracing the edge back to its memory access pattern.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .ir import Graph, Node, NodeKind, Space
from .symbolic import AccessPattern, blocked_access


def _gather(mem: np.ndarray, acc: AccessPattern) -> np.ndarray:
    flat = mem.reshape(-1)
    idx = np.fromiter(acc.addresses(mem.shape), dtype=np.int64)
    return flat[idx]


def _scatter(mem: np.ndarray, acc: AccessPattern, seq: np.ndarray) -> None:
    flat = mem.reshape(-1)
    idx = np.fromiter(acc.addresses(mem.shape), dtype=np.int64)
    flat[idx] = seq
    # mem viewed via reshape(-1) may be a copy for non-contiguous arrays;
    # callers pass contiguous buffers.


def origin_access(g: Graph, edge) -> Tuple[Optional[Node], Optional[AccessPattern]]:
    """Trace an in-edge backwards through pass-through modules (reader /
    stream / sync / issuer) to the memory it originates from, returning
    ``(memory node, access pattern)`` — or ``(None, None)`` when the value
    is produced by an upstream compute instead.

    The pallas emission backend has sibling walkers
    (``pallas_backend._trace_to_source/_trace_to_sink``) with stricter
    error semantics (they raise on malformed pass-through chains, since a
    region plan must not silently skip an operand); keep the traversal
    rules in sync when adding pass-through node kinds."""
    e = edge
    while True:
        src = g.nodes[e.src]
        if src.kind == NodeKind.MEMORY:
            return src, e.access
        if src.kind == NodeKind.COMPUTE:
            return None, None
        ins = g.in_edges(src.name)
        if len(ins) != 1:
            return None, None
        e = ins[0]


def sink_access(g: Graph, edge) -> Tuple[Optional[Node], Optional[AccessPattern]]:
    """Forward counterpart of :func:`origin_access` for an out-edge."""
    e = edge
    while True:
        dst = g.nodes[e.dst]
        if dst.kind == NodeKind.MEMORY:
            return dst, e.access
        if dst.kind == NodeKind.COMPUTE:
            return None, None
        outs = g.out_edges(e.dst)
        if len(outs) != 1:
            return None, None
        e = outs[0]


def carry_layout(g: Graph, node: Node):
    """Shared layout facts for interpreting a carry compute: step count,
    sweep length, per-operand block shapes and the outer symbols.

    Returns ``(n_steps, sweep, in_blocks, out_blocks, outer_syms)`` where
    block entries are shape tuples (or None when the operand access does not
    decompose into a blocked view — the per-step slice then stays flat).
    """
    spec = node.meta["carry"]
    dom = node.domain
    if dom is None or not dom.symbols or dom.symbols[-1] != spec.axis:
        raise ValueError(
            f"carry compute {node.name!r}: carry axis {spec.axis!r} must be "
            f"the last step-domain symbol (got {dom.symbols if dom else ()})")
    exts = dom.extents
    n_steps = 1
    for e in exts:
        n_steps *= e
    sweep = exts[-1]

    def block_of(edge, backwards: bool):
        mem, acc = (origin_access if backwards else sink_access)(g, edge)
        if mem is None or acc is None:
            return None
        # the compute's step symbols must stay grid symbols: an access that
        # walks them densely is still visited one block per step
        ba = blocked_access(acc, mem.shape, protect=dom.symbols)
        return ba.block if ba is not None else None

    in_blocks = [block_of(e, True) for e in g.in_edges(node.name)]
    out_blocks = [block_of(e, False) for e in g.out_edges(node.name)]
    return n_steps, sweep, in_blocks, out_blocks, dom.symbols[:-1]


def _run_carry(g: Graph, node: Node, bound: Dict[str, np.ndarray]
               ) -> Dict[str, np.ndarray]:
    """Interpret one sequential-carry compute on numpy sequences."""
    spec = node.meta["carry"]
    n_steps, sweep, in_blocks, _out_blocks, outer_syms = carry_layout(g, node)
    n_in = len(in_blocks)
    per_step = [bound[f"in{k}"].size // n_steps for k in range(n_in)]
    n_out = len(g.out_edges(node.name))
    n_step_out = spec.n_step_outs(n_out)
    chunks: List[List[np.ndarray]] = [[] for _ in range(n_out)]

    carry = spec.init_arrays(np)
    step = 0
    for env in node.domain.points():
        pos = step % sweep
        if pos == 0:
            carry = spec.init_arrays(np)
        blocks = []
        for k in range(n_in):
            sl = bound[f"in{k}"][step * per_step[k]:(step + 1) * per_step[k]]
            blocks.append(sl.reshape(in_blocks[k])
                          if in_blocks[k] is not None else sl)
        kwargs = {}
        if spec.pass_idx:
            kwargs["idx"] = dict(
                step=pos, outer=tuple(env[s] for s in outer_syms), pump=0)
        carry, step_out = spec.step_fn(carry, *blocks, **kwargs)
        for k in range(n_step_out):
            chunks[k].append(np.asarray(step_out[f"out{k}"]).reshape(-1))
        if spec.final_fn is not None and pos == sweep - 1:
            fouts = spec.final_fn(carry)
            for k in range(n_step_out, n_out):
                chunks[k].append(np.asarray(fouts[f"out{k}"]).reshape(-1))
        step += 1
    return {f"out{k}": np.concatenate(chunks[k]) if chunks[k]
            else np.zeros(0, np.float32) for k in range(n_out)}


def _toposort(g: Graph) -> List[str]:
    indeg: Dict[str, int] = {n: 0 for n in g.nodes}
    for e in g.edges:
        indeg[e.dst] += 1
    ready = [n for n, d in indeg.items() if d == 0]
    order: List[str] = []
    while ready:
        n = ready.pop()
        order.append(n)
        for e in g.out_edges(n):
            indeg[e.dst] -= 1
            if indeg[e.dst] == 0:
                ready.append(e.dst)
    if len(order) != len(g.nodes):
        raise ValueError("graph has a cycle")
    return order


def run(g: Graph, inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Execute ``g``; returns the contents of every HBM memory node.

    ``inputs`` maps memory-node names to arrays.  Compute nodes' ``fn`` maps a
    dict of named input sequences (1-D, FIFO order) to a dict of named output
    sequences; edge order defines name binding: inputs are bound as ``in0``,
    ``in1``, ... and outputs ``out0``, ... in edge insertion order.
    """
    g.validate()
    mems: Dict[str, np.ndarray] = {}
    for n in g.nodes.values():
        if n.kind == NodeKind.MEMORY:
            if n.name in inputs:
                mems[n.name] = np.array(inputs[n.name], dtype=n.dtype).copy()
            else:
                mems[n.name] = np.zeros(n.shape, dtype=n.dtype)

    # value on each edge (sequences for stream-ish hops)
    edge_val: Dict[int, np.ndarray] = {}

    for name in _toposort(g):
        node = g.nodes[name]
        ins = g.in_edges(name)
        outs = g.out_edges(name)
        if node.kind == NodeKind.MEMORY:
            # writers have already scattered into mems[name]
            for e in outs:
                if g.nodes[e.dst].kind == NodeKind.COMPUTE and e.access is not None:
                    edge_val[id(e)] = _gather(mems[name], e.access)
                elif g.nodes[e.dst].kind == NodeKind.READER:
                    pass  # reader pulls via its own access pattern
        elif node.kind == NodeKind.READER:
            src = ins[0]
            seq = _gather(mems[src.src], src.access)
            edge_val[id(outs[0])] = seq
        elif node.kind == NodeKind.WRITER:
            seq = edge_val[id(ins[0])]
            dst = outs[0]
            _scatter(mems[dst.dst], dst.access, seq)
        elif node.kind in (NodeKind.SYNC, NodeKind.ISSUER, NodeKind.PACKER):
            # Value-preserving by construction: issuer/packer only re-chunk
            # transactions; sync crosses rate domains.  FIFO order is kept.
            edge_val[id(outs[0])] = edge_val[id(ins[0])]
        elif node.kind == NodeKind.STREAM:
            edge_val[id(outs[0])] = edge_val[id(ins[0])]
        elif node.kind == NodeKind.COMPUTE:
            bound = {f"in{k}": edge_val[id(e)] for k, e in enumerate(ins)}
            if node.meta.get("carry") is not None:
                result = _run_carry(g, node, bound)
            else:
                result = node.fn(**bound) if node.fn else {}
            if not isinstance(result, dict):
                result = {"out0": result}
            for k, e in enumerate(outs):
                seq = np.asarray(result[f"out{k}"])
                dst = g.nodes[e.dst]
                if dst.kind == NodeKind.MEMORY and e.access is not None:
                    _scatter(mems[e.dst], e.access, seq)
                else:
                    edge_val[id(e)] = seq
        else:  # pragma: no cover
            raise NotImplementedError(node.kind)

    return mems
