"""Reference executor for the dataflow IR.

Interprets a Graph on numpy arrays so the transformation passes can be
*semantically validated*: streaming extraction and multi-pumping must be
value-preserving (issuer∘packer = identity; FIFO order = memory order).  The
executor is deliberately simple — streams are materialized as full sequences
in FIFO order — because it exists to check transformations, not to be fast.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .ir import Graph, Node, NodeKind, Space
from .symbolic import AccessPattern


def _gather(mem: np.ndarray, acc: AccessPattern) -> np.ndarray:
    flat = mem.reshape(-1)
    idx = np.fromiter(acc.addresses(mem.shape), dtype=np.int64)
    return flat[idx]


def _scatter(mem: np.ndarray, acc: AccessPattern, seq: np.ndarray) -> None:
    flat = mem.reshape(-1)
    idx = np.fromiter(acc.addresses(mem.shape), dtype=np.int64)
    flat[idx] = seq
    # mem viewed via reshape(-1) may be a copy for non-contiguous arrays;
    # callers pass contiguous buffers.


def _toposort(g: Graph) -> List[str]:
    indeg: Dict[str, int] = {n: 0 for n in g.nodes}
    for e in g.edges:
        indeg[e.dst] += 1
    ready = [n for n, d in indeg.items() if d == 0]
    order: List[str] = []
    while ready:
        n = ready.pop()
        order.append(n)
        for e in g.out_edges(n):
            indeg[e.dst] -= 1
            if indeg[e.dst] == 0:
                ready.append(e.dst)
    if len(order) != len(g.nodes):
        raise ValueError("graph has a cycle")
    return order


def run(g: Graph, inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Execute ``g``; returns the contents of every HBM memory node.

    ``inputs`` maps memory-node names to arrays.  Compute nodes' ``fn`` maps a
    dict of named input sequences (1-D, FIFO order) to a dict of named output
    sequences; edge order defines name binding: inputs are bound as ``in0``,
    ``in1``, ... and outputs ``out0``, ... in edge insertion order.
    """
    g.validate()
    mems: Dict[str, np.ndarray] = {}
    for n in g.nodes.values():
        if n.kind == NodeKind.MEMORY:
            if n.name in inputs:
                mems[n.name] = np.array(inputs[n.name], dtype=n.dtype).copy()
            else:
                mems[n.name] = np.zeros(n.shape, dtype=n.dtype)

    # value on each edge (sequences for stream-ish hops)
    edge_val: Dict[int, np.ndarray] = {}

    for name in _toposort(g):
        node = g.nodes[name]
        ins = g.in_edges(name)
        outs = g.out_edges(name)
        if node.kind == NodeKind.MEMORY:
            # writers have already scattered into mems[name]
            for e in outs:
                if g.nodes[e.dst].kind == NodeKind.COMPUTE and e.access is not None:
                    edge_val[id(e)] = _gather(mems[name], e.access)
                elif g.nodes[e.dst].kind == NodeKind.READER:
                    pass  # reader pulls via its own access pattern
        elif node.kind == NodeKind.READER:
            src = ins[0]
            seq = _gather(mems[src.src], src.access)
            edge_val[id(outs[0])] = seq
        elif node.kind == NodeKind.WRITER:
            seq = edge_val[id(ins[0])]
            dst = outs[0]
            _scatter(mems[dst.dst], dst.access, seq)
        elif node.kind in (NodeKind.SYNC, NodeKind.ISSUER, NodeKind.PACKER):
            # Value-preserving by construction: issuer/packer only re-chunk
            # transactions; sync crosses rate domains.  FIFO order is kept.
            edge_val[id(outs[0])] = edge_val[id(ins[0])]
        elif node.kind == NodeKind.STREAM:
            edge_val[id(outs[0])] = edge_val[id(ins[0])]
        elif node.kind == NodeKind.COMPUTE:
            bound = {f"in{k}": edge_val[id(e)] for k, e in enumerate(ins)}
            result = node.fn(**bound) if node.fn else {}
            if not isinstance(result, dict):
                result = {"out0": result}
            for k, e in enumerate(outs):
                seq = np.asarray(result[f"out{k}"])
                dst = g.nodes[e.dst]
                if dst.kind == NodeKind.MEMORY and e.access is not None:
                    _scatter(mems[e.dst], e.access, seq)
                else:
                    edge_val[id(e)] = seq
        else:  # pragma: no cover
            raise NotImplementedError(node.kind)

    return mems
