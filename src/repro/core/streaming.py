"""Streaming pass (paper §3.2, box ②).

Converts memory-mediated dataflow into FIFO-stream dataflow:

1. *Legality*: for each (producer module → Memory → consumer module) pair,
   check with :func:`repro.core.symbolic.sequence_equivalent` that the write
   and read sequences visit the same addresses in the same order.  This is the
   "intersection check on each pair of connected modules".
2. *Extraction*: for each Memory input of a Compute node, inject a ``Reader``
   module that walks the memory in the computation's access order and pushes
   into a new Stream; symmetrically a ``Writer`` pops from a Stream and
   commits to memory.  After this, streams drive control flow and all modules
   run concurrently — the precondition for re-negotiating their rates
   (multi-pumping).

The pass is *greedy over the whole graph* by default (paper §3.4: "taking the
largest possible subgraph as the candidate"), but accepts a node filter for
interactive/targeted application.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from .ir import Edge, Graph, Node, NodeKind, RateDomain, Space
from .symbolic import AccessPattern, sequence_equivalent


class StreamingReport:
    def __init__(self):
        self.streamed: List[Tuple[str, str]] = []
        self.rejected: List[Tuple[str, str, str]] = []  # (src, dst, reason)

    def __repr__(self):  # pragma: no cover
        return (f"StreamingReport(streamed={len(self.streamed)}, "
                f"rejected={len(self.rejected)})")


def can_stream_edge(g: Graph, mem: Node, write: Optional[Edge],
                    read: Edge) -> Tuple[bool, str]:
    """Check that a memory container's producer/consumer can be FIFO-linked."""
    if mem.kind != NodeKind.MEMORY:
        return False, "not a memory node"
    if read.access is None:
        return False, "consumer access unknown"
    if write is None:
        # External input: a Reader can always linearize a known access pattern.
        return True, "external input"
    if write.access is None:
        return False, "producer access unknown"
    if not sequence_equivalent(write.access, read.access, mem.shape):
        return False, "write/read orders differ (intersection check failed)"
    return True, "orders match"


def apply_streaming(g: Graph,
                    node_filter: Optional[Callable[[Node], bool]] = None
                    ) -> Tuple[Graph, StreamingReport]:
    """Rewrite ``g``: memory edges into/out of Compute nodes become streams.

    Returns a new graph; ``g`` is unmodified.  Memory containers that feed
    computes through a legal order become Reader->Stream (inputs) and
    Stream->Writer (outputs).  Illegal edges are left as direct memory access
    and recorded in the report.
    """
    out = g.copy()
    report = StreamingReport()
    keep = node_filter or (lambda n: True)

    new_edges: List[Edge] = []
    drop: set = set()

    for comp in list(out.computes()):
        if not keep(comp):
            continue
        # ---- inputs: Memory -> Compute becomes Memory -> Reader -> Stream -> Compute
        for e in out.in_edges(comp.name):
            src = out.nodes[e.src]
            if src.kind != NodeKind.MEMORY or src.space != Space.HBM:
                continue
            writers = [w for w in out.in_edges(src.name)]
            wedge = writers[0] if writers else None
            ok, why = can_stream_edge(out, src, wedge, e)
            if not ok:
                report.rejected.append((src.name, comp.name, why))
                continue
            rd = out.add(Node(f"read_{src.name}_{comp.name}", NodeKind.READER,
                              rate=RateDomain.SLOW, domain=e.access.domain))
            st = out.stream(f"s_{src.name}_{comp.name}", dtype=src.dtype,
                            elem_width=e.access.width)
            new_edges.append(Edge(src.name, rd.name, e.access, e.volume))
            new_edges.append(Edge(rd.name, st.name, None, e.volume))
            new_edges.append(Edge(st.name, comp.name, None, e.volume))
            drop.add(id_of(out, e))
            report.streamed.append((src.name, comp.name))
        # ---- outputs: Compute -> Memory becomes Compute -> Stream -> Writer -> Memory
        for e in out.out_edges(comp.name):
            dst = out.nodes[e.dst]
            if dst.kind != NodeKind.MEMORY or dst.space != Space.HBM:
                continue
            if e.access is None:
                report.rejected.append((comp.name, dst.name, "unknown access"))
                continue
            readers_downstream = out.out_edges(dst.name)
            legal = True
            for rdedge in readers_downstream:
                ok, why = can_stream_edge(out, dst, e, rdedge)
                if not ok:
                    legal = False
                    report.rejected.append((comp.name, dst.name, why))
                    break
            if not legal:
                continue
            wr = out.add(Node(f"write_{comp.name}_{dst.name}", NodeKind.WRITER,
                              rate=RateDomain.SLOW, domain=e.access.domain))
            st = out.stream(f"s_{comp.name}_{dst.name}", dtype=dst.dtype,
                            elem_width=e.access.width)
            new_edges.append(Edge(comp.name, st.name, None, e.volume))
            new_edges.append(Edge(st.name, wr.name, None, e.volume))
            new_edges.append(Edge(wr.name, dst.name, e.access, e.volume))
            drop.add(id_of(out, e))
            report.streamed.append((comp.name, dst.name))

    out.edges = [e for e in out.edges if id_of(out, e) not in drop] + new_edges
    out.validate()
    return out, report


def id_of(g: Graph, e: Edge) -> int:
    return id(e)


def streamable_subgraph(g: Graph) -> List[str]:
    """Largest set of modules connected purely by streams (paper's greedy pick)."""
    names = []
    for n in g.modules():
        edges = g.in_edges(n.name) + g.out_edges(n.name)
        if edges and all(
            g.nodes[e.src].kind == NodeKind.STREAM
            or g.nodes[e.dst].kind == NodeKind.STREAM
            for e in edges
        ):
            names.append(n.name)
    return names
