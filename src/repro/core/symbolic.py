"""Minimal symbolic affine-expression engine for data-movement analysis.

The paper's streaming / multi-pumping legality checks (§3.2) rest on comparing
the *order* in which connected modules produce and consume memory locations.
DaCe uses sympy for this; we implement the small affine subset the analysis
needs so the package stays dependency-free:

    expr ::= const + sum_k coeff_k * sym_k

Access patterns are tuples of affine expressions over a rectangular iteration
domain.  Two patterns are *sequence-equivalent* when, walking their domains in
lexicographic order, they touch the same addresses in the same order — the
condition under which a memory edge can be replaced by a FIFO stream.

For grouped / ragged iteration (a MoE expert id selecting a weight slab, a
tile id selecting its group's row offset) the pure-affine subset is extended
with *group-indexed table terms*: ``Affine.table(sym, values)`` contributes
``values[sym]`` — a static integer lookup keyed by a domain symbol.  Tables
keep every analysis static (the lookup is data-independent, fixed at graph
construction), so streaming legality, blocked-view derivation and Pallas
index maps all continue to work; only the expression is no longer linear in
the table symbol.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, Mapping, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Affine:
    """``const + Σ coeff[sym]·sym + Σ table[sym]`` with integer coefficients.

    ``tables`` holds group-indexed lookup terms ``(sym, values)``: the term
    contributes ``values[sym]`` — ragged row offsets, expert→slab ids, GQA
    head folding.  Lookups are static integer tables, so the expression
    stays analyzable; they are simply not linear in the table symbol.
    """

    terms: Tuple[Tuple[str, int], ...] = ()
    const: int = 0
    tables: Tuple[Tuple[str, Tuple[int, ...]], ...] = ()

    # -- constructors -------------------------------------------------------
    @staticmethod
    def of(sym: str, coeff: int = 1, const: int = 0) -> "Affine":
        if coeff == 0:
            return Affine((), const)
        return Affine(((sym, coeff),), const)

    @staticmethod
    def constant(c: int) -> "Affine":
        return Affine((), c)

    @staticmethod
    def table(sym: str, values: Iterable[int]) -> "Affine":
        """Group-indexed term ``values[sym]`` (static integer lookup)."""
        return Affine((), 0, ((sym, tuple(int(v) for v in values)),))

    def _as_dict(self) -> Dict[str, int]:
        return dict(self.terms)

    @staticmethod
    def _from_dict(d: Mapping[str, int], const: int,
                   tables: Tuple = ()) -> "Affine":
        items = tuple(sorted((s, c) for s, c in d.items() if c != 0))
        return Affine(items, const, tables)

    # -- algebra -------------------------------------------------------------
    def __add__(self, other: "Affine | int") -> "Affine":
        if isinstance(other, int):
            return Affine(self.terms, self.const + other, self.tables)
        d = self._as_dict()
        for s, c in other.terms:
            d[s] = d.get(s, 0) + c
        return Affine._from_dict(d, self.const + other.const,
                                 self.tables + other.tables)

    def __radd__(self, other: int) -> "Affine":
        return self.__add__(other)

    def __mul__(self, k: int) -> "Affine":
        if not isinstance(k, int):
            raise TypeError("Affine supports multiplication by int only")
        return Affine._from_dict(
            {s: c * k for s, c in self.terms}, self.const * k,
            tuple((s, tuple(v * k for v in t)) for s, t in self.tables))

    __rmul__ = __mul__

    def __sub__(self, other: "Affine | int") -> "Affine":
        if isinstance(other, int):
            other = Affine.constant(other)
        return self + other * (-1)

    # -- queries --------------------------------------------------------------
    def symbols(self) -> Tuple[str, ...]:
        return tuple(s for s, _ in self.terms) \
            + tuple(s for s, _ in self.tables)

    def coeff(self, sym: str) -> int:
        return self._as_dict().get(sym, 0)

    def table_range(self) -> Tuple[int, int]:
        """(min, max) total contribution of the table terms."""
        lo = hi = 0
        for _s, t in self.tables:
            lo += min(t)
            hi += max(t)
        return lo, hi

    def evaluate(self, env: Mapping[str, int]) -> int:
        out = self.const + sum(c * env[s] for s, c in self.terms)
        for s, t in self.tables:
            out += t[env[s]]
        return out

    def substitute(self, mapping: Mapping[str, "Affine"]) -> "Affine":
        for s, _t in self.tables:
            if s in mapping:
                raise ValueError(
                    f"cannot substitute table-indexed symbol {s!r}; "
                    "group-indexed lookups are not linear")
        out = Affine((), self.const, self.tables)
        for s, c in self.terms:
            repl = mapping.get(s)
            if repl is None:
                out = out + Affine.of(s, c)
            else:
                out = out + repl * c
        return out

    def rename(self, mapping: Mapping[str, str]) -> "Affine":
        return Affine._from_dict(
            {mapping.get(s, s): c for s, c in self.terms}, self.const,
            tuple((mapping.get(s, s), t) for s, t in self.tables)
        )

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        parts = [f"{c}*{s}" for s, c in self.terms]
        parts += [f"tbl[{s}]" for s, _ in self.tables]
        if self.const or not parts:
            parts.append(str(self.const))
        return " + ".join(parts)


@dataclasses.dataclass(frozen=True)
class Domain:
    """Rectangular iteration domain; dims walked in lexicographic order."""

    dims: Tuple[Tuple[str, int, int, int], ...]  # (sym, start, stop, step)

    @staticmethod
    def of(*dims: Tuple[str, int, int] | Tuple[str, int, int, int]) -> "Domain":
        norm = []
        for d in dims:
            if len(d) == 3:
                norm.append((d[0], d[1], d[2], 1))
            else:
                norm.append(tuple(d))
        return Domain(tuple(norm))

    @property
    def symbols(self) -> Tuple[str, ...]:
        return tuple(d[0] for d in self.dims)

    @property
    def extents(self) -> Tuple[int, ...]:
        return tuple(
            max(0, (stop - start + step - 1) // step)
            for _, start, stop, step in self.dims
        )

    def size(self) -> int:
        n = 1
        for e in self.extents:
            n *= e
        return n

    def points(self, limit: int | None = None) -> Iterable[Dict[str, int]]:
        ranges = [range(start, stop, step) for _, start, stop, step in self.dims]
        for i, combo in enumerate(itertools.product(*ranges)):
            if limit is not None and i >= limit:
                return
            yield dict(zip(self.symbols, combo))

    def scaled(self, sym: str, factor: int) -> "Domain":
        """Divide extent of ``sym`` by ``factor`` (vectorization of a range)."""
        out = []
        for s, start, stop, step in self.dims:
            if s == sym:
                n = (stop - start + step - 1) // step
                if n % factor != 0:
                    raise ValueError(
                        f"extent of {sym} ({n}) not divisible by pump factor {factor}"
                    )
                out.append((s, start, start + (n // factor) * step, step))
            else:
                out.append((s, start, stop, step))
        return Domain(tuple(out))


@dataclasses.dataclass(frozen=True)
class AccessPattern:
    """Multi-dimensional affine access walked over a Domain."""

    domain: Domain
    exprs: Tuple[Affine, ...]
    # number of contiguous elements touched per point along the last dim
    width: int = 1

    def addresses(self, shape: Sequence[int], limit: int | None = None):
        """Linearized addresses in iteration order (for brute-force checks)."""
        strides = []
        acc = 1
        for s in reversed(shape):
            strides.append(acc)
            acc *= s
        strides = list(reversed(strides))
        for env in self.domain.points(limit=limit):
            base = sum(
                e.evaluate(env) * st for e, st in zip(self.exprs, strides)
            )
            for w in range(self.width):
                yield base + w

    def normalized_exprs(self) -> Tuple[Affine, ...]:
        """Rename domain symbols to canonical names _i0, _i1, ..."""
        mapping = {s: f"_i{k}" for k, s in enumerate(self.domain.symbols)}
        return tuple(e.rename(mapping) for e in self.exprs)


@dataclasses.dataclass(frozen=True)
class BlockedAccess:
    """A block-structured reading of an :class:`AccessPattern`.

    The Pallas emission backend consumes this instead of the flat address
    sequence: every grid point ``env`` (one integer per outer symbol) touches
    the dense box ``[offsets[d](env) : offsets[d](env) + block[d]]`` per
    memory dimension.  ``offsets`` are *element-unit* affines over the grid
    symbols; dividing them by ``block`` (when exact) yields the block-unit
    index map a ``pl.BlockSpec`` wants — see :meth:`block_unit_offsets`.
    """

    block: Tuple[int, ...]                 # slice extent per memory dim
    grid: Tuple[Tuple[str, int], ...]      # (symbol, extent), outermost first
    offsets: Tuple[Affine, ...]            # element-unit start per memory dim

    @property
    def grid_symbols(self) -> Tuple[str, ...]:
        return tuple(s for s, _ in self.grid)

    def block_unit_offsets(self) -> "Tuple[Affine, ...] | None":
        """Offsets divided by the block extents, or None when any coefficient
        is not an exact multiple (the access is then not expressible as a
        Pallas block-index map, only as an element-unit ``dynamic_slice``)."""
        out = []
        for a, b in zip(self.offsets, self.block):
            if b == 1:
                out.append(a)
                continue
            if a.const % b or any(c % b for _, c in a.terms) \
                    or any(v % b for _, t in a.tables for v in t):
                return None
            out.append(Affine(tuple((s, c // b) for s, c in a.terms),
                              a.const // b,
                              tuple((s, tuple(v // b for v in t))
                                    for s, t in a.tables)))
        return tuple(out)

    def covers(self, shape: Sequence[int]) -> bool:
        """True when the grid×block tiling exactly covers ``shape`` element
        count (no gaps) — the precondition for emitting this access as a
        Pallas *output* whose buffer starts uninitialized."""
        n = 1
        for b in self.block:
            n *= b
        for _, e in self.grid:
            n *= e
        total = 1
        for s in shape:
            total *= s
        return n == total


def blocked_access(acc: AccessPattern, shape: Sequence[int],
                   protect: Sequence[str] = ()) -> "BlockedAccess | None":
    """Derive a :class:`BlockedAccess` from ``acc`` over a memory ``shape``.

    Two sources contribute to the block: the contiguous ``width`` (spilling
    backwards over trailing dimensions whose expression is identically 0),
    and a suffix of unit-coefficient, unit-step domain symbols that each walk
    one dimension densely (e.g. the row symbol of a matmul panel).  Remaining
    (outer) symbols become the grid.  Returns None when the pattern does not
    decompose this way — callers fall back to flat gather/scatter lowering.

    ``protect`` lists domain symbols that must stay *grid* symbols even when
    they walk a dimension densely.  A compute's step-domain symbols are
    protected by the region planner/carry layout: an access like
    ``o[bi, hi, :]`` over the domain ``(bi, hi)`` is locally one dense
    ``(b, h, d)`` block, but the kernel visits it one ``(1, 1, d)`` tile per
    (bi, hi) grid point — absorbing the step symbols would collapse the
    emission grid (and mis-size per-sweep carry outputs).
    """
    rank = len(shape)
    if len(acc.exprs) != rank:
        return None

    block = [1] * rank
    exprs = list(acc.exprs)

    # 1. distribute the contiguous width over trailing dims
    w = acc.width
    d = rank - 1
    while w > 1 and d >= 0:
        if w >= shape[d]:
            if w % shape[d] or exprs[d].terms or exprs[d].const:
                return None        # spill requires a full, zero-based dim
            block[d] = shape[d]
            w //= shape[d]
        else:
            block[d] = w
            w = 1
        d -= 1
    if w > 1:
        return None

    # 2. absorb a dense suffix of intra-block symbols (unit coeff/step/base)
    dims = list(acc.domain.dims)
    extents = list(acc.domain.extents)
    while dims:
        sym, start, _stop, step = dims[-1]
        ext = extents[-1]
        if sym in protect:
            break
        hits = [i for i, e in enumerate(exprs) if e.coeff(sym)]
        if len(hits) != 1 or exprs[hits[0]].coeff(sym) != 1:
            break
        if start != 0 or step != 1:
            break
        i = hits[0]
        if block[i] != 1:
            break                   # width already owns this dimension
        rest = exprs[i].substitute({sym: Affine.constant(0)})
        if rest.const % ext or any(c % ext for _, c in rest.terms) \
                or any(v % ext for _, t in rest.tables for v in t):
            break                   # unaligned dense walk: keep as grid dim
        block[i] = ext
        exprs[i] = rest
        dims.pop()
        extents.pop()

    # 3. remaining (outer) symbols form the grid; emission walks raw indices
    #    0..extent-1, so they must be zero-based with unit step
    for sym, start, _stop, step in dims:
        if start != 0 or step != 1:
            return None
    grid = tuple((s, e) for (s, _, _, _), e in zip(dims, extents))
    grid_syms = {s for s, _ in grid}
    for e in exprs:
        if any(s not in grid_syms for s in e.symbols()):
            return None             # leftover intra symbol in an offset
    # 4. every grid point's box must stay in bounds (no row straddling)
    for d_i, (e, b) in enumerate(zip(exprs, block)):
        tlo, thi = e.table_range()
        lo = e.const + tlo
        hi = e.const + thi
        for s, c in e.terms:
            ext = dict(grid)[s]
            if c >= 0:
                hi += c * (ext - 1)
            else:
                lo += c * (ext - 1)
        if lo < 0 or hi + b > shape[d_i]:
            return None
    return BlockedAccess(tuple(block), grid, tuple(exprs))


def split_temporal(acc: BlockedAccess, sym: str, factor: int,
                   pump_sym: str = "_pump") -> BlockedAccess:
    """Mode-T temporal realization: split grid symbol ``sym`` (extent G) into
    an outer symbol of extent G/factor and the innermost temporal symbol
    ``pump_sym`` of extent ``factor`` — one wide transaction per outer step,
    ``factor`` narrow beats per transaction.  Offsets are rewritten by the
    exact substitution ``sym -> sym*factor + pump_sym``."""
    repl = Affine.of(sym, factor) + Affine.of(pump_sym)
    grid = []
    for s, e in acc.grid:
        if s == sym:
            if e % factor:
                raise ValueError(f"extent {e} of {sym} not divisible by "
                                 f"pump factor {factor}")
            grid.append((s, e // factor))
        else:
            grid.append((s, e))
    grid.append((pump_sym, factor))
    offsets = tuple(e.substitute({sym: repl}) for e in acc.offsets)
    return BlockedAccess(acc.block, tuple(grid), offsets)


def narrow_block(acc: BlockedAccess, dim: int, factor: int,
                 pump_sym: str = "_pump") -> BlockedAccess:
    """Mode-R temporal realization for one access: narrow ``block[dim]`` by
    ``factor`` and walk the ``factor`` sub-tiles with the temporal symbol
    (which the caller appends to the region grid)."""
    b = acc.block[dim]
    if b % factor:
        raise ValueError(f"block extent {b} not divisible by {factor}")
    block = list(acc.block)
    block[dim] = b // factor
    offsets = list(acc.offsets)
    offsets[dim] = offsets[dim] + Affine.of(pump_sym, b // factor)
    return BlockedAccess(tuple(block), acc.grid, tuple(offsets))


def sequence_equivalent(
    a: AccessPattern, b: AccessPattern, shape: Sequence[int], probe: int = 4096
) -> bool:
    """True iff ``a`` and ``b`` touch the same address sequence in order.

    This is the intersection/order check from §3.2 used to decide whether a
    memory edge between two modules may become a FIFO stream.  Fast path:
    identical domains (up to symbol names) and identical normalized affine
    expressions.  Slow path (small domains / differing shapes): brute-force
    compare the first ``probe`` linearized addresses.
    """
    if (
        a.domain.extents == b.domain.extents
        and a.width == b.width
        and a.normalized_exprs() == b.normalized_exprs()
    ):
        return True
    # brute force fallback, bounded
    if a.domain.size() * a.width != b.domain.size() * b.width:
        return False
    seq_a = a.addresses(shape, limit=probe)
    seq_b = b.addresses(shape, limit=probe)
    return all(x == y for x, y in itertools.zip_longest(seq_a, seq_b))
