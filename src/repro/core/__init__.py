"""Temporal vectorization core: dataflow IR + streaming + multi-pumping.

Public API:

    from repro.core import (
        Graph, Domain, Affine, AccessPattern, PumpSpec,
        apply_streaming, apply_multipump, effective_rate,
        plan_kernel_pump, plan_trainer_pump,
    )
"""
from .symbolic import Affine, AccessPattern, Domain, sequence_equivalent
from .ir import (CarrySpec, Edge, Graph, Node, NodeKind, PumpSpec,
                 RateDomain, Space, effective_rate)
from .streaming import apply_streaming, streamable_subgraph, StreamingReport
from .multipump import (apply_multipump, check_multipump, PumpReport,
                        throughput_model, pump_spec_for)
from .pump_plan import (KernelEstimate, best_pump_factor, plan_kernel_pump,
                        plan_trainer_pump, mxu_aligned_tile, align_up,
                        PEAK_FLOPS_BF16, HBM_BW, ICI_BW, VMEM_BYTES, MXU_DIM)
from . import executor
from .autopump import autopump, AutopumpResult, BUILDERS


def __getattr__(name):
    # Lazy re-export of the compiler subsystem (PEP 562):
    # ``repro.core.compiler.compile(graph, ...)`` runs the pass pipeline +
    # lowering backend.  Deferred so that repro.core itself stays jax-free
    # (reference executor / IR analysis users pay no jax import) and the
    # core→compiler→core import cycle never materializes eagerly.
    if name == "compiler":
        from repro import compiler
        return compiler
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Affine", "AccessPattern", "Domain", "sequence_equivalent",
    "CarrySpec", "Edge", "Graph", "Node", "NodeKind", "PumpSpec",
    "RateDomain", "Space",
    "effective_rate", "apply_streaming", "streamable_subgraph",
    "StreamingReport", "apply_multipump", "check_multipump", "PumpReport",
    "throughput_model", "pump_spec_for", "KernelEstimate", "best_pump_factor",
    "plan_kernel_pump", "plan_trainer_pump", "mxu_aligned_tile", "align_up",
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW", "VMEM_BYTES", "MXU_DIM",
    "executor", "autopump", "AutopumpResult", "BUILDERS", "compiler",
]
