"""Automatic multi-pumping: the paper's end-to-end workflow as one call.

The paper's §3 pipeline is: program → dataflow IR → streaming pass →
(greedy largest-subgraph) multi-pump transform → codegen.  This module is
that pipeline for our kernel library: each registered kernel carries an IR
*builder* describing its data movement; :func:`autopump` runs the passes,
checks legality, consults the capacity model for the factor, and returns
both the transformed graph (for inspection/reporting) and the
:class:`~repro.core.ir.PumpSpec` the Pallas layer consumes.

    spec, report = autopump("matmul", m=4096, n=4096, k=4096)
    out = kernels.matmul(a, b, pump=spec)

This is the "automatic application" contribution: the user never chooses M
or identifies the streamable subgraph by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from .ir import CarrySpec, Graph, PumpSpec
from .multipump import PumpReport
from .pump_plan import KernelEstimate, VMEM_BYTES
from .symbolic import AccessPattern, Affine, Domain


@dataclasses.dataclass
class AutopumpResult:
    spec: PumpSpec
    graph: Graph                 # transformed IR (streamed + pumped)
    streaming_report: object
    pump_report: Optional[PumpReport]
    estimate: KernelEstimate
    pipeline_report: object = None   # repro.compiler PipelineReport
    kernel: object = None            # CompiledKernel when backend != 'none'

    def summary(self) -> str:
        r = self.graph.resources()
        return (f"M={self.spec.factor} mode={self.spec.mode} "
                f"units={r['compute_units']} adapters={r['adapters']} "
                f"modeled_tp={self.estimate.throughput(self.spec.factor):.3g}/s")


def _xp(a):
    """numpy/jax dispatch for fn bodies that need library calls (not just
    operators).  jax.numpy is imported lazily so repro.core stays jax-free
    for reference-executor users; numpy arrays keep numpy semantics."""
    if isinstance(a, np.ndarray):
        return np
    import jax.numpy as jnp
    return jnp


# ------------------------------------------------------------ IR builders --
# fn bodies are numpy/jax polymorphic (operator-based) so the same body runs
# in the reference executor and in the compiler's lowering backends.  The
# optional meta['tile_fn'] is the *per-grid-step* form consumed by the Pallas
# emission backend: it maps operand blocks (shaped per the blocked view of
# the access pattern) to one output block, while fn maps whole FIFO
# sequences.  meta['reduce']='add' marks tile_fn outputs as partial
# contributions accumulated over grid dims absent from the output access.
# Kernels with a loop-carried dependency declare meta['carry'] (a CarrySpec:
# per-step step_fn + per-sweep final_fn over block-shaped operands) instead
# of fn/tile_fn, and meta['axes'] labels each operand/output/state dimension
# with a logical axis so mode-R narrowing follows the dataflow
# correspondence rather than a size/symbol heuristic.
def _vecadd_graph(n: int, vector_width: int = 8, itemsize: int = 4):
    v = vector_width
    g = Graph("vecadd")
    g.memory("x", (n,))
    g.memory("y", (n,))
    g.memory("z", (n,))
    dom = Domain.of(("i", 0, max(n // v, 1)))
    acc = AccessPattern(dom, (Affine.of("i", v),), width=v)
    add = lambda in0, in1: {"out0": in0 + in1}   # noqa: E731 - elementwise
    g.compute("add", dom, fn=add, vector_width=v, tile_fn=add)
    g.connect("x", "add", acc)
    g.connect("y", "add", acc)
    g.connect("add", "z", acc)
    est = KernelEstimate(block_bytes_in=2 * v * itemsize,
                         block_bytes_out=v * itemsize,
                         flops_per_block=float(v))
    return g, est


def _matmul_graph(m: int, n: int, k: int, bm: int = 128, bn: int = 128,
                  bk: int = 128, itemsize: int = 4,
                  vector_width: Optional[int] = None):
    g = Graph("matmul")
    g.memory("a", (m, k))
    g.memory("b", (k, n))
    g.memory("c", (m, n))
    dom = Domain.of(("i", 0, max(m // bm, 1)), ("j", 0, max(n // bn, 1)),
                    ("kk", 0, max(k // bk, 1)))
    fn = None
    if m % bm == 0 and n % bn == 0 and k % bk == 0:
        # Executable form: access patterns walk full (row-contiguous) operand
        # panels per block point, so the FIFO sequences carry all the data
        # and the compute body is a real blocked matmul.
        nbm, nbn, nbk = m // bm, n // bn, k // bk
        dom_a = Domain.of(("i", 0, nbm), ("j", 0, nbn), ("kk", 0, nbk),
                          ("r", 0, bm))
        acc_a = AccessPattern(
            dom_a, (Affine.of("i", bm) + Affine.of("r"), Affine.of("kk", bk)),
            width=bk)
        dom_b = Domain.of(("i", 0, nbm), ("j", 0, nbn), ("kk", 0, nbk),
                          ("r", 0, bk))
        acc_b = AccessPattern(
            dom_b, (Affine.of("kk", bk) + Affine.of("r"), Affine.of("j", bn)),
            width=bn)
        dom_c = Domain.of(("i", 0, nbm), ("j", 0, nbn), ("r", 0, bm))
        acc_c = AccessPattern(
            dom_c, (Affine.of("i", bm) + Affine.of("r"), Affine.of("j", bn)),
            width=bn)

        def fn(in0, in1):
            a = in0.reshape(nbm, nbn, nbk, bm, bk)
            b = in1.reshape(nbm, nbn, nbk, bk, bn)
            return {"out0": (a @ b).sum(axis=2).reshape(-1)}

        # per-tile form: one MXU panel product, accumulated over the kk
        # grid dimension (absent from the output access) by the backend
        tile_fn = lambda in0, in1: {"out0": in0 @ in1}   # noqa: E731
    else:
        # Fallback (non-divisible shapes): corner-sampled transaction
        # schedule — enough for planning/legality, not executable.
        acc_a = AccessPattern(dom, (Affine.of("i", bm), Affine.of("kk", bk)),
                              width=1)
        acc_b = AccessPattern(dom, (Affine.of("kk", bk), Affine.of("j", bn)),
                              width=1)
        acc_c = AccessPattern(dom, (Affine.of("i", bm), Affine.of("j", bn)),
                              width=1)
        tile_fn = None
    if vector_width is None:
        vector_width = bm * bn // (128 * 128) or 1
    g.compute("mxu_tile", dom, fn=fn, vector_width=vector_width,
              tile_fn=tile_fn, reduce="add")
    g.connect("a", "mxu_tile", acc_a)
    g.connect("b", "mxu_tile", acc_b)
    g.connect("mxu_tile", "c", acc_c)
    est = KernelEstimate(block_bytes_in=(bm * bk + bk * bn) * itemsize,
                         block_bytes_out=0.0,
                         flops_per_block=2.0 * bm * bn * bk)
    return g, est


def _stencil_graph(d0: int, d1: int, d2: int, itemsize: int = 4,
                   coef: float = 0.25):
    """Plane-sweep Jacobi update along axis 0: each interior plane i+1 of
    ``y`` is rebuilt from the three-plane halo window x[i:i+3]; boundary
    planes keep the output memory's initial contents (zeros)."""
    g = Graph("stencil")
    g.memory("x", (d0, d1, d2))
    g.memory("y", (d0, d1, d2))
    ni = max(d0 - 2, 1)
    dom = Domain.of(("i", 0, ni))
    # overlapping halo reads: plane window [i, i+3); interior-plane writes
    acc_in = AccessPattern(dom, (Affine.of("i"), Affine.constant(0),
                                 Affine.constant(0)), width=3 * d1 * d2)
    acc_out = AccessPattern(dom, (Affine.of("i") + 1, Affine.constant(0),
                                  Affine.constant(0)), width=d1 * d2)

    def tile_fn(in0):
        # one halo window (3, d1', d2') -> one interior plane (1, d1', d2');
        # shape-polymorphic in the trailing dims (mode R narrows them)
        return {"out0": coef * (in0[0:1] + in0[2:3])
                + (1.0 - 2.0 * coef) * in0[1:2]}

    def fn(in0):
        w = in0.reshape(-1, 3, d1, d2)
        out = coef * (w[:, 0] + w[:, 2]) + (1.0 - 2.0 * coef) * w[:, 1]
        return {"out0": out.reshape(-1)}

    g.compute("plane_update", dom, fn=fn, tile_fn=tile_fn,
              vector_width=max(d1 * d2 // 128, 4))
    g.connect("x", "plane_update", acc_in)
    g.connect("plane_update", "y", acc_out)
    est = KernelEstimate(block_bytes_in=3 * d1 * d2 * itemsize,
                         block_bytes_out=d1 * d2 * itemsize,
                         flops_per_block=7.0 * d1 * d2)
    return g, est


def _floyd_graph(n: int, itemsize: int = 4):
    """All-pairs shortest paths.  The k-relaxation carries a loop-borne
    dependency through the whole matrix, so the IR models one compute whose
    fn runs the full pivot loop; the access pattern streams the matrix
    row-by-row (duplicate-free, so the graph is lowerable)."""
    g = Graph("floyd_warshall")
    g.memory("dist", (n, n))
    g.memory("out", (n, n))
    dom = Domain.of(("r", 0, n))
    acc = AccessPattern(dom, (Affine.of("r"), Affine.constant(0)), width=n)

    def fn(in0):
        xp = _xp(in0)
        d = in0.reshape(n, n)
        for k in range(n):
            d = xp.minimum(d, d[:, k:k + 1] + d[k:k + 1, :])
        return {"out0": d.reshape(-1)}

    g.compute("relax", dom, fn=fn, vector_width=max(n // 128, 4),
              data_dependent_io=False)
    g.connect("dist", "relax", acc)
    g.connect("relax", "out", acc)
    est = KernelEstimate(block_bytes_in=2 * n * itemsize,   # pivot row+col
                         block_bytes_out=0.0,
                         flops_per_block=2.0 * n * n)
    return g, est


NEG_INF = -1e30


def _blk(sym: str, size: int, nblocks: int) -> Affine:
    """Block-offset expression ``sym*size``; collapses to the constant 0
    when the axis has a single block (a symbolically nonzero expression on a
    width-spanned dimension would defeat blocked-view derivation)."""
    return Affine.of(sym, size) if nblocks > 1 else Affine.constant(0)


def _flash_graph(b: int, h: int, s: int, t: int, d: int, bq: int = 128,
                 bkv: int = 128, itemsize: int = 2, hkv: Optional[int] = None,
                 causal: bool = False, scale: Optional[float] = None,
                 dtype: str = "float32", vector_width: Optional[int] = None):
    """Flash attention as an executable carry graph.

    The online-softmax recurrence over KV blocks is the sequential-carry
    axis (``ji``); the compute is *multi-output* — the attention tile plus
    its running max and denominator land in three memories (``o``, ``m``,
    ``l``).  GQA head folding is a group-indexed table on the KV head dim.
    """
    hkv = hkv or h
    g = Graph("flash_attention")
    g.memory("q", (b, h, s, d), dtype=dtype)
    g.memory("k", (b, hkv, t, d), dtype=dtype)
    g.memory("v", (b, hkv, t, d), dtype=dtype)
    g.memory("o", (b, h, s, d), dtype=dtype)
    g.memory("m", (b, h, s))
    g.memory("l", (b, h, s))
    bq, bkv = min(bq, s), min(bkv, t)
    if scale is None:
        scale = d ** -0.5
    if vector_width is None:
        vector_width = bq * d // 128 or 1
    est = KernelEstimate(block_bytes_in=2 * bkv * d * itemsize,
                         block_bytes_out=0.0,
                         flops_per_block=4.0 * bq * bkv * d)

    nq, nj = s // bq, t // bkv
    dom = Domain.of(("bi", 0, b), ("hi", 0, h), ("qi", 0, max(nq, 1)),
                    ("ji", 0, max(nj, 1)))
    if s % bq or t % bkv or h % hkv:
        # corner-sampled transaction schedule: planning/legality only
        acc_kv = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                     Affine.of("ji", bkv),
                                     Affine.constant(0)), width=1)
        acc_o = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                    Affine.of("qi", bq), Affine.constant(0)),
                              width=1)
        g.compute("online_softmax", dom, vector_width=vector_width)
        g.connect("q", "online_softmax", acc_o)
        g.connect("k", "online_softmax", acc_kv)
        g.connect("v", "online_softmax", acc_kv)
        g.connect("online_softmax", "o", acc_o)
        return g, est

    group = h // hkv
    head = Affine.of("hi") if group == 1 else \
        Affine.table("hi", [i // group for i in range(h)])
    dom_q = Domain.of(("bi", 0, b), ("hi", 0, h), ("qi", 0, nq),
                      ("ji", 0, nj), ("r", 0, bq))
    acc_q = AccessPattern(dom_q, (Affine.of("bi"), Affine.of("hi"),
                                  _blk("qi", bq, nq) + Affine.of("r"),
                                  Affine.constant(0)), width=d)
    dom_kv = Domain.of(("bi", 0, b), ("hi", 0, h), ("qi", 0, nq),
                       ("ji", 0, nj), ("r", 0, bkv))
    acc_kv = AccessPattern(dom_kv, (Affine.of("bi"), head,
                                    _blk("ji", bkv, nj) + Affine.of("r"),
                                    Affine.constant(0)), width=d)
    dom_o = Domain.of(("bi", 0, b), ("hi", 0, h), ("qi", 0, nq),
                      ("r", 0, bq))
    acc_o = AccessPattern(dom_o, (Affine.of("bi"), Affine.of("hi"),
                                  _blk("qi", bq, nq) + Affine.of("r"),
                                  Affine.constant(0)), width=d)
    acc_ml = AccessPattern(dom_o, (Affine.of("bi"), Affine.of("hi"),
                                   _blk("qi", bq, nq) + Affine.of("r")),
                           width=1)

    def step_fn(carry, q_blk, k_blk, v_blk, idx=None):
        xp = _xp(q_blk)
        m_run, l_run, acc = carry
        f32 = xp.float32
        q2 = q_blk.reshape(q_blk.shape[-2], q_blk.shape[-1]).astype(f32)
        k2 = k_blk.reshape(k_blk.shape[-2], k_blk.shape[-1]).astype(f32)
        v2 = v_blk.reshape(v_blk.shape[-2], v_blk.shape[-1]).astype(f32)
        sc = (q2 * f32(scale)) @ k2.T                       # (bq', bkv)
        if causal:
            q_pos = idx["outer"][2] * bq + idx["pump"] * q2.shape[0] \
                + xp.arange(q2.shape[0])[:, None]
            k_pos = idx["step"] * bkv + xp.arange(k2.shape[0])[None, :]
            sc = xp.where(q_pos >= k_pos, sc, f32(NEG_INF))
        m_new = xp.maximum(m_run, sc.max(axis=-1, keepdims=True))
        alpha = xp.exp(m_run - m_new)
        prob = xp.exp(sc - m_new)
        l_new = l_run * alpha + prob.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + prob @ v2
        return (m_new, l_new, acc_new), None

    def final_fn(carry):
        xp = _xp(carry[0])
        m_run, l_run, acc = carry
        l_safe = xp.where(l_run == 0.0, xp.float32(1.0), l_run)
        o_blk = acc / l_safe
        return {"out0": o_blk[None, None],            # (1, 1, bq', d)
                "out1": m_run[None, None, :, 0],      # (1, 1, bq')
                "out2": l_run[None, None, :, 0]}

    g.compute(
        "online_softmax", dom, vector_width=vector_width,
        carry=CarrySpec(
            axis="ji",
            state=(((bq, 1), "float32", NEG_INF), ((bq, 1), "float32"),
                   ((bq, d), "float32")),
            step_fn=step_fn, final_fn=final_fn, pass_idx=True),
        axes=dict(ins=({2: "q", 3: "d"}, {2: "kv", 3: "d"}, {2: "kv", 3: "d"}),
                  outs=({2: "q", 3: "d"}, {2: "q"}, {2: "q"}),
                  carry=({0: "q"}, {0: "q"}, {0: "q", 1: "d"}),
                  narrow="q"))
    g.connect("q", "online_softmax", acc_q)
    g.connect("k", "online_softmax", acc_kv)
    g.connect("v", "online_softmax", acc_kv)
    g.connect("online_softmax", "o", acc_o)
    g.connect("online_softmax", "m", acc_ml)
    g.connect("online_softmax", "l", acc_ml)
    return g, est


def _ssd_graph(b: int, l: int, h: int, p: int, n: int, chunk: int = 64,
               itemsize: int = 2, n_groups: Optional[int] = None,
               dtype: str = "float32", vector_width: Optional[int] = None,
               final_state: bool = False):
    """Mamba-2 SSD chunked scan as an executable carry graph.

    The inter-chunk state recurrence is the sequential-carry axis (``ci``);
    each step consumes one chunk of (x, dt, B, C), emits one chunk of y, and
    threads the (n, p) state.  Group→head folding (B/C shared by ``h/g``
    heads) is a group-indexed table on the head symbol.

    ``final_state=True`` adds a second output memory ``state`` (b, h, n, p)
    carrying the post-sweep carry state — ``y`` stays a per-step output while
    ``state`` is emitted once per sweep through ``CarrySpec.final_fn``
    (``step_outs=1``).  This is what lets cached SSM prefill route through
    the compiler: decode needs the final inter-chunk state, which the
    plain scan graph never surfaced.
    """
    grp = n_groups or h
    g = Graph("ssd_scan")
    g.memory("x", (b, l, h, p), dtype=dtype)
    g.memory("dt", (b, l, h), dtype=dtype)
    g.memory("a", (h,), dtype=dtype)
    g.memory("bmat", (b, l, grp, n), dtype=dtype)
    g.memory("cmat", (b, l, grp, n), dtype=dtype)
    g.memory("y", (b, l, h, p), dtype=dtype)
    if final_state:
        g.memory("state", (b, h, n, p))
    chunk = min(chunk, l)
    if vector_width is None:
        vector_width = chunk * p // 128 or 1
    est = KernelEstimate(block_bytes_in=chunk * (p + 1 + 2 * n) * itemsize,
                         block_bytes_out=chunk * p * itemsize,
                         flops_per_block=2.0 * chunk * chunk * (n + p))

    nc = l // chunk
    dom = Domain.of(("bi", 0, b), ("hi", 0, h), ("ci", 0, max(nc, 1)))
    if l % chunk or h % grp:
        acc = AccessPattern(dom, (Affine.of("bi"), Affine.of("ci", chunk),
                                  Affine.of("hi"), Affine.constant(0)),
                            width=1)
        g.compute("chunk_update", dom, vector_width=vector_width)
        g.connect("x", "chunk_update", acc)
        g.connect("chunk_update", "y", acc)
        return g, est

    hpg = h // grp
    gexpr = Affine.of("hi") if hpg == 1 else \
        Affine.table("hi", [i // hpg for i in range(h)])
    dom_r = Domain.of(("bi", 0, b), ("hi", 0, h), ("ci", 0, nc),
                      ("r", 0, chunk))
    row = _blk("ci", chunk, nc) + Affine.of("r")
    acc_x = AccessPattern(dom_r, (Affine.of("bi"), row, Affine.of("hi"),
                                  Affine.constant(0)), width=p)
    acc_dt = AccessPattern(dom_r, (Affine.of("bi"), row, Affine.of("hi")),
                           width=1)
    acc_a = AccessPattern(dom, (Affine.of("hi"),), width=1)
    acc_bc = AccessPattern(dom_r, (Affine.of("bi"), row, gexpr,
                                   Affine.constant(0)), width=n)

    def step_fn(carry, x_blk, dt_blk, a_blk, b_blk, c_blk):
        xp = _xp(x_blk)
        f32 = xp.float32
        (state,) = carry                                   # (n, p')
        xc = x_blk.reshape(x_blk.shape[1], x_blk.shape[-1]).astype(f32)
        dtc = dt_blk.reshape(-1).astype(f32)               # (c,)
        a_dec = a_blk.reshape(-1)[0].astype(f32)
        bc_ = b_blk.reshape(b_blk.shape[1], b_blk.shape[-1]).astype(f32)
        cc_ = c_blk.reshape(c_blk.shape[1], c_blk.shape[-1]).astype(f32)
        logp = xp.cumsum(a_dec * dtc)                      # (c,) running decay
        y_carry = xp.exp(logp)[:, None] * (cc_ @ state)    # (c, p')
        cb = cc_ @ bc_.T                                   # (c, c)
        ratio = logp[:, None] - logp[None, :]
        t_idx = xp.arange(dtc.shape[0])
        mask = t_idx[:, None] >= t_idx[None, :]
        gmat = xp.where(mask,
                        cb * xp.exp(xp.where(mask, ratio, f32(0.0)))
                        * dtc[None, :], f32(0.0))
        y = y_carry + gmat @ xc
        w = xp.exp(logp[-1] - logp) * dtc                  # (c,)
        state = state * xp.exp(logp[-1]) + (bc_ * w[:, None]).T @ xc
        return (state,), {"out0": y[None, :, None, :]}     # (1, c, 1, p')

    final_fn = None
    out_axes = ({3: "p"},)
    if final_state:
        # surface the post-sweep carry state as a real graph output
        # (out1 — absolute edge position, after the per-step y)
        final_fn = lambda carry: {"out1": carry[0][None, None]}  # noqa: E731
        out_axes = ({3: "p"}, {3: "p"})
    g.compute(
        "chunk_update", dom, vector_width=vector_width,
        carry=CarrySpec(axis="ci", state=(((n, p), "float32"),),
                        step_fn=step_fn, final_fn=final_fn,
                        step_outs=1 if final_state else 0),
        axes=dict(ins=({3: "p"}, {}, {}, {}, {}),
                  outs=out_axes,
                  carry=({1: "p"},),
                  narrow="p"))
    g.connect("x", "chunk_update", acc_x)
    g.connect("dt", "chunk_update", acc_dt)
    g.connect("a", "chunk_update", acc_a)
    g.connect("bmat", "chunk_update", acc_bc)
    g.connect("cmat", "chunk_update", acc_bc)
    g.connect("chunk_update", "y", acc_x)
    if final_state:
        dom_s = Domain.of(("bi", 0, b), ("hi", 0, h))
        acc_s = AccessPattern(dom_s, (Affine.of("bi"), Affine.of("hi"),
                                      Affine.constant(0), Affine.constant(0)),
                              width=n * p)
        g.connect("chunk_update", "state", acc_s)
    return g, est


def _decode_attention_graph(b: int, h: int, t: int, d: int, bkv: int = 128,
                            itemsize: int = 4, hkv: Optional[int] = None,
                            scale: Optional[float] = None,
                            dtype: str = "float32",
                            vector_width: Optional[int] = None):
    """Incremental (S=1) attention against a preallocated KV cache.

    One query row per (batch, head) runs the online-softmax recurrence over
    KV tiles — the same sequential-carry axis (``ji``) as prefill flash
    attention, but with the causal mask replaced by a *position-offset*
    validity mask: an int32 ``pos`` input (one per batch row) marks the last
    written cache slot, and each step masks keys symbolically via
    ``k_pos <= pos`` (k_pos derived from the carry step index — no
    materialized boolean, so a bucketed cache length costs only the mask
    compare).  GQA head folding is the same group-indexed table as prefill.
    """
    hkv = hkv or h
    g = Graph("decode_attention")
    g.memory("q", (b, h, d), dtype=dtype)
    g.memory("k", (b, hkv, t, d), dtype=dtype)
    g.memory("v", (b, hkv, t, d), dtype=dtype)
    g.memory("pos", (b,), dtype="int32")
    g.memory("o", (b, h, d), dtype=dtype)
    bkv = min(bkv, t)
    if scale is None:
        scale = d ** -0.5
    if vector_width is None:
        vector_width = d // 128 or 1
    est = KernelEstimate(block_bytes_in=2 * bkv * d * itemsize,
                         block_bytes_out=0.0,
                         flops_per_block=4.0 * bkv * d)

    nj = t // bkv
    dom = Domain.of(("bi", 0, b), ("hi", 0, h), ("ji", 0, max(nj, 1)))
    if t % bkv or h % hkv:
        # corner-sampled transaction schedule: planning/legality only
        acc_kv = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                     Affine.of("ji", bkv),
                                     Affine.constant(0)), width=1)
        acc_o = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                    Affine.constant(0)), width=1)
        g.compute("decode_softmax", dom, vector_width=vector_width)
        g.connect("q", "decode_softmax", acc_o)
        g.connect("k", "decode_softmax", acc_kv)
        g.connect("v", "decode_softmax", acc_kv)
        g.connect("decode_softmax", "o", acc_o)
        return g, est

    group = h // hkv
    head = Affine.of("hi") if group == 1 else \
        Affine.table("hi", [i // group for i in range(h)])
    acc_q = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                Affine.constant(0)), width=d)
    dom_kv = Domain.of(("bi", 0, b), ("hi", 0, h), ("ji", 0, nj),
                       ("r", 0, bkv))
    acc_kv = AccessPattern(dom_kv, (Affine.of("bi"), head,
                                    _blk("ji", bkv, nj) + Affine.of("r"),
                                    Affine.constant(0)), width=d)
    acc_pos = AccessPattern(dom, (Affine.of("bi"),), width=1)
    dom_o = Domain.of(("bi", 0, b), ("hi", 0, h))
    acc_o = AccessPattern(dom_o, (Affine.of("bi"), Affine.of("hi"),
                                  Affine.constant(0)), width=d)

    def step_fn(carry, q_blk, k_blk, v_blk, pos_blk, idx=None):
        xp = _xp(q_blk)
        f32 = xp.float32
        m_run, l_run, acc = carry
        q2 = q_blk.reshape(1, q_blk.shape[-1]).astype(f32)
        k2 = k_blk.reshape(k_blk.shape[-2], k_blk.shape[-1]).astype(f32)
        v2 = v_blk.reshape(v_blk.shape[-2], v_blk.shape[-1]).astype(f32)
        sc = (q2 * f32(scale)) @ k2.T                      # (1, bkv)
        k_pos = idx["step"] * bkv + xp.arange(k2.shape[0])[None, :]
        sc = xp.where(k_pos <= pos_blk.reshape(-1)[0], sc, f32(NEG_INF))
        m_new = xp.maximum(m_run, sc.max(axis=-1, keepdims=True))
        alpha = xp.exp(m_run - m_new)
        prob = xp.exp(sc - m_new)
        l_new = l_run * alpha + prob.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + prob @ v2
        return (m_new, l_new, acc_new), None

    def final_fn(carry):
        xp = _xp(carry[0])
        m_run, l_run, acc = carry
        l_safe = xp.where(l_run == 0.0, xp.float32(1.0), l_run)
        return {"out0": (acc / l_safe)[None]}              # (1, 1, d')

    g.compute(
        "decode_softmax", dom, vector_width=vector_width,
        carry=CarrySpec(
            axis="ji",
            state=(((1, 1), "float32", NEG_INF), ((1, 1), "float32"),
                   ((1, d), "float32")),
            step_fn=step_fn, final_fn=final_fn, pass_idx=True),
        # the query row and the scores span the full head dim (it is the
        # softmax contraction), so mode R narrows only the value path:
        # v / accumulator / output walk d in M sub-tiles
        axes=dict(ins=({}, {}, {3: "d"}, {}),
                  outs=({2: "d"},),
                  carry=({}, {}, {1: "d"}),
                  narrow="d"))
    g.connect("q", "decode_softmax", acc_q)
    g.connect("k", "decode_softmax", acc_kv)
    g.connect("v", "decode_softmax", acc_kv)
    g.connect("pos", "decode_softmax", acc_pos)
    g.connect("decode_softmax", "o", acc_o)
    return g, est


def _ssd_decode_graph(b: int, h: int, p: int, n: int, itemsize: int = 4,
                      n_groups: Optional[int] = None, dtype: str = "float32",
                      vector_width: Optional[int] = None):
    """Single-token SSD recurrent step: one state update per (batch, head).

    ``state' = state · exp(A·dt) + (B·dt) ⊗ x`` and ``y = C · state'`` — a
    pure per-(bi, hi) map with *two* outputs (the token's y and the new
    state), expressed as a multi-output tile compute so the fused-region
    backend emits it as one blocked kernel.  Group→head folding of B/C is
    the group-indexed table shared with the chunked scan.
    """
    grp = n_groups or h
    g = Graph("ssd_decode")
    g.memory("state", (b, h, n, p))                       # fp32 carried state
    g.memory("x", (b, h, p), dtype=dtype)
    g.memory("dt", (b, h), dtype=dtype)
    g.memory("a", (h,), dtype=dtype)
    g.memory("bmat", (b, grp, n), dtype=dtype)
    g.memory("cmat", (b, grp, n), dtype=dtype)
    g.memory("y", (b, h, p), dtype=dtype)
    g.memory("state_out", (b, h, n, p))
    if vector_width is None:
        vector_width = n * p // 128 or 1
    est = KernelEstimate(block_bytes_in=(n * p + p + 2 * n) * itemsize,
                         block_bytes_out=(n * p + p) * itemsize,
                         flops_per_block=4.0 * n * p)
    if h % grp:
        dom = Domain.of(("bi", 0, b), ("hi", 0, h))
        acc = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                  Affine.constant(0)), width=1)
        g.compute("state_step", dom, vector_width=vector_width)
        g.connect("x", "state_step", acc)
        g.connect("state_step", "y", acc)
        return g, est

    hpg = h // grp
    gexpr = Affine.of("hi") if hpg == 1 else \
        Affine.table("hi", [i // hpg for i in range(h)])
    dom = Domain.of(("bi", 0, b), ("hi", 0, h))
    acc_state = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                    Affine.constant(0), Affine.constant(0)),
                              width=n * p)
    acc_x = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi"),
                                Affine.constant(0)), width=p)
    acc_dt = AccessPattern(dom, (Affine.of("bi"), Affine.of("hi")), width=1)
    acc_a = AccessPattern(dom, (Affine.of("hi"),), width=1)
    acc_bc = AccessPattern(dom, (Affine.of("bi"), gexpr,
                                 Affine.constant(0)), width=n)

    def tile_fn(in0, in1, in2, in3, in4, in5):
        xp = _xp(in1)
        f32 = xp.float32
        st = in0.reshape(in0.shape[-2], in0.shape[-1]).astype(f32)  # (n, p')
        xv = in1.reshape(-1).astype(f32)                            # (p',)
        dtv = in2.reshape(-1)[0].astype(f32)
        av = in3.reshape(-1)[0].astype(f32)
        bv = in4.reshape(-1).astype(f32)                            # (n,)
        cv = in5.reshape(-1).astype(f32)
        st2 = st * xp.exp(av * dtv) + (bv * dtv)[:, None] * xv[None, :]
        yv = cv @ st2                                               # (p',)
        return {"out0": yv[None, None, :], "out1": st2[None, None]}

    def fn(in0, in1, in2, in3, in4, in5):
        xp = _xp(in1)
        f32 = xp.float32
        st = in0.reshape(b, h, n, p).astype(f32)
        xv = in1.reshape(b, h, p).astype(f32)
        dtv = in2.reshape(b, h).astype(f32)
        av = in3.reshape(b, h).astype(f32)
        bv = in4.reshape(b, h, n).astype(f32)     # head-expanded by the FIFO
        cv = in5.reshape(b, h, n).astype(f32)
        decay = xp.exp(av * dtv)                                    # (b, h)
        st2 = st * decay[..., None, None] \
            + (bv * dtv[..., None])[..., :, None] * xv[..., None, :]
        yv = (cv[..., :, None] * st2).sum(axis=-2)                  # (b, h, p)
        return {"out0": yv.reshape(-1), "out1": st2.reshape(-1)}

    g.compute("state_step", dom, fn=fn, tile_fn=tile_fn,
              vector_width=vector_width,
              axes=dict(ins=({3: "p"}, {2: "p"}, {}, {}, {}, {}),
                        outs=({2: "p"}, {3: "p"}), carry=(), narrow="p"))
    g.connect("state", "state_step", acc_state)
    g.connect("x", "state_step", acc_x)
    g.connect("dt", "state_step", acc_dt)
    g.connect("a", "state_step", acc_a)
    g.connect("bmat", "state_step", acc_bc)
    g.connect("cmat", "state_step", acc_bc)
    g.connect("state_step", "y", acc_x)
    g.connect("state_step", "state_out", acc_state)
    return g, est


def _grouped_gemm_graph(e: int, c: int, d: int, f: int, bc: int = 128,
                        bf: int = 128, bd: int = 128, itemsize: int = 2,
                        group_sizes: Optional[Sequence[int]] = None,
                        dtype: str = "float32",
                        vector_width: Optional[int] = None):
    """Grouped (per-expert) GEMM as an executable IR graph.

    Dense form (``group_sizes=None``): ``o[e] = x[e] @ w[e]`` with the
    expert axis as the outermost grid symbol — a derivable BlockSpec per
    operand, the contraction accumulated over the ``ki`` reduction symbol.

    Ragged form: ``x`` is a row-major concatenation of per-expert row
    groups (``sum(group_sizes)`` rows).  The iteration flattens to a *tile
    list*: group-indexed tables map each row-tile id to its expert slab and
    its row offset (the megablocks idiom) — still a derivable BlockSpec,
    via table-affine index maps.  Each group size must divide the row
    block ``bc``.
    """
    bc, bf, bd = min(bc, c), min(bf, f), min(bd, d)
    if vector_width is None:
        vector_width = bc * bf // (128 * 128) or 1
    est = KernelEstimate(block_bytes_in=(bc * bd + bd * bf) * itemsize,
                         block_bytes_out=0.0,
                         flops_per_block=2.0 * bc * bf * bd)
    nbf, nbd = f // bf, d // bd

    if group_sizes is not None:
        sizes = [int(sz) for sz in group_sizes]
        if len(sizes) != e:
            raise ValueError(f"{len(sizes)} group sizes for {e} experts")
        rows = sum(sizes)
        g = Graph("grouped_gemm")
        g.memory("x", (rows, d), dtype=dtype)
        g.memory("w", (e, d, f), dtype=dtype)
        g.memory("o", (rows, f), dtype=dtype)
        if any(sz % bc for sz in sizes) or f % bf or d % bd:
            dom = Domain.of(("ti", 0, max(rows // bc, 1)))
            acc = AccessPattern(dom, (Affine.of("ti", bc),
                                      Affine.constant(0)), width=1)
            g.compute("expert_tile", dom, vector_width=vector_width)
            g.connect("x", "expert_tile", acc)
            g.connect("expert_tile", "o", acc)
            return g, est
        experts, row_starts = [], []
        for ei, sz in enumerate(sizes):
            for r0 in range(0, sz, bc):
                experts.append(ei)
                row_starts.append(sum(sizes[:ei]) + r0)
        nt = len(experts)
        row0 = Affine.table("ti", row_starts)
        dom_x = Domain.of(("ti", 0, nt), ("ji", 0, nbf), ("ki", 0, nbd),
                          ("r", 0, bc))
        acc_x = AccessPattern(dom_x, (row0 + Affine.of("r"),
                                      _blk("ki", bd, nbd)), width=bd)
        dom_w = Domain.of(("ti", 0, nt), ("ji", 0, nbf), ("ki", 0, nbd),
                          ("r", 0, bd))
        acc_w = AccessPattern(dom_w, (Affine.table("ti", experts),
                                      _blk("ki", bd, nbd) + Affine.of("r"),
                                      _blk("ji", bf, nbf)), width=bf)
        dom_o = Domain.of(("ti", 0, nt), ("ji", 0, nbf), ("r", 0, bc))
        acc_o = AccessPattern(dom_o, (row0 + Affine.of("r"),
                                      _blk("ji", bf, nbf)), width=bf)

        def fn(in0, in1):
            x_ = in0.reshape(nt, nbf, nbd, bc, bd)
            w_ = in1.reshape(nt, nbf, nbd, bd, bf)
            return {"out0": (x_ @ w_).sum(axis=2).reshape(-1)}

        tile_fn = lambda in0, in1: {"out0": in0 @ in1[0]}   # noqa: E731
        g.compute("expert_tile", Domain.of(("ti", 0, nt), ("ji", 0, nbf),
                                           ("ki", 0, nbd)),
                  fn=fn, tile_fn=tile_fn, reduce="add",
                  vector_width=vector_width,
                  axes=dict(ins=({0: "c", 1: "k"}, {1: "k", 2: "f"}),
                            outs=({0: "c", 1: "f"},), carry=(), narrow="f"))
        g.connect("x", "expert_tile", acc_x)
        g.connect("w", "expert_tile", acc_w)
        g.connect("expert_tile", "o", acc_o)
        return g, est

    g = Graph("grouped_gemm")
    g.memory("x", (e, c, d), dtype=dtype)
    g.memory("w", (e, d, f), dtype=dtype)
    g.memory("o", (e, c, f), dtype=dtype)
    nbc = c // bc
    dom = Domain.of(("ei", 0, e), ("ii", 0, max(nbc, 1)),
                    ("ji", 0, max(nbf, 1)), ("ki", 0, max(nbd, 1)))
    if c % bc or f % bf or d % bd:
        acc_x = AccessPattern(dom, (Affine.of("ei"), Affine.of("ii", bc),
                                    Affine.of("ki", bd)))
        acc_w = AccessPattern(dom, (Affine.of("ei"), Affine.of("ki", bd),
                                    Affine.of("ji", bf)))
        acc_o = AccessPattern(dom, (Affine.of("ei"), Affine.of("ii", bc),
                                    Affine.of("ji", bf)))
        g.compute("expert_tile", dom, vector_width=vector_width)
        g.connect("x", "expert_tile", acc_x)
        g.connect("w", "expert_tile", acc_w)
        g.connect("expert_tile", "o", acc_o)
        return g, est

    dom_x = Domain.of(("ei", 0, e), ("ii", 0, nbc), ("ji", 0, nbf),
                      ("ki", 0, nbd), ("r", 0, bc))
    acc_x = AccessPattern(dom_x, (Affine.of("ei"),
                                  _blk("ii", bc, nbc) + Affine.of("r"),
                                  _blk("ki", bd, nbd)), width=bd)
    dom_w = Domain.of(("ei", 0, e), ("ii", 0, nbc), ("ji", 0, nbf),
                      ("ki", 0, nbd), ("r", 0, bd))
    acc_w = AccessPattern(dom_w, (Affine.of("ei"),
                                  _blk("ki", bd, nbd) + Affine.of("r"),
                                  _blk("ji", bf, nbf)), width=bf)
    dom_o = Domain.of(("ei", 0, e), ("ii", 0, nbc), ("ji", 0, nbf),
                      ("r", 0, bc))
    acc_o = AccessPattern(dom_o, (Affine.of("ei"),
                                  _blk("ii", bc, nbc) + Affine.of("r"),
                                  _blk("ji", bf, nbf)), width=bf)

    def fn(in0, in1):
        x_ = in0.reshape(e, nbc, nbf, nbd, bc, bd)
        w_ = in1.reshape(e, nbc, nbf, nbd, bd, bf)
        return {"out0": (x_ @ w_).sum(axis=3).reshape(-1)}

    tile_fn = lambda in0, in1: {"out0": in0 @ in1}   # noqa: E731
    g.compute("expert_tile", dom, fn=fn, tile_fn=tile_fn, reduce="add",
              vector_width=vector_width,
              axes=dict(ins=({1: "c", 2: "k"}, {1: "k", 2: "f"}),
                        outs=({1: "c", 2: "f"},), carry=(), narrow="f"))
    g.connect("x", "expert_tile", acc_x)
    g.connect("w", "expert_tile", acc_w)
    g.connect("expert_tile", "o", acc_o)
    return g, est


BUILDERS: Dict[str, Callable] = {
    "grouped_gemm": _grouped_gemm_graph,
    "vecadd": _vecadd_graph,
    "matmul": _matmul_graph,
    "stencil": _stencil_graph,
    "floyd_warshall": _floyd_graph,
    "flash_attention": _flash_graph,
    "ssd_scan": _ssd_graph,
    "decode_attention": _decode_attention_graph,
    "ssd_decode": _ssd_decode_graph,
}


def autopump(kernel: str, *args, mode: str = "T", max_factor: int = 16,
             vmem_budget: int = VMEM_BYTES, cache=None,
             backend: str = "none", autotune=None,
             **kwargs) -> AutopumpResult:
    """Run the full §3 pipeline for a registered kernel.

    1. build the dataflow IR; 2. drive the ``repro.compiler`` pass pipeline
    (streaming → stream-fusion → multipump with the capacity-model factor →
    FIFO sizing).  Falls back to M=1 (untransformed) when the legality checks
    reject — mirroring "the transformation can check for feasibility"
    semantics of data-centric transforms.  Pipeline decisions are memoized in
    the persistent compile cache (``cache=False`` disables), so repeated
    calls across benchmark/serve runs are O(1).

    ``backend`` defaults to ``'none'`` (plan only); pass ``'pallas'`` or
    ``'jax'`` to also lower the transformed graph (the executable lands in
    ``AutopumpResult.kernel``), and ``autotune='measure'`` to pick the pump
    factor from measured runtimes instead of the capacity model.
    """
    if kernel not in BUILDERS:
        raise KeyError(f"no IR builder for kernel {kernel!r}; "
                       f"known: {sorted(BUILDERS)}")
    g, est = BUILDERS[kernel](*args, **kwargs)

    # imported lazily: repro.compiler depends on repro.core's submodules
    from repro import compiler

    kern = compiler.compile(g, factor="auto", mode=mode,
                            vmem_budget=vmem_budget, max_factor=max_factor,
                            estimate=est, backend=backend, cache=cache,
                            autotune=autotune)
    report = kern.report
    srec = report.record("streaming")
    prec = report.record("multipump")
    from .streaming import StreamingReport
    s_report = srec.report if srec is not None and srec.report is not None \
        else StreamingReport()
    p_report = prec.report if prec is not None and prec.applied else None
    return AutopumpResult(kern.spec, kern.graph, s_report, p_report, est,
                          pipeline_report=report,
                          kernel=kern if backend != "none" else None)
