"""Automatic multi-pumping: the paper's end-to-end workflow as one call.

The paper's §3 pipeline is: program → dataflow IR → streaming pass →
(greedy largest-subgraph) multi-pump transform → codegen.  This module is
that pipeline for our kernel library: each registered kernel carries an IR
*builder* describing its data movement; :func:`autopump` runs the passes,
checks legality, consults the capacity model for the factor, and returns
both the transformed graph (for inspection/reporting) and the
:class:`~repro.core.ir.PumpSpec` the Pallas layer consumes.

    spec, report = autopump("matmul", m=4096, n=4096, k=4096)
    out = kernels.matmul(a, b, pump=spec)

This is the "automatic application" contribution: the user never chooses M
or identifies the streamable subgraph by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .ir import Graph, PumpSpec
from .multipump import PumpReport
from .pump_plan import KernelEstimate, VMEM_BYTES
from .symbolic import AccessPattern, Affine, Domain


@dataclasses.dataclass
class AutopumpResult:
    spec: PumpSpec
    graph: Graph                 # transformed IR (streamed + pumped)
    streaming_report: object
    pump_report: Optional[PumpReport]
    estimate: KernelEstimate
    pipeline_report: object = None   # repro.compiler PipelineReport
    kernel: object = None            # CompiledKernel when backend != 'none'

    def summary(self) -> str:
        r = self.graph.resources()
        return (f"M={self.spec.factor} mode={self.spec.mode} "
                f"units={r['compute_units']} adapters={r['adapters']} "
                f"modeled_tp={self.estimate.throughput(self.spec.factor):.3g}/s")


def _xp(a):
    """numpy/jax dispatch for fn bodies that need library calls (not just
    operators).  jax.numpy is imported lazily so repro.core stays jax-free
    for reference-executor users; numpy arrays keep numpy semantics."""
    if isinstance(a, np.ndarray):
        return np
    import jax.numpy as jnp
    return jnp


# ------------------------------------------------------------ IR builders --
# fn bodies are numpy/jax polymorphic (operator-based) so the same body runs
# in the reference executor and in the compiler's lowering backends.  The
# optional meta['tile_fn'] is the *per-grid-step* form consumed by the Pallas
# emission backend: it maps operand blocks (shaped per the blocked view of
# the access pattern) to one output block, while fn maps whole FIFO
# sequences.  meta['reduce']='add' marks tile_fn outputs as partial
# contributions accumulated over grid dims absent from the output access.
def _vecadd_graph(n: int, vector_width: int = 8, itemsize: int = 4):
    v = vector_width
    g = Graph("vecadd")
    g.memory("x", (n,))
    g.memory("y", (n,))
    g.memory("z", (n,))
    dom = Domain.of(("i", 0, max(n // v, 1)))
    acc = AccessPattern(dom, (Affine.of("i", v),), width=v)
    add = lambda in0, in1: {"out0": in0 + in1}   # noqa: E731 - elementwise
    g.compute("add", dom, fn=add, vector_width=v, tile_fn=add)
    g.connect("x", "add", acc)
    g.connect("y", "add", acc)
    g.connect("add", "z", acc)
    est = KernelEstimate(block_bytes_in=2 * v * itemsize,
                         block_bytes_out=v * itemsize,
                         flops_per_block=float(v))
    return g, est


def _matmul_graph(m: int, n: int, k: int, bm: int = 128, bn: int = 128,
                  bk: int = 128, itemsize: int = 4,
                  vector_width: Optional[int] = None):
    g = Graph("matmul")
    g.memory("a", (m, k))
    g.memory("b", (k, n))
    g.memory("c", (m, n))
    dom = Domain.of(("i", 0, max(m // bm, 1)), ("j", 0, max(n // bn, 1)),
                    ("kk", 0, max(k // bk, 1)))
    fn = None
    if m % bm == 0 and n % bn == 0 and k % bk == 0:
        # Executable form: access patterns walk full (row-contiguous) operand
        # panels per block point, so the FIFO sequences carry all the data
        # and the compute body is a real blocked matmul.
        nbm, nbn, nbk = m // bm, n // bn, k // bk
        dom_a = Domain.of(("i", 0, nbm), ("j", 0, nbn), ("kk", 0, nbk),
                          ("r", 0, bm))
        acc_a = AccessPattern(
            dom_a, (Affine.of("i", bm) + Affine.of("r"), Affine.of("kk", bk)),
            width=bk)
        dom_b = Domain.of(("i", 0, nbm), ("j", 0, nbn), ("kk", 0, nbk),
                          ("r", 0, bk))
        acc_b = AccessPattern(
            dom_b, (Affine.of("kk", bk) + Affine.of("r"), Affine.of("j", bn)),
            width=bn)
        dom_c = Domain.of(("i", 0, nbm), ("j", 0, nbn), ("r", 0, bm))
        acc_c = AccessPattern(
            dom_c, (Affine.of("i", bm) + Affine.of("r"), Affine.of("j", bn)),
            width=bn)

        def fn(in0, in1):
            a = in0.reshape(nbm, nbn, nbk, bm, bk)
            b = in1.reshape(nbm, nbn, nbk, bk, bn)
            return {"out0": (a @ b).sum(axis=2).reshape(-1)}

        # per-tile form: one MXU panel product, accumulated over the kk
        # grid dimension (absent from the output access) by the backend
        tile_fn = lambda in0, in1: {"out0": in0 @ in1}   # noqa: E731
    else:
        # Fallback (non-divisible shapes): corner-sampled transaction
        # schedule — enough for planning/legality, not executable.
        acc_a = AccessPattern(dom, (Affine.of("i", bm), Affine.of("kk", bk)),
                              width=1)
        acc_b = AccessPattern(dom, (Affine.of("kk", bk), Affine.of("j", bn)),
                              width=1)
        acc_c = AccessPattern(dom, (Affine.of("i", bm), Affine.of("j", bn)),
                              width=1)
        tile_fn = None
    if vector_width is None:
        vector_width = bm * bn // (128 * 128) or 1
    g.compute("mxu_tile", dom, fn=fn, vector_width=vector_width,
              tile_fn=tile_fn, reduce="add")
    g.connect("a", "mxu_tile", acc_a)
    g.connect("b", "mxu_tile", acc_b)
    g.connect("mxu_tile", "c", acc_c)
    est = KernelEstimate(block_bytes_in=(bm * bk + bk * bn) * itemsize,
                         block_bytes_out=0.0,
                         flops_per_block=2.0 * bm * bn * bk)
    return g, est


def _stencil_graph(d0: int, d1: int, d2: int, itemsize: int = 4,
                   coef: float = 0.25):
    """Plane-sweep Jacobi update along axis 0: each interior plane i+1 of
    ``y`` is rebuilt from the three-plane halo window x[i:i+3]; boundary
    planes keep the output memory's initial contents (zeros)."""
    g = Graph("stencil")
    g.memory("x", (d0, d1, d2))
    g.memory("y", (d0, d1, d2))
    ni = max(d0 - 2, 1)
    dom = Domain.of(("i", 0, ni))
    # overlapping halo reads: plane window [i, i+3); interior-plane writes
    acc_in = AccessPattern(dom, (Affine.of("i"), Affine.constant(0),
                                 Affine.constant(0)), width=3 * d1 * d2)
    acc_out = AccessPattern(dom, (Affine.of("i") + 1, Affine.constant(0),
                                  Affine.constant(0)), width=d1 * d2)

    def tile_fn(in0):
        # one halo window (3, d1', d2') -> one interior plane (1, d1', d2');
        # shape-polymorphic in the trailing dims (mode R narrows them)
        return {"out0": coef * (in0[0:1] + in0[2:3])
                + (1.0 - 2.0 * coef) * in0[1:2]}

    def fn(in0):
        w = in0.reshape(-1, 3, d1, d2)
        out = coef * (w[:, 0] + w[:, 2]) + (1.0 - 2.0 * coef) * w[:, 1]
        return {"out0": out.reshape(-1)}

    g.compute("plane_update", dom, fn=fn, tile_fn=tile_fn,
              vector_width=max(d1 * d2 // 128, 4))
    g.connect("x", "plane_update", acc_in)
    g.connect("plane_update", "y", acc_out)
    est = KernelEstimate(block_bytes_in=3 * d1 * d2 * itemsize,
                         block_bytes_out=d1 * d2 * itemsize,
                         flops_per_block=7.0 * d1 * d2)
    return g, est


def _floyd_graph(n: int, itemsize: int = 4):
    """All-pairs shortest paths.  The k-relaxation carries a loop-borne
    dependency through the whole matrix, so the IR models one compute whose
    fn runs the full pivot loop; the access pattern streams the matrix
    row-by-row (duplicate-free, so the graph is lowerable)."""
    g = Graph("floyd_warshall")
    g.memory("dist", (n, n))
    g.memory("out", (n, n))
    dom = Domain.of(("r", 0, n))
    acc = AccessPattern(dom, (Affine.of("r"), Affine.constant(0)), width=n)

    def fn(in0):
        xp = _xp(in0)
        d = in0.reshape(n, n)
        for k in range(n):
            d = xp.minimum(d, d[:, k:k + 1] + d[k:k + 1, :])
        return {"out0": d.reshape(-1)}

    g.compute("relax", dom, fn=fn, vector_width=max(n // 128, 4),
              data_dependent_io=False)
    g.connect("dist", "relax", acc)
    g.connect("relax", "out", acc)
    est = KernelEstimate(block_bytes_in=2 * n * itemsize,   # pivot row+col
                         block_bytes_out=0.0,
                         flops_per_block=2.0 * n * n)
    return g, est


def _flash_graph(b: int, h: int, s: int, t: int, d: int, bq: int = 128,
                 bkv: int = 128, itemsize: int = 2):
    g = Graph("flash_attention")
    g.memory("kv", (t, 2 * d))
    g.memory("o", (s, d))
    dom = Domain.of(("j", 0, max(t // bkv, 1)))
    acc = AccessPattern(dom, (Affine.of("j", bkv), Affine.constant(0)),
                        width=bkv)
    g.compute("online_softmax", dom, vector_width=bq * d // 128 or 1)
    g.connect("kv", "online_softmax", acc)
    out_dom = Domain.of(("j", 0, 1))
    g.connect("online_softmax", "o",
              AccessPattern(out_dom, (Affine.constant(0),
                                      Affine.constant(0)), width=bq))
    est = KernelEstimate(block_bytes_in=2 * bkv * d * itemsize,
                         block_bytes_out=0.0,
                         flops_per_block=4.0 * bq * bkv * d)
    return g, est


def _ssd_graph(b: int, l: int, h: int, p: int, n: int, chunk: int = 64,
               itemsize: int = 2):
    g = Graph("ssd_scan")
    g.memory("xs", (l, p))
    g.memory("ys", (l, p))
    dom = Domain.of(("c", 0, max(l // chunk, 1)))
    acc = AccessPattern(dom, (Affine.of("c", chunk), Affine.constant(0)),
                        width=chunk)
    g.compute("chunk_update", dom, vector_width=chunk * p // 128 or 1)
    g.connect("xs", "chunk_update", acc)
    g.connect("chunk_update", "ys", acc)
    est = KernelEstimate(block_bytes_in=chunk * (p + 1 + 2 * n) * itemsize,
                         block_bytes_out=chunk * p * itemsize,
                         flops_per_block=2.0 * chunk * chunk * (n + p))
    return g, est


def _grouped_gemm_graph(e: int, c: int, d: int, f: int, bc: int = 128,
                        bf: int = 128, bd: int = 128, itemsize: int = 2):
    g = Graph("grouped_gemm")
    g.memory("x", (e, c, d))
    g.memory("w", (e, d, f))
    g.memory("o", (e, c, f))
    dom = Domain.of(("e", 0, e), ("i", 0, max(c // bc, 1)),
                    ("j", 0, max(f // bf, 1)), ("k", 0, max(d // bd, 1)))
    acc_x = AccessPattern(dom, (Affine.of("e"), Affine.of("i", bc),
                                Affine.of("k", bd)))
    acc_w = AccessPattern(dom, (Affine.of("e"), Affine.of("k", bd),
                                Affine.of("j", bf)))
    acc_o = AccessPattern(dom, (Affine.of("e"), Affine.of("i", bc),
                                Affine.of("j", bf)))
    g.compute("expert_tile", dom, vector_width=bc * bf // (128 * 128) or 1)
    g.connect("x", "expert_tile", acc_x)
    g.connect("w", "expert_tile", acc_w)
    g.connect("expert_tile", "o", acc_o)
    est = KernelEstimate(block_bytes_in=(bc * bd + bd * bf) * itemsize,
                         block_bytes_out=0.0,
                         flops_per_block=2.0 * bc * bf * bd)
    return g, est


BUILDERS: Dict[str, Callable] = {
    "grouped_gemm": _grouped_gemm_graph,
    "vecadd": _vecadd_graph,
    "matmul": _matmul_graph,
    "stencil": _stencil_graph,
    "floyd_warshall": _floyd_graph,
    "flash_attention": _flash_graph,
    "ssd_scan": _ssd_graph,
}


def autopump(kernel: str, *args, mode: str = "T", max_factor: int = 16,
             vmem_budget: int = VMEM_BYTES, cache=None,
             backend: str = "none", autotune=None,
             **kwargs) -> AutopumpResult:
    """Run the full §3 pipeline for a registered kernel.

    1. build the dataflow IR; 2. drive the ``repro.compiler`` pass pipeline
    (streaming → stream-fusion → multipump with the capacity-model factor →
    FIFO sizing).  Falls back to M=1 (untransformed) when the legality checks
    reject — mirroring "the transformation can check for feasibility"
    semantics of data-centric transforms.  Pipeline decisions are memoized in
    the persistent compile cache (``cache=False`` disables), so repeated
    calls across benchmark/serve runs are O(1).

    ``backend`` defaults to ``'none'`` (plan only); pass ``'pallas'`` or
    ``'jax'`` to also lower the transformed graph (the executable lands in
    ``AutopumpResult.kernel``), and ``autotune='measure'`` to pick the pump
    factor from measured runtimes instead of the capacity model.
    """
    if kernel not in BUILDERS:
        raise KeyError(f"no IR builder for kernel {kernel!r}; "
                       f"known: {sorted(BUILDERS)}")
    g, est = BUILDERS[kernel](*args, **kwargs)

    # imported lazily: repro.compiler depends on repro.core's submodules
    from repro import compiler

    kern = compiler.compile(g, factor="auto", mode=mode,
                            vmem_budget=vmem_budget, max_factor=max_factor,
                            estimate=est, backend=backend, cache=cache,
                            autotune=autotune)
    report = kern.report
    srec = report.record("streaming")
    prec = report.record("multipump")
    from .streaming import StreamingReport
    s_report = srec.report if srec is not None and srec.report is not None \
        else StreamingReport()
    p_report = prec.report if prec is not None and prec.applied else None
    return AutopumpResult(kern.spec, kern.graph, s_report, p_report, est,
                          pipeline_report=report,
                          kernel=kern if backend != "none" else None)
