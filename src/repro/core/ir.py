"""Dataflow IR for temporal vectorization.

A deliberately small data-centric graph IR in the spirit of DaCe SDFGs
(paper §3.1): nodes are *data containers* (random-access ``Memory`` or FIFO
``Stream``) and *modules* (``Compute``, ``Reader``, ``Writer`` plus the
multi-pumping adapter modules ``Sync``/``Issuer``/``Packer``); edges carry
symbolic :class:`~repro.core.symbolic.AccessPattern` descriptions of all data
movement.  The two transformation passes (``streaming.py``, ``multipump.py``)
are graph-rewriting rules over this IR, and the kernel layer consumes the
rewritten graph as a :class:`PumpSpec` when constructing Pallas BlockSpecs.

Rate domains replace the paper's clock domains: ``SLOW`` is the wide/long-path
domain (HBM DMA, ICI collectives), ``FAST`` the multi-pumped compute domain.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from .symbolic import AccessPattern, Domain


class Space(enum.Enum):
    HBM = "hbm"      # long data path: off-chip memory
    VMEM = "vmem"    # on-chip scratch (BRAM analogue)
    STREAM = "stream"


class RateDomain(enum.Enum):
    SLOW = "slow"   # clk0: readers/writers, long paths
    FAST = "fast"   # clk1 = M * clk0: multi-pumped compute


class NodeKind(enum.Enum):
    MEMORY = "memory"
    STREAM = "stream"
    COMPUTE = "compute"
    READER = "reader"
    WRITER = "writer"
    SYNC = "sync"       # clock-domain crossing (Pallas pipeline boundary)
    ISSUER = "issuer"   # 1 wide transaction -> M narrow transactions
    PACKER = "packer"   # M narrow transactions -> 1 wide transaction


@dataclasses.dataclass(frozen=True)
class CarrySpec:
    """Sequential-carry (associative/online-scan) description of a compute.

    A compute carrying state across one domain axis — flash attention's
    running (max, denominator, accumulator) over KV blocks, the SSD scan's
    inter-chunk state — cannot be expressed as a pure map/reduce ``fn``.
    Instead the node declares:

    ``axis``      the domain symbol swept sequentially (must be the *last*
                  symbol of the compute's step domain: lexicographic walk
                  order makes each sweep contiguous)
    ``state``     tuple of ``(shape, dtype[, fill])`` per loop-carried
                  array; each sweep of the carry axis starts from
                  ``full(shape, fill)`` (fill defaults to 0 — flash
                  attention's running max uses ``-inf``-like fills)
    ``step_fn``   ``(carry, *in_blocks[, idx=...]) -> (carry', outs|None)``
                  one sequential step; operands arrive as block-shaped
                  arrays (the blocked view of each access pattern) and
                  ``outs`` is a ``{"out0": block, ...}`` dict for kernels
                  that emit per step (SSD), or None
    ``final_fn``  ``carry -> {"out<k>": block, ...}`` — emitted once per
                  sweep after the last step, for kernels whose outputs are a
                  function of the final state (flash attention's tile plus
                  its max/denominator, the SSD scan's final inter-chunk
                  state).  Output edges are partitioned by ``step_outs``:
                  the first ``step_outs`` node outputs come from ``step_fn``
                  every step and the remaining outputs come from
                  ``final_fn`` once per sweep (keyed by their *absolute*
                  edge position, e.g. ``{"out1": ...}`` when ``step_outs``
                  is 1).  ``step_outs=0`` (the default) with a ``final_fn``
                  means all outputs are per-sweep; without a ``final_fn``
                  all outputs come from ``step_fn`` regardless.
    ``step_outs`` number of leading per-step outputs when ``final_fn`` is
                  set (ignored otherwise — see above)
    ``pass_idx``  pass ``idx=dict(step=<position along the carry sweep>,
                  outer=<coords of the non-carry step symbols>,
                  pump=<mode-R sub-tile index, 0 elsewhere>)`` to both fns
                  (causal masks and other position-dependent bodies)

    Multi-pumping legality is unchanged — a sequential carry is exactly the
    dependency pattern temporal vectorization tolerates (paper §2): mode T
    runs M dependent steps per wide transaction; the state never leaves the
    fast domain.
    """

    axis: str
    state: Tuple[Tuple, ...]          # (shape, dtype[, fill]) per array
    step_fn: Callable
    final_fn: Optional[Callable] = None
    pass_idx: bool = False
    step_outs: int = 0                # leading per-step outputs with final_fn

    def n_step_outs(self, n_out: int) -> int:
        """How many of the node's ``n_out`` outputs come from ``step_fn``."""
        return n_out if self.final_fn is None else self.step_outs

    def init_arrays(self, xp=np,
                    narrow: "Optional[Dict[int, Tuple[int, int]]]" = None):
        """Fresh per-sweep state arrays; ``narrow`` maps state-array index →
        (dim, factor): mode-R narrowing of the labelled state dimension."""
        out = []
        for i, entry in enumerate(self.state):
            shape, dtype = entry[0], entry[1]
            fill = entry[2] if len(entry) > 2 else 0.0
            if narrow and i in narrow:
                d, factor = narrow[i]
                shape = tuple(s // factor if j == d else s
                              for j, s in enumerate(shape))
            out.append(xp.full(shape, fill, dtype=dtype))
        return tuple(out)

    def signature(self) -> Tuple:
        """Stable identity for cache/memo keys (no object ids)."""
        return ("carry", self.axis, self.state, bool(self.final_fn),
                self.pass_idx, self.step_outs)


@dataclasses.dataclass
class Node:
    name: str
    kind: NodeKind
    # containers
    shape: Tuple[int, ...] = ()
    dtype: str = "float32"
    space: Space = Space.HBM
    # streams
    elem_width: int = 1            # elements per transaction
    depth: int = 2                 # FIFO depth
    # modules
    domain: Optional[Domain] = None
    vector_width: int = 1          # spatial vectorization V (replicated units)
    rate: RateDomain = RateDomain.SLOW
    pump: int = 1                  # temporal multiplicity M (FAST domain only)
    fn: Optional[Callable] = None  # python/jnp body, used by the executor
    data_dependent_io: bool = False  # forbids multi-pumping (paper §3.2)
    meta: Dict = dataclasses.field(default_factory=dict)

    def bytes_per_elem(self) -> int:
        return np.dtype(self.dtype).itemsize

    def footprint_bytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n * self.bytes_per_elem()


@dataclasses.dataclass
class Edge:
    src: str
    dst: str
    access: Optional[AccessPattern] = None  # None for pure stream hops
    volume: int = 0                         # elements moved over edge lifetime

    def key(self) -> Tuple[str, str]:
        return (self.src, self.dst)


class Graph:
    """A flat dataflow graph with named nodes."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.edges: List[Edge] = []

    # -- construction ---------------------------------------------------------
    def add(self, node: Node) -> Node:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        self.nodes[node.name] = node
        return node

    def memory(self, name: str, shape, dtype="float32", space=Space.HBM) -> Node:
        return self.add(Node(name, NodeKind.MEMORY, shape=tuple(shape),
                             dtype=dtype, space=space))

    def stream(self, name: str, dtype="float32", elem_width=1, depth=2) -> Node:
        return self.add(Node(name, NodeKind.STREAM, dtype=dtype,
                             elem_width=elem_width, depth=depth,
                             space=Space.STREAM))

    def compute(self, name: str, domain: Domain, fn=None, vector_width=1,
                data_dependent_io=False, **meta) -> Node:
        return self.add(Node(name, NodeKind.COMPUTE, domain=domain, fn=fn,
                             vector_width=vector_width,
                             data_dependent_io=data_dependent_io, meta=meta))

    def connect(self, src: str, dst: str, access: AccessPattern | None = None,
                volume: int = 0) -> Edge:
        for end in (src, dst):
            if end not in self.nodes:
                raise ValueError(f"unknown node {end}")
        e = Edge(src, dst, access, volume)
        self.edges.append(e)
        return e

    # -- queries ---------------------------------------------------------------
    def in_edges(self, name: str) -> List[Edge]:
        return [e for e in self.edges if e.dst == name]

    def out_edges(self, name: str) -> List[Edge]:
        return [e for e in self.edges if e.src == name]

    def modules(self) -> List[Node]:
        return [n for n in self.nodes.values()
                if n.kind not in (NodeKind.MEMORY, NodeKind.STREAM)]

    def computes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.kind == NodeKind.COMPUTE]

    def streams(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.kind == NodeKind.STREAM]

    def validate(self) -> None:
        for e in self.edges:
            src, dst = self.nodes[e.src], self.nodes[e.dst]
            if src.kind == NodeKind.MEMORY and dst.kind == NodeKind.MEMORY:
                raise ValueError(f"memory->memory edge {e.key()}")
            if src.kind == NodeKind.STREAM and dst.kind == NodeKind.STREAM:
                raise ValueError(f"stream->stream edge {e.key()}")
        # every stream has exactly one producer and one consumer
        for s in self.streams():
            if len(self.in_edges(s.name)) != 1 or len(self.out_edges(s.name)) != 1:
                raise ValueError(f"stream {s.name} must have 1 producer, 1 consumer")

    def copy(self) -> "Graph":
        g = Graph(self.name)
        g.nodes = {k: dataclasses.replace(v, meta=dict(v.meta))
                   for k, v in self.nodes.items()}
        g.edges = [dataclasses.replace(e) for e in self.edges]
        return g

    # -- resource model ----------------------------------------------------------
    def resources(self) -> Dict[str, float]:
        """TPU analogue of the paper's DSP/BRAM/LUT report.

        compute_units : Σ spatial vector widths of compute modules (DSP analogue)
        vmem_bytes    : Σ VMEM container footprints (BRAM analogue)
        adapters      : count of sync/issuer/packer modules (LUT/reg overhead)
        stream_bytes  : Σ FIFO buffer footprints
        """
        cu = sum(n.vector_width for n in self.computes())
        vmem = sum(n.footprint_bytes() for n in self.nodes.values()
                   if n.kind == NodeKind.MEMORY and n.space == Space.VMEM)
        adapters = sum(1 for n in self.nodes.values()
                       if n.kind in (NodeKind.SYNC, NodeKind.ISSUER, NodeKind.PACKER))
        stream_bytes = sum(s.elem_width * s.depth * s.bytes_per_elem()
                           for s in self.streams())
        return dict(compute_units=cu, vmem_bytes=vmem, adapters=adapters,
                    stream_bytes=stream_bytes)

    def __repr__(self) -> str:  # pragma: no cover
        lines = [f"Graph({self.name})"]
        for n in self.nodes.values():
            extra = ""
            if n.kind == NodeKind.COMPUTE:
                extra = f" V={n.vector_width} rate={n.rate.value} M={n.pump}"
            if n.kind == NodeKind.STREAM:
                extra = f" w={n.elem_width}"
            lines.append(f"  [{n.kind.value:7s}] {n.name}{extra}")
        for e in self.edges:
            lines.append(f"  {e.src} -> {e.dst}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class PumpSpec:
    """The artifact the IR passes hand to the kernel layer.

    ``factor``     pump factor M (1 = not pumped)
    ``mode``       'T' widen external paths, keep compute width (throughput)
                   'R' keep external width, narrow compute by M (resource)
    ``axis``       which block axis carries the temporal dimension
    ``vmem_budget``bytes available for the widened working set
    """

    factor: int = 1
    mode: str = "T"
    axis: int = 0
    vmem_budget: int = 64 * 1024 * 1024

    def __post_init__(self):
        if self.mode not in ("T", "R"):
            raise ValueError(f"mode must be T or R, got {self.mode}")
        if self.factor < 1:
            raise ValueError("pump factor must be >= 1")

    @property
    def is_pumped(self) -> bool:
        return self.factor > 1


def effective_rate(clk0: float, clk1: float, pump: int) -> float:
    """Paper §2.1: rate_eff = min(clk0, clk1 / M).

    On TPU ``clk0`` is the wide-transaction (DMA/collective) issue rate and
    ``clk1`` the compute-iteration rate; the law is unchanged.
    """
    if pump <= 1:
        return min(clk0, clk1)
    return min(clk0, clk1 / pump)
