"""Attention variants: GQA (qk-norm / qkv-bias options) and DeepSeek MLA.

Two execution paths, selected by ``cfg.attention_impl``:

  - ``xla_chunked``: a pure-jnp flash-style attention — ``lax.scan`` over KV
    blocks with an online-softmax carry.  This *is* temporal vectorization in
    XLA form: the KV stream is consumed in wide blocks while the softmax
    dependency chain stays sequential.  Memory is O(S·block), so 32k prefill
    lowers without materializing S×S logits.  Differentiable; used by the
    dry-run and trainer.
  - ``pallas``: the :mod:`repro.kernels.flash_attention` kernel (interpret
    mode on CPU) — used by smoke tests at small sizes and the TPU target.

Decode attends one query token against a preallocated KV cache.  Under
``attention_impl='pallas'`` + ``kernel_plan='measure'`` (the serving
default) the step routes through the compiled decode kernel — the plan
registry buckets the attended prefix on pos and replays the measured pump
plan — while the plain-jnp O(T) softmax stays as the ``'direct'``
differential reference.  MLA caches the *compressed* c_kv + rope key
(576 B/token for deepseek-v3) and uses the absorbed-matmul decode path.

Cache positions come in two shapes.  A scalar ``pos`` is the classic
one-batch-at-a-time engine: every row is at the same depth.  A **per-slot**
``pos`` vector ``(B,)`` is the continuous-batching engine
(:mod:`repro.serve.scheduler`): each cache row is an independent decode
lane at its own depth, so the single-token write mask, the KV validity
mask and the rope positions are all per-row.  The vector form is
decode-only (S == 1) — slot prefill always runs on a fresh scalar-pos
cache and is scattered into its lane afterwards.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers
from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


def _rope_positions(positions):
    """Broadcast shape for ``apply_rope`` over (B, H, S, D) heads: accepts
    the classic per-step ``(S,)`` vector or per-slot ``(B, S)`` ragged
    positions (continuous batching — each batch row at its own depth)."""
    return positions[None, :] if positions.ndim == 1 \
        else positions[:, None, :]


def _kv_valid_mask(length: int, pos, s: int):
    """Valid-slot mask for a cache of ``length`` after writing ``s`` tokens
    at ``pos``: ``(length,)`` for a scalar pos, ``(B, length)`` per-slot."""
    idx = jnp.arange(length)
    if jnp.ndim(pos):
        return idx[None, :] < (pos[:, None] + s)
    return idx < (pos + s)


def _flash_kernel(cfg, q, k, v, *, causal, interpret=True):
    """Flash-attention kernel dispatch for the ``pallas`` impl paths.

    ``cfg.kernel_plan == 'measure'`` (default) routes through the process
    plan registry: shapes pad to buckets and the pump factor replays the
    measured-runtime winner, so serving decode/prefill hits a warm plan in
    O(1).  ``'direct'`` keeps the raw ``kernels.ops`` call (default pump) —
    the differential reference for the registry path."""
    if cfg.kernel_plan == "measure":
        from repro.compiler.registry import default_registry
        return default_registry().flash_attention(q, k, v, causal=causal)
    from repro.kernels.ops import flash_attention as _flash
    return _flash(q, k, v, causal=causal, interpret=interpret)


# ------------------------------------------------------------ core attention
def chunked_attention(q, k, v, *, causal: bool, q_pos=None, kv_mask=None,
                      block: int = 1024, scale: float | None = None):
    """Flash-style attention via lax.scan over KV blocks.

    q: (B, H, S, D); k/v: (B, Hkv, T, Dk/Dv).  GQA folded by reshaping q into
    (B, Hkv, G, S, D).  Returns (B, H, S, Dv).
    """
    b, h, s, d = q.shape
    _, hkv, t, dk = k.shape
    dv = v.shape[-1]
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    block = min(block, t)
    nblk = -(-t // block)
    tpad = nblk * block

    if tpad != t:
        pad = [(0, 0), (0, 0), (0, tpad - t), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        base_mask = jnp.arange(tpad) < t
    else:
        base_mask = jnp.ones((tpad,), bool)
    if kv_mask is not None:
        base_mask = base_mask & jnp.pad(kv_mask, (0, tpad - t),
                                        constant_values=False)
    if q_pos is None:
        q_pos = jnp.arange(s)

    qg = q.reshape(b, hkv, g, s, d).astype(jnp.float32) * scale
    kb = k.reshape(b, hkv, nblk, block, dk).astype(jnp.float32)
    vb = v.reshape(b, hkv, nblk, block, dv).astype(jnp.float32)
    mb = base_mask.reshape(nblk, block)

    def step(carry, inputs):
        m_run, l_run, acc = carry
        kc, vc, mask_c, kpos = inputs          # (b,hkv,block,dk) ...
        sblk = jnp.einsum("bkgsd,bktd->bkgst", qg, kc)
        mask = mask_c[None, None, None, None, :]
        if causal:
            mask = mask & (q_pos[:, None] >= kpos[None, :])[None, None, None]
        sblk = jnp.where(mask, sblk, NEG_INF)
        m_new = jnp.maximum(m_run, sblk.max(axis=-1))
        p = jnp.exp(sblk - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgst,bktd->bkgsd", p, vc)
        return (m_new, l_new, acc), None

    kb_t = jnp.moveaxis(kb, 2, 0)              # (nblk, b, hkv, block, dk)
    vb_t = jnp.moveaxis(vb, 2, 0)
    kpos_t = jnp.arange(tpad).reshape(nblk, block)
    init = (jnp.full((b, hkv, g, s), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, s), jnp.float32),
            jnp.zeros((b, hkv, g, s, dv), jnp.float32))
    (m_run, l_run, acc), _ = jax.lax.scan(step, init, (kb_t, vb_t, mb, kpos_t))
    l_run = jnp.where(l_run == 0.0, 1.0, l_run)
    out = acc / l_run[..., None]
    return out.reshape(b, h, s, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_mask, *, scale=None):
    """Single-position attention. q: (B, H, D); caches: (B, Hkv, T, D)."""
    b, h, d = q.shape
    hkv, t = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k_cache.astype(jnp.float32))
    s = jnp.where(kv_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------- GQA module
def gqa_init(key, cfg, dtype=jnp.float32):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def gqa_apply(p, cfg, x, *, positions, causal=True, cache=None,
              kv_input=None, interpret=True):
    """GQA attention.  x: (B, S, d).  Returns (out, new_cache).

    ``kv_input`` (B, T, d) switches to cross-attention (no cache, no causal).
    ``cache``: dict(k, v, pos) for incremental decode (S == 1).
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    kv_src = kv_input if kv_input is not None else x
    t = kv_src.shape[1]

    q = dense(p["wq"], x).reshape(b, s, h, hd)
    k = dense(p["wk"], kv_src).reshape(b, t, hkv, hd)
    v = dense(p["wv"], kv_src).reshape(b, t, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if kv_input is None:  # self-attention: rope
        rp = _rope_positions(positions)
        q = apply_rope(q.swapaxes(1, 2), rp, cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), rp, cfg.rope_theta).swapaxes(1, 2)

    q = q.swapaxes(1, 2)   # (B, H, S, hd)
    k = k.swapaxes(1, 2)
    v = v.swapaxes(1, 2)

    new_cache = None
    if cache is not None:
        # write current kv at position, attend over the whole cache
        pos = cache["pos"]
        if s == 1:
            # mask-based single-token write: elementwise on the (possibly
            # sequence-sharded) cache, so GSPMD keeps it shard-local —
            # dynamic_update_slice at a traced offset forced one cache
            # shard through collectives per layer per token
            # (EXPERIMENTS.md §Perf E1).  A per-slot pos vector makes the
            # mask per-row: each decode lane writes at its own depth.
            idx = jnp.arange(cache["k"].shape[2])
            tmask = ((idx[None, :] == pos[:, None])[:, None, :, None]
                     if jnp.ndim(pos)
                     else (idx == pos)[None, None, :, None])
            kc = jnp.where(tmask, k.astype(cache["k"].dtype), cache["k"])
            vc = jnp.where(tmask, v.astype(cache["v"].dtype), cache["v"])
        else:
            if jnp.ndim(pos):
                raise ValueError(
                    "per-slot cache positions are decode-only (S == 1): "
                    "prefill runs on a fresh scalar-pos cache and is "
                    "scattered into its slot (serve.scheduler.insert_rows)")
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0))
        new_cache = {"k": kc, "v": vc, "pos": pos + s}
        kv_mask = _kv_valid_mask(kc.shape[2], pos, s)
        if s == 1:
            if cfg.attention_impl == "pallas" and cfg.kernel_plan == "measure":
                # kernelized decode: the plan registry buckets the attended
                # cache prefix (pow2 over pos) and replays the measured pump
                # plan; the kernel's position mask covers slots 0..pos —
                # exactly kv_mask for the just-written cache
                from repro.compiler.registry import default_registry
                out = default_registry().decode_attention(q[:, :, 0], kc, vc,
                                                          pos)
            else:
                out = decode_attention(
                    q[:, :, 0], kc, vc,
                    jnp.broadcast_to(kv_mask, (b, kc.shape[2])))
            out = out[:, :, None, :]
        elif cfg.attention_impl == "pallas" and cfg.fresh_prefill_kernel:
            # fresh-cache prefill (pos == 0 — the flag's contract, set by
            # the serve Engine whose prefill always builds a new cache):
            # attention over the just-written cache under kv_mask equals
            # causal attention over the current tokens' k/v, which the
            # plan-registry kernel serves from a warm measured plan.  The
            # kernel's causal mask is position-relative, so the contract is
            # enforced at runtime: a pos > 0 continuation (traced pos —
            # unknowable here) selects the position-aware chunked branch.
            out = jax.lax.cond(
                pos == 0,
                lambda: _flash_kernel(cfg, q, k, v, causal=causal,
                                      interpret=interpret),
                lambda: chunked_attention(q, kc, vc, causal=causal,
                                          q_pos=positions, kv_mask=kv_mask,
                                          block=cfg.attn_block_kv))
        else:
            # prefill into the cache (assumes contiguous fill from `pos`)
            out = chunked_attention(q, kc, vc, causal=causal,
                                    q_pos=positions, kv_mask=kv_mask,
                                    block=cfg.attn_block_kv)
    elif cfg.attention_impl == "pallas" and kv_input is None:
        out = _flash_kernel(cfg, q, k, v, causal=causal, interpret=interpret)
    else:
        out = chunked_attention(q, k, v, causal=causal and kv_input is None,
                                q_pos=positions, block=cfg.attn_block_kv)
    out = out.swapaxes(1, 2).reshape(b, s, h * hd)
    return dense(p["wo"], out), new_cache


def gqa_cache_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                   per_slot_pos: bool = False):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    pos_shape = (batch,) if per_slot_pos else ()
    return {"k": jnp.zeros((batch, hkv, max_len, hd), dtype),
            "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
            "pos": jnp.zeros(pos_shape, jnp.int32)}


# --------------------------------------------------------------- MLA module
def mla_init(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv, kvr = m.nope_head_dim, m.rope_head_dim, m.v_head_dim, \
        m.kv_lora_rank
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, m.q_lora_rank, dtype=dtype)
        p["q_norm"] = rmsnorm_init(m.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], m.q_lora_rank, h * (dn + dr), dtype=dtype)
    else:
        p["wq"] = dense_init(ks[0], d, h * (dn + dr), dtype=dtype)
    p["wkv_a"] = dense_init(ks[2], d, kvr + dr, dtype=dtype)
    p["kv_norm"] = rmsnorm_init(kvr, dtype)
    p["wkv_b"] = dense_init(ks[3], kvr, h * (dn + dv), dtype=dtype)
    p["wo"] = dense_init(ks[4], h * dv, d, dtype=dtype)
    return p


def _mla_q(p, cfg, x):
    m = cfg.mla
    h, dn, dr = cfg.n_heads, m.nope_head_dim, m.rope_head_dim
    b, s, _ = x.shape
    if m.q_lora_rank:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x),
                                     cfg.norm_eps))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(b, s, h, dn + dr)
    return q[..., :dn], q[..., dn:]


def mla_apply(p, cfg, x, *, positions, causal=True, cache=None,
              interpret=True):
    """MLA attention.  Prefill/train: decompressed path + chunked flash.
    Decode: absorbed path over the compressed cache."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv, kvr = m.nope_head_dim, m.rope_head_dim, m.v_head_dim, \
        m.kv_lora_rank
    scale = (dn + dr) ** -0.5

    q_nope, q_rope = _mla_q(p, cfg, x)
    rp = _rope_positions(positions)
    q_rope = apply_rope(q_rope.swapaxes(1, 2), rp,
                        cfg.rope_theta).swapaxes(1, 2)

    kv_a = dense(p["wkv_a"], x)
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., :kvr], cfg.norm_eps)  # (B,S,kvr)
    k_rope = apply_rope(kv_a[..., None, kvr:].swapaxes(1, 2),
                        rp, cfg.rope_theta).swapaxes(1, 2)
    # k_rope: (B, S, 1, dr) shared over heads

    if cache is not None and s > 1:
        # prefill: write the compressed cache, attend over current tokens
        pos = cache["pos"]
        if jnp.ndim(pos):
            raise ValueError(
                "per-slot cache positions are decode-only (S == 1): "
                "prefill runs on a fresh scalar-pos cache and is "
                "scattered into its slot (serve.scheduler.insert_rows)")
        ckv_c = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        krope_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype),
            (0, pos, 0))
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c, "pos": pos + s}
        if cfg.prefill_continuation:
            # continuation chunk (pos > 0): the current tokens must attend
            # over the WHOLE written cache, not just this chunk — the
            # compressed prefix is decompressed through wkv_b (same math
            # as decode's absorbed path, unabsorbed) and masked to the
            # valid pos + s slots.  At pos == 0 the mask reduces this to
            # the chunk-local computation below.
            t = ckv_c.shape[1]
            kv = dense(p["wkv_b"], ckv_c.astype(x.dtype)) \
                .reshape(b, t, h, dn + dv)
            k_nope, v = kv[..., :dn], kv[..., dn:]
            k = jnp.concatenate(
                [k_nope,
                 jnp.broadcast_to(krope_c[:, :, None, :].astype(x.dtype),
                                  (b, t, h, dr))], axis=-1)
            q = jnp.concatenate([q_nope, q_rope], axis=-1)
            q, k, v = (u.swapaxes(1, 2) for u in (q, k, v))
            out = chunked_attention(q, k, v, causal=causal,
                                    q_pos=positions,
                                    kv_mask=_kv_valid_mask(t, pos, s),
                                    block=cfg.attn_block_kv, scale=scale)
            out = out.swapaxes(1, 2).reshape(b, s, h * dv)
            return dense(p["wo"], out), new_cache
        kv = dense(p["wkv_b"], c_kv).reshape(b, s, h, dn + dv)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q, k, v = (u.swapaxes(1, 2) for u in (q, k, v))
        if cfg.attention_impl == "pallas" and dn + dr == dv \
                and cfg.fresh_prefill_kernel:
            # fresh-cache serving prefill: the registry kernel replaces
            # chunked attention over the current tokens; the runtime cond
            # keeps any pos > 0 continuation on the reference chunked path
            out = jax.lax.cond(
                pos == 0,
                lambda: _flash_kernel(cfg, q, k, v, causal=causal,
                                      interpret=interpret),
                lambda: chunked_attention(q, k, v, causal=causal,
                                          q_pos=positions,
                                          block=cfg.attn_block_kv,
                                          scale=scale))
        else:
            out = chunked_attention(q, k, v, causal=causal, q_pos=positions,
                                    block=cfg.attn_block_kv, scale=scale)
        out = out.swapaxes(1, 2).reshape(b, s, h * dv)
        return dense(p["wo"], out), new_cache

    if cache is not None:
        pos = cache["pos"]
        if jnp.ndim(pos):
            # per-slot decode lanes: mask-based write at each row's depth
            wm = (jnp.arange(cache["c_kv"].shape[1])[None, :]
                  == pos[:, None])[:, :, None]            # (B, T, 1)
            ckv_c = jnp.where(wm, c_kv.astype(cache["c_kv"].dtype),
                              cache["c_kv"])
            krope_c = jnp.where(
                wm, k_rope[:, :, 0].astype(cache["k_rope"].dtype),
                cache["k_rope"])
        else:
            ckv_c = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
            krope_c = jax.lax.dynamic_update_slice(
                cache["k_rope"],
                k_rope[:, :, 0].astype(cache["k_rope"].dtype), (0, pos, 0))
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c, "pos": pos + s}
        t = ckv_c.shape[1]
        kv_mask = _kv_valid_mask(t, pos, s)
        # absorbed decode: w_uk (kvr, h, dn), w_uv (kvr, h, dv).
        # All cache-touching einsums run on the NATIVE (bf16) cache with
        # fp32 accumulation (preferred_element_type) — materializing an
        # fp32 copy of the compressed cache doubled decode HBM traffic
        # (EXPERIMENTS.md §Perf B2).
        wkv_b = p["wkv_b"]["w"].reshape(kvr, h, dn + dv)
        w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
        q_abs = jnp.einsum("bhd,khd->bhk", q_nope[:, 0], w_uk,
                           preferred_element_type=jnp.float32)  # (B,H,kvr)
        sc = jnp.einsum("bhk,btk->bht", q_abs.astype(ckv_c.dtype), ckv_c,
                        preferred_element_type=jnp.float32)
        sc += jnp.einsum("bhr,btr->bht", q_rope[:, 0].astype(krope_c.dtype),
                         krope_c, preferred_element_type=jnp.float32)
        sc = jnp.where(kv_mask[:, None, :] if kv_mask.ndim == 2
                       else kv_mask[None, None, :], sc * scale, NEG_INF)
        attn = jax.nn.softmax(sc, axis=-1)
        out_c = jnp.einsum("bht,btk->bhk", attn.astype(ckv_c.dtype), ckv_c,
                           preferred_element_type=jnp.float32)
        out = jnp.einsum("bhk,khd->bhd", out_c.astype(w_uv.dtype), w_uv,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, 1, h * dv).astype(x.dtype)
        return dense(p["wo"], out), new_cache

    # prefill / train: decompress and run standard attention
    kv = dense(p["wkv_b"], c_kv).reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q, k, v = (u.swapaxes(1, 2) for u in (q, k, v))
    if cfg.attention_impl == "pallas" and dn + dr == dv:
        out = _flash_kernel(cfg, q, k, v, causal=causal, interpret=interpret)
    else:
        out = chunked_attention(q, k, v, causal=causal, q_pos=positions,
                                block=cfg.attn_block_kv, scale=scale)
    out = out.swapaxes(1, 2).reshape(b, s, h * dv)
    return dense(p["wo"], out), None


def mla_cache_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                   per_slot_pos: bool = False):
    m = cfg.mla
    pos_shape = (batch,) if per_slot_pos else ()
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
            "pos": jnp.zeros(pos_shape, jnp.int32)}
