"""Decoder-only LM assembly: dense / MoE / SSM / hybrid families.

Layers are scanned (``jax.lax.scan`` over stacked per-layer params) so the
HLO is O(1) in depth — a 61-layer MoE lowers as fast as a 2-layer toy — and
rematerialization (``jax.checkpoint``) is applied per block when
``cfg.remat``.  Heterogeneous stacks are segmented:

  dense / vlm : one scanned segment of (attn + SwiGLU) blocks
  moe         : ``n_dense_layers`` scanned dense blocks, then scanned
                (attn + MoE) blocks; router aux losses accumulate in carry
  ssm         : scanned Mamba-2 blocks
  hybrid      : scanned groups of Mamba-2 blocks with one *shared*
                (attn + SwiGLU) block applied between groups (zamba2-style;
                the shared block's weights are a single copy)

Public API: ``init_params``, ``forward`` (tokens → logits, plus aux loss),
``loss_fn``, ``init_cache``, ``decode_step``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import layers, moe as moe_mod, ssm as ssm_mod
from .layers import (cross_entropy, dense, embed, embedding_init, rmsnorm,
                     rmsnorm_init, swiglu, swiglu_init, unembed)


# ---------------------------------------------------------------- blocks ----
def _attn_init(key, cfg, dtype):
    return (attn_mod.mla_init(key, cfg, dtype) if cfg.mla
            else attn_mod.gqa_init(key, cfg, dtype))


def _attn_apply(p, cfg, x, positions, cache):
    if cfg.mla:
        return attn_mod.mla_apply(p, cfg, x, positions=positions, cache=cache)
    return attn_mod.gqa_apply(p, cfg, x, positions=positions, cache=cache)


def dense_block_init(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def dense_block_apply(p, cfg, x, positions, cache=None):
    h, new_cache = _attn_apply(p["attn"], cfg,
                               rmsnorm(p["norm1"], x, cfg.norm_eps),
                               positions, cache)
    x = x + h
    x = x + swiglu(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
    return x, jnp.zeros((), jnp.float32), new_cache


def moe_block_init(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "moe": moe_mod.moe_init(k2, cfg, dtype),
    }


def moe_block_apply(p, cfg, x, positions, cache=None):
    h, new_cache = _attn_apply(p["attn"], cfg,
                               rmsnorm(p["norm1"], x, cfg.norm_eps),
                               positions, cache)
    x = x + h
    y, aux = moe_mod.moe_apply(p["moe"], cfg,
                               rmsnorm(p["norm2"], x, cfg.norm_eps),
                               dropless=cache is not None)
    return x + y, aux, new_cache


def mamba_block_init(key, cfg, dtype=jnp.float32):
    return {
        "norm": rmsnorm_init(cfg.d_model, dtype),
        "mixer": ssm_mod.mamba2_init(key, cfg, dtype),
    }


def mamba_block_apply(p, cfg, x, positions, cache=None):
    h, new_cache = ssm_mod.mamba2_apply(p["mixer"], cfg,
                                        rmsnorm(p["norm"], x, cfg.norm_eps),
                                        cache=cache)
    return x + h, jnp.zeros((), jnp.float32), new_cache


_BLOCKS = {
    "dense": (dense_block_init, dense_block_apply),
    "moe": (moe_block_init, moe_block_apply),
    "mamba": (mamba_block_init, mamba_block_apply),
}


# --------------------------------------------------------------- scanning ---
def _stack_init(key, cfg, n: int, kind: str, dtype):
    init, _ = _BLOCKS[kind]
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init(k, cfg, dtype))(keys)


def _scan_blocks(stacked, cfg, x, positions, kind: str, caches=None):
    """Scan a homogeneous segment.  Returns (x, aux_sum, new_caches)."""
    _, apply = _BLOCKS[kind]

    if caches is None:
        def body(carry, p_layer):
            xc, aux = carry
            y, a, _ = apply(p_layer, cfg, xc, positions, None)
            return (y, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   stacked)
        return x, aux, None

    def body(carry, layer):
        xc, aux = carry
        p_layer, cache_layer = layer
        y, a, nc = apply(p_layer, cfg, xc, positions, cache_layer)
        return (y, aux + a), nc

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked, caches))
    return x, aux, new_caches


# ------------------------------------------------------------- LM assembly --
def _segments(cfg):
    """(name, kind, n_layers) segments of the decoder stack."""
    if cfg.family == "moe":
        nd = cfg.moe.n_dense_layers
        segs = []
        if nd:
            segs.append(("blocks_dense", "dense", nd))
        segs.append(("blocks", "moe", cfg.n_layers - nd))
        return segs
    if cfg.family == "ssm":
        return [("blocks", "mamba", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("blocks", "mamba", cfg.n_layers)]  # + shared attn, see below
    return [("blocks", "dense", cfg.n_layers)]


def init_params(cfg, key, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    params = {"embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                      dtype),
              "final_norm": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(ks[1], cfg.d_model,
                                              cfg.vocab_size, dtype=dtype)
    for i, (name, kind, n) in enumerate(_segments(cfg)):
        params[name] = _stack_init(ks[2 + i], cfg, n, kind, dtype)
    if cfg.family == "hybrid":
        params["shared_attn"] = dense_block_init(ks[6], cfg, dtype)
    if cfg.mtp_depth:
        params["mtp"] = dense_block_init(ks[7], cfg, dtype)
    return params


def _backbone(cfg, params, x, positions, caches=None):
    """Embedded input -> final hidden states.  Returns (x, aux, new_caches)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}

    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        g = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // g
        stacked = params["blocks"]
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, g) + a.shape[1:]), stacked)
        gc = caches["blocks"] if caches else None
        gc = jax.tree.map(
            lambda a: a.reshape((n_groups, g) + a.shape[1:]), gc) \
            if gc is not None else None
        shared = params["shared_attn"]
        out_caches = []
        for gi in range(n_groups):
            seg = jax.tree.map(lambda a: a[gi], grouped)
            seg_cache = jax.tree.map(lambda a: a[gi], gc) if gc is not None \
                else None
            x, aux, nc = _scan_blocks(seg, cfg, x, positions, "mamba",
                                      seg_cache)
            aux_total += aux
            if caches is not None:
                out_caches.append(nc)
                sc = jax.tree.map(lambda a: a[gi], caches["shared_attn"])
                x, _, nsc = dense_block_apply(shared, cfg, x, positions, sc)
                new_caches.setdefault("shared_attn_list", []).append(nsc)
            else:
                x, _, _ = dense_block_apply(shared, cfg, x, positions, None)
        if caches is not None:
            new_caches["blocks"] = jax.tree.map(
                lambda *xs: jnp.stack(xs).reshape(
                    (n_groups * g,) + xs[0].shape[1:]), *out_caches)
            new_caches["shared_attn"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_caches.pop("shared_attn_list"))
        return x, aux_total, new_caches if caches is not None else None

    for name, kind, n in _segments(cfg):
        seg_cache = caches[name] if caches is not None else None
        x, aux, nc = _scan_blocks(params[name], cfg, x, positions, kind,
                                  seg_cache)
        aux_total += aux
        if caches is not None:
            new_caches[name] = nc
    return x, aux_total, new_caches if caches is not None else None


def forward(cfg, params, tokens, *, input_embeds=None, last_only=False):
    """tokens: (B, S) -> (logits (B, S, V) fp32, aux_loss).

    ``last_only=True`` (serving prefill) projects only the final position —
    computing 32k×vocab logits nobody reads dominated the prefill memory
    roofline (§Perf C1)."""
    x = embed(params["embed"], tokens, cfg.activation_dtype)
    if input_embeds is not None:       # vlm: prefix patch embeddings
        x = jnp.concatenate([input_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, aux, _ = _backbone(cfg, params, x, positions)
    if last_only:
        x = x[:, -1:]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["lm_head"], x.astype(jnp.float32))
    return logits, aux


def loss_fn(cfg, params, batch):
    """batch: dict(tokens (B,S), labels (B,S)[, input_embeds]) -> scalar."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          input_embeds=batch.get("input_embeds"))
    labels = batch["labels"]
    if "input_embeds" in batch and batch["input_embeds"] is not None:
        # vision prefix positions carry no labels
        pad = -jnp.ones(batch["input_embeds"].shape[:2], jnp.int32) * 100
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = cross_entropy(logits, labels)
    if cfg.mtp_depth:  # predict t+2 through one extra block
        x = embed(params["embed"], batch["tokens"], cfg.activation_dtype)
        positions = jnp.arange(x.shape[1])
        h, _, _ = dense_block_apply(params["mtp"], cfg, x, positions)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits2 = (unembed(params["embed"], h) if cfg.tie_embeddings
                   else dense(params["lm_head"], h.astype(jnp.float32)))
        l2 = jnp.pad(batch["labels"][:, 2:], ((0, 0), (0, 2)),
                     constant_values=-100)
        loss = loss + 0.1 * cross_entropy(logits2, l2)
    return loss + aux


# ------------------------------------------------------------------ decode --
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               per_slot_pos: bool = False):
    """``per_slot_pos=True`` builds the continuous-batching cache: the
    ``pos`` leaf is a ``(batch,)`` vector so each cache row is an
    independent decode lane (see :mod:`repro.serve.scheduler`)."""
    def one(kind):
        if kind == "mamba":
            return ssm_mod.mamba2_cache_init(cfg, batch, dtype,
                                             per_slot_pos=per_slot_pos)
        if cfg.mla:
            return attn_mod.mla_cache_init(cfg, batch, max_len, dtype,
                                           per_slot_pos=per_slot_pos)
        return attn_mod.gqa_cache_init(cfg, batch, max_len, dtype,
                                       per_slot_pos=per_slot_pos)

    caches = {}
    for name, kind, n in _segments(cfg):
        caches[name] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one(kind) for _ in range(n)])
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        caches["shared_attn"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one("dense") for _ in range(n_groups)])
    return caches


def plan_requests(cfg, batch: int, max_len: int, *, dtype=None, policy=None,
                  cached: bool = False):
    """Warmup descriptors for the kernels this config routes through the
    plan registry (:mod:`repro.compiler.registry`).

    Enumerates the (kernel, shape) bucket grid a serving process will touch
    — one flash-attention request per sequence bucket up to ``max_len`` for
    the pallas attention impl, one SSD request per bucket for the pallas SSM
    impl — so ``PlanRegistry.warmup(plan_requests(...))`` pre-measures every
    plan at launch and the first real token is already a warm hit.  The
    ragged MoE grouped GEMM is routing-dependent (group sizes only exist at
    serve time), so it warms on first use instead.

    ``cached=True`` is the KV/state-cached serving grid (the Engine):
    attention prefill plans appear only behind ``cfg.fresh_prefill_kernel``
    (pre-measuring dead plans would inflate launch time), SSD prefill plans
    request the final-state output the cached path consumes, and the
    **decode bucket grid** is added — one ``decode_attention`` plan per pos
    bucket up to ``max_len`` (the top bucket doubles as the traced-pos plan
    the jit'd engine decode step keys on) and one ``ssd_decode`` plan for
    SSM/hybrid stacks.  The default (``cached=False``) is the cache-free
    forward grid (scoring / benchmark layer steps).
    """
    from repro.compiler.registry import BucketPolicy
    policy = policy or BucketPolicy()
    dtype = dtype or str(cfg.activation_dtype)
    reqs = []

    wants_attn = cfg.attention_impl == "pallas" and (
        cfg.family in ("dense", "moe", "vlm")
        or (cfg.family == "hybrid" and cfg.hybrid_attn_every))
    prefill_attn = wants_attn and (not cached or cfg.fresh_prefill_kernel)
    if prefill_attn and cfg.mla:
        m = cfg.mla
        # mla_apply only takes the kernel path when head dims line up
        prefill_attn = m.nope_head_dim + m.rope_head_dim == m.v_head_dim
    if prefill_attn:
        if cfg.mla:
            h = hkv = cfg.n_heads
            d = cfg.mla.nope_head_dim + cfg.mla.rope_head_dim
        else:
            h, hkv, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        for sb in policy.seq_grid(max_len):
            reqs.append(("flash_attention",
                         dict(b=batch, h=h, hkv=hkv, s=sb, t=sb, d=d,
                              causal=True, dtype=dtype)))
    if cached and wants_attn and not cfg.mla:
        # decode bucket grid (GQA only: MLA decode runs the absorbed path
        # over the compressed cache, which the decode builder does not
        # model).  An eager decode step buckets on pos; the jit'd engine
        # step keys on the full preallocated length — bucket_seq(max_len),
        # the top of this same grid.
        for tb in policy.seq_grid(max_len):
            reqs.append(("decode_attention",
                         dict(b=batch, h=cfg.n_heads, hkv=cfg.n_kv_heads,
                              t=tb, d=cfg.head_dim_, dtype=dtype)))

    if cfg.family in ("ssm", "hybrid") and cfg.ssm_impl == "pallas" \
            and cfg.ssm:
        s = cfg.ssm
        nh = s.expand * cfg.d_model // s.head_dim
        for lb in policy.seq_grid(max_len):
            reqs.append(("ssd_scan",
                         dict(b=batch, l=lb, h=nh, p=s.head_dim,
                              n=s.state_dim, chunk=s.chunk,
                              n_groups=s.n_groups, dtype=dtype,
                              final_state=cached)))
        if cached:
            reqs.append(("ssd_decode",
                         dict(b=batch, h=nh, p=s.head_dim, n=s.state_dim,
                              n_groups=s.n_groups, dtype=dtype)))
    return reqs


def decode_step(cfg, params, tokens, cache):
    """tokens: (B, 1) -> (logits (B, 1, V), new_cache).

    Works for both cache layouts: a scalar per-layer ``pos`` gives the
    classic ``(S,)`` positions vector; a per-slot ``(B,)`` pos gives
    ``(B, S)`` ragged positions — ``pos.ndim`` is static, so each layout
    traces its own specialization of the same jitted callable."""
    x = embed(params["embed"], tokens, cfg.activation_dtype)
    seg0 = _segments(cfg)[0][0]
    pos = cache[seg0]["pos"][0]          # caches are stacked over layers
    if pos.ndim:                         # per-slot lanes: (B,) -> (B, S)
        positions = pos[:, None] + jnp.arange(tokens.shape[1])[None, :]
    else:
        positions = pos[None] + jnp.arange(tokens.shape[1])
    x, _, new_caches = _backbone(cfg, params, x, positions, caches=cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (unembed(params["embed"], x) if cfg.tie_embeddings
              else dense(params["lm_head"], x.astype(jnp.float32)))
    return logits, new_caches
