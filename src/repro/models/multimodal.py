"""InternVL2-style VLM: stubbed ViT frontend + dense LM backbone.

Per the assignment the modality frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_vision_tokens, d_vision).  The module owns
the projector MLP (d_vision → d_model, the InternVL "mlp1" bridge) and
delegates the backbone to :mod:`repro.models.transformer` with the vision
tokens as prefix embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer
from .layers import dense, dense_init, layernorm, layernorm_init


def init_params(cfg, key, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    params = transformer.init_params(cfg, k1, dtype)
    params["projector"] = {
        "norm": layernorm_init(cfg.d_vision, dtype),
        "fc1": dense_init(k2, cfg.d_vision, cfg.d_model, bias=True,
                          dtype=dtype),
        "fc2": dense_init(k3, cfg.d_model, cfg.d_model, bias=True,
                          dtype=dtype),
    }
    return params


def project(cfg, params, patches):
    p = params["projector"]
    x = layernorm(p["norm"], patches.astype(cfg.activation_dtype))
    return dense(p["fc2"], jax.nn.gelu(dense(p["fc1"], x)))


def forward(cfg, params, batch):
    """batch: dict(patches (B,P,d_vision), tokens (B,S)) -> (logits, aux)."""
    embeds = project(cfg, params, batch["patches"])
    return transformer.forward(cfg, params, batch["tokens"],
                               input_embeds=embeds)


def loss_fn(cfg, params, batch):
    embeds = project(cfg, params, batch["patches"])
    return transformer.loss_fn(
        cfg, params, {"tokens": batch["tokens"], "labels": batch["labels"],
                      "input_embeds": embeds})


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               per_slot_pos: bool = False):
    return transformer.init_cache(cfg, batch, max_len, dtype,
                                  per_slot_pos=per_slot_pos)


def decode_step(cfg, params, tokens, cache):
    return transformer.decode_step(cfg, params, tokens, cache)
