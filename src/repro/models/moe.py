"""Mixture-of-Experts layer (DeepSeek style: shared + routed top-k experts).

Dispatch is capacity-based with scatter/gather indexing (no (T, E, C) one-hot
tensor): top-k routing → per-expert slot assignment via a stable sort by
expert id → scatter tokens into a (E, C, d) buffer → batched expert SwiGLU
(einsum over the expert axis, EP-shardable) → gather + gate-weighted combine.
Tokens overflowing an expert's capacity are dropped (standard GShard
semantics); the auxiliary load-balance loss pushes the router away from
overflow.

The (E, C, d) expert buffer is the unit the ``model`` mesh axis shards for
expert parallelism; XLA inserts the dispatch all-to-all automatically from
the sharding annotations in launch/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense, dense_init


def _ep_constraint(arr):
    """Pin the (E, C, d) expert buffer to expert-parallel sharding when a
    mesh is active (no-op otherwise): experts over 'model', capacity over
    'data'.  Both dims sharded ⇒ the dispatch lowers as an all-to-all and
    the expert GEMMs stay fully distributed (§Perf iteration A2/A3)."""
    import os
    if os.environ.get("REPRO_MOE_EP_CONSTRAINT", "0") != "1":
        # Measured on deepseek-v3 train_4k (EXPERIMENTS.md §Perf A2/A3):
        # forcing EP×DP layout on the buffer made GSPMD reshard the scatter
        # operands (+2.2× bytes, +3.5× collectives).  GSPMD's propagated
        # layout matches the unconstrained optimum, so this is opt-in only.
        return arr
    try:
        from jax.sharding import NamedSharding, PartitionSpec as P
        env = jax.interpreters.pxla.thread_resources.env
        mesh = env.physical_mesh
        if mesh.empty or "model" not in mesh.axis_names:
            return arr
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        espec = "model" if arr.shape[0] % sizes["model"] == 0 else None
        cspec = "data" if ("data" in sizes
                           and arr.shape[1] % sizes["data"] == 0) else None
        if espec is None and cspec is None:
            return arr
        return jax.lax.with_sharding_constraint(
            arr, NamedSharding(mesh, P(espec, cspec, None)))
    except Exception:  # noqa: BLE001 — sharding is an optimization only
        return arr


def _ragged_dropless_experts(p, cfg, xt, gate, idx):
    """Expert SwiGLU over ragged row groups (the megablocks idiom).

    Tokens sort by expert id into a row-major concatenation of per-expert
    groups; all three expert GEMMs (gate/up/down) run as one ragged grouped
    GEMM each, with row groups padded to the row tile instead of a dense
    capacity.  ``cfg.kernel_plan == 'measure'`` routes through the plan
    registry (bucketed group sizes, measured pump); ``'direct'`` calls
    ``kernels.ops.grouped_gemm`` with the default pump.
    """
    mo = cfg.moe
    t, d = xt.shape
    e, k = mo.n_experts, mo.top_k
    flat_e = np.asarray(idx).reshape(-1)                          # (T*k,)
    order = np.argsort(flat_e, kind="stable")
    counts = np.bincount(flat_e, minlength=e)

    if cfg.kernel_plan == "measure":
        from repro.compiler.registry import default_registry
        reg = default_registry()
        bucket = reg.policy.bucket_group

        def gg(a, w):
            return reg.grouped_gemm(a, w, group_sizes=padded)
    else:
        from repro.kernels.ops import grouped_gemm as _gg
        bucket = lambda c: -(-c // 16) * 16 if c else 0   # noqa: E731

        def gg(a, w):
            return _gg(a, w, group_sizes=padded, bc=16)

    # scatter tokens into the bucketed padded row layout ONCE; all three
    # expert GEMMs consume it directly (group sizes == padded sizes, so
    # the ragged execution core skips per-group segmentation/re-slicing)
    padded = [int(bucket(int(c))) for c in counts]
    rows_p = sum(padded)
    offs = np.concatenate(([0], np.cumsum(padded)[:-1]))
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    sorted_e = flat_e[order]
    rows = offs[sorted_e] + (np.arange(t * k) - starts[sorted_e])
    tok_idx = np.repeat(np.arange(t), k)
    xs = jnp.zeros((rows_p, d), xt.dtype).at[rows].set(xt[tok_idx[order]])

    h_gate = gg(xs, p["gate"].astype(xt.dtype))
    h_up = gg(xs, p["up"].astype(xt.dtype))
    h = jax.nn.silu(h_gate) * h_up
    y_pad = gg(h, p["down"].astype(xt.dtype))

    y_sorted = y_pad[rows]                  # back to assignment order
    inv = np.empty_like(order)
    inv[order] = np.arange(t * k)
    gathered = y_sorted[inv].reshape(t, k, d)                     # dropless:
    return jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                      gate).astype(xt.dtype)                      # keep all


def moe_init(key, cfg, dtype=jnp.float32):
    mo = cfg.moe
    d, de = cfg.d_model, mo.d_expert
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    p = {
        "router": dense_init(ks[0], d, mo.n_experts, dtype=dtype),
        # routed experts, stacked: (E, d, de) / (E, de, d)
        "gate": jax.random.normal(ks[1], (mo.n_experts, d, de), dtype) * scale,
        "up": jax.random.normal(ks[2], (mo.n_experts, d, de), dtype) * scale,
        "down": jax.random.normal(ks[3], (mo.n_experts, de, d), dtype) \
            * (1.0 / jnp.sqrt(de).astype(jnp.float32)),
    }
    if mo.n_shared_experts:
        from .layers import swiglu_init
        p["shared"] = swiglu_init(ks[4], d, de * mo.n_shared_experts, dtype)
    return p


def moe_apply(p, cfg, x, *, dropless: bool = False
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    ``dropless=True`` (the serve path) sizes capacity to the worst case so
    no token is ever dropped — decode must be deterministic and match the
    full forward pass; training uses GShard capacity semantics."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = mo.n_experts, mo.top_k
    if dropless:
        icf = mo.inference_capacity_factor
        cap = t * k if icf <= 0 else min(t * k, -(-int(icf * t * k) // e) + 1)
    else:
        cap = int(mo.capacity_factor * t * k / e) + 1
        if t >= 4096:                    # production shapes: align for EP×DP
            cap = ((cap + 255) // 256) * 256

    xt = x.reshape(t, d)
    logits = dense(p["router"], xt.astype(jnp.float32))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                          # (T, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)    # renormalize

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                       # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)) / (t * k)
    aux = e * jnp.sum(me * ce) * mo.router_aux_weight

    # ---- ragged dropless path (serving): skip the dense capacity buffer ---
    # The ragged grouped-gemm kernel consumes per-expert row groups padded
    # only to the row tile — no (E, cap, d) worst-case buffer, empty experts
    # emit no tiles.  Group sizes must be static (they parameterize the
    # group-indexed BlockSpec tables), so this engages only on concrete
    # (non-traced) routing; jit'd calls keep the dense reference path.
    # Only the *strictly* dropless regime (icf <= 0) qualifies: a positive
    # inference_capacity_factor caps-and-drops in the dense path, and the
    # ragged path (which keeps every routed token) must not silently
    # diverge from that reference.
    if dropless and mo.ragged_dropless \
            and mo.inference_capacity_factor <= 0 \
            and not isinstance(x, jax.core.Tracer):
        y = _ragged_dropless_experts(p, cfg, xt, gate, idx)
        if mo.n_shared_experts:
            from .layers import swiglu
            y = y + swiglu(p["shared"], xt)
        return y.reshape(b, s, d), aux

    # ---- slot assignment: stable sort of (expert, arrival) pairs ----------
    flat_e = idx.reshape(-1)                                      # (T*k,)
    order = jnp.argsort(flat_e, stable=True)                      # sorted by e
    sorted_e = flat_e[order]
    # position within expert = index - start offset of that expert
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(t * k) - starts[sorted_e]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap                                              # drop overflow
    posc = jnp.where(keep, pos, cap)                              # cap = trash row

    # ---- dispatch: 3-D scatter into the (E, cap+1, d) expert buffer --------
    # Keeping the expert axis a REAL tensor dim (not flattened) lets GSPMD
    # shard the buffer P('model', None, None) (expert parallelism) and lower
    # the dispatch as an all-to-all instead of replicating the token stream
    # (§Perf iteration A2 — the flattened (E·C+1, d) form forced involuntary
    # full rematerialization and ~16× collective blowup on deepseek-v3).
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, posc].set(xt[tok_idx], mode="drop")
    expert_in = _ep_constraint(buf[:, :cap])                      # (E, cap, d)

    # ---- batched expert SwiGLU (EP axis = leading expert dim) --------------
    h_gate = jnp.einsum("ecd,edf->ecf", expert_in,
                        p["gate"].astype(x.dtype))
    h_up = jnp.einsum("ecd,edf->ecf", expert_in, p["up"].astype(x.dtype))
    h = jax.nn.silu(h_gate) * h_up
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))
    expert_out = _ep_constraint(expert_out)

    # ---- combine: gather slots back, weight by gates ------------------------
    padded = jnp.concatenate(
        [expert_out, jnp.zeros((e, 1, d), x.dtype)], axis=1)      # trash row
    gathered = padded[flat_e, posc].reshape(t, k, d)
    y = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                   gate * keep.reshape(t, k)).astype(x.dtype)

    if mo.n_shared_experts:
        from .layers import swiglu
        y = y + swiglu(p["shared"], xt)
    return y.reshape(b, s, d), aux
