"""Shared neural-net layers as pure init/apply function pairs.

No framework dependency: params are plain dict pytrees, every layer is
``init_*(key, ...) -> params`` + ``apply`` functions.  Weight layout
conventions (consumed by launch/sharding.py rules):

  - 2-D weights are (d_in, d_out) under key ``"w"``; biases ``"b"``.
  - stacked-per-layer params get a leading L axis added by the scanner.
  - embedding tables are (vocab, d_model) under key ``"embedding"``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None, dtype=jnp.float32):
    scale = scale if scale is not None else (1.0 / math.sqrt(d_in))
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dtype)


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"embedding": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p, ids, dtype=jnp.bfloat16):
    return p["embedding"].astype(dtype)[ids]


def unembed(p, x):
    """Logits via (tied or separate) embedding table; fp32 output."""
    return x.astype(jnp.float32) @ p["embedding"].astype(jnp.float32).T


# --------------------------------------------------------------------- MLP --
def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, d_ff, dtype=dtype),
        "up": dense_init(k2, d, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d, dtype=dtype),
    }


def swiglu(p, x):
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


def gelu_mlp_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "up": dense_init(k1, d, d_ff, bias=True, dtype=dtype),
        "down": dense_init(k2, d_ff, d, bias=True, dtype=dtype),
    }


def gelu_mlp(p, x):
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


# -------------------------------------------------------------------- RoPE --
def rope_freqs(head_dim: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    return inv  # (head_dim/2,)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, D) with D even; positions: (..., S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- softmax CE --
def cross_entropy(logits, labels, *, z_weight: float = 0.0):
    """Mean token cross-entropy (+ optional z-loss); labels -100 ignored."""
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if z_weight:
        nll = nll + z_weight * jnp.square(logz)
    denom = jnp.maximum(mask.sum(), 1)
    return jnp.where(mask, nll, 0.0).sum() / denom
