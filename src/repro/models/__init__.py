"""Model zoo: pure-JAX implementations of the assigned architecture families."""
from . import attention, encdec, layers, model, moe, multimodal, ssm, transformer
from .model import (init_params, loss_fn, forward, init_cache, decode_step,
                    example_batch)

__all__ = ["attention", "encdec", "layers", "model", "moe", "multimodal",
           "ssm", "transformer", "init_params", "loss_fn", "forward",
           "init_cache", "decode_step", "example_batch"]
