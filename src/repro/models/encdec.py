"""Whisper-style encoder–decoder transformer.

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, d) directly to the encoder.  The
encoder runs bidirectional self-attention; the decoder runs causal
self-attention + cross-attention over encoder output.  Whisper uses
LayerNorm + GELU; we keep the repo-wide pre-norm block structure with those
substitutions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .layers import (cross_entropy, dense, dense_init, embed, embedding_init,
                     gelu_mlp, gelu_mlp_init, layernorm, layernorm_init,
                     unembed)


def _sinusoid(t: int, d: int):
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def enc_block_init(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"norm1": layernorm_init(cfg.d_model, dtype),
            "attn": attn_mod.gqa_init(k1, cfg, dtype),
            "norm2": layernorm_init(cfg.d_model, dtype),
            "mlp": gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def enc_block_apply(p, cfg, x, positions):
    h, _ = attn_mod.gqa_apply(p["attn"], cfg,
                              layernorm(p["norm1"], x, cfg.norm_eps),
                              positions=positions, causal=False)
    x = x + h
    return x + gelu_mlp(p["mlp"], layernorm(p["norm2"], x, cfg.norm_eps))


def dec_block_init(key, cfg, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": layernorm_init(cfg.d_model, dtype),
            "self_attn": attn_mod.gqa_init(k1, cfg, dtype),
            "norm2": layernorm_init(cfg.d_model, dtype),
            "cross_attn": attn_mod.gqa_init(k2, cfg, dtype),
            "norm3": layernorm_init(cfg.d_model, dtype),
            "mlp": gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)}


def dec_block_apply(p, cfg, x, enc_out, positions, cache=None):
    h, new_cache = attn_mod.gqa_apply(
        p["self_attn"], cfg, layernorm(p["norm1"], x, cfg.norm_eps),
        positions=positions, causal=True, cache=cache)
    x = x + h
    h, _ = attn_mod.gqa_apply(
        p["cross_attn"], cfg, layernorm(p["norm2"], x, cfg.norm_eps),
        positions=positions, kv_input=enc_out)
    x = x + h
    return x + gelu_mlp(p["mlp"], layernorm(p["norm3"], x, cfg.norm_eps)), \
        new_cache


def init_params(cfg, key, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frontend_proj": dense_init(ks[2], cfg.d_model, cfg.d_model,
                                    dtype=dtype),
        "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg, dtype))(
            enc_keys),
        "enc_norm": layernorm_init(cfg.d_model, dtype),
        "embed": embedding_init(ks[3], cfg.vocab_size, cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg, dtype))(
            dec_keys),
        "dec_norm": layernorm_init(cfg.d_model, dtype),
    }


def encode(cfg, params, frames):
    """frames: (B, T_enc, d_model) precomputed frame embeddings (stub)."""
    x = dense(params["frontend_proj"], frames.astype(cfg.activation_dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1])

    def body(xc, p_layer):
        return enc_block_apply(p_layer, cfg, xc, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def decode(cfg, params, tokens, enc_out, caches=None):
    """tokens: (B, S) -> (logits, new_caches)."""
    x = embed(params["embed"], tokens, cfg.activation_dtype)
    if caches is not None:
        pos0 = caches["pos"][0]
        positions = pos0 + jnp.arange(tokens.shape[1])
    else:
        positions = jnp.arange(tokens.shape[1])
    x = x + _sinusoid(int(2 ** 15), cfg.d_model).astype(x.dtype)[positions][None]

    if caches is None:
        def body(xc, p_layer):
            y, _ = dec_block_apply(p_layer, cfg, xc, enc_out, positions)
            return y, None
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        new_caches = None
    else:
        def body(xc, layer):
            p_layer, c_layer = layer
            y, nc = dec_block_apply(p_layer, cfg, xc, enc_out, positions,
                                    cache=c_layer)
            return y, nc
        x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))

    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)   # whisper ties embeddings
    return logits, new_caches


def forward(cfg, params, batch):
    """batch: dict(frames, tokens) -> (logits, aux)."""
    enc_out = encode(cfg, params, batch["frames"])
    logits, _ = decode(cfg, params, batch["tokens"], enc_out)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg, params, batch):
    logits, _ = forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    per_layer = [attn_mod.gqa_cache_init(cfg, batch, max_len, dtype)
                 for _ in range(cfg.n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def decode_step(cfg, params, tokens, enc_out, caches):
    return decode(cfg, params, tokens, enc_out, caches=caches)
