"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Block structure: in-proj → short causal conv → SSD scan (the temporal-
vectorization flagship kernel) → gated out-proj.  Two SSD paths selected by
``cfg.ssm_impl``: ``pallas`` (repro.kernels.ssd_scan, interpret on CPU) and
``xla`` (chunked jnp with a lax.scan over chunks — the same chunked math the
kernel implements, so the two agree to float tolerance).

Decode keeps a recurrent state (B, H, N, P) + conv tail (B, conv_w-1, d_in)
per layer: O(1) per token, the reason mamba2/zamba2 run the long_500k cell.
Under ``ssm_impl='pallas'`` + ``kernel_plan='measure'`` both cached paths
are compiled: prefill runs the SSD scan kernel with its final-state output
(so the decode state comes out of the same measured kernel that computed y)
and the per-token step runs the ``ssd_decode`` multi-output tile kernel;
``kernel_plan='direct'`` keeps the jnp math as the differential reference.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, rmsnorm, rmsnorm_init


def mamba2_init(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    ks = jax.random.split(key, 5)
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], d,
                              2 * d_in + 2 * s.n_groups * s.state_dim + n_heads,
                              dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "D": jnp.ones((n_heads,), dtype),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(ks[4], d_in, d, dtype=dtype),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    gn = s.n_groups * s.state_dim
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt, d_in, n_heads, gn


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time.  xbc: (B, L, C); w: (W, C)."""
    wdt = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (wdt - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(wdt))
    return jax.nn.silu(out + b)


def _ssd_xla(x, dt, A, B, C, chunk):
    """Chunked SSD in pure jnp (same math as the Pallas kernel).

    Group-aware (§Perf C3): B/C projections are shared across the
    ``hpg = h/g`` heads of a group, so all einsums carry explicit (g, j)
    axes instead of materializing head-repeated copies of B and C — on
    mamba2-1.3b prefill the two ``jnp.repeat`` tensors were the largest
    intermediates in the block.  Matmul precision follows the input dtype
    (bf16 activations → bf16 MXU operands, fp32 accumulation); the decay
    cumsum and the inter-chunk state stay fp32.
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    nch = l // chunk
    cdt = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32
    f32 = jnp.float32
    xg = x.reshape(b, nch, chunk, g, hpg, p).astype(cdt)
    dtg = dt.reshape(b, nch, chunk, g, hpg).astype(f32)
    Bc = B.reshape(b, nch, chunk, g, n).astype(cdt)
    Cc = C.reshape(b, nch, chunk, g, n).astype(cdt)
    Ag = A.reshape(g, hpg)
    logp = jnp.cumsum(Ag[None, None, None] * dtg, axis=2)  # (b,nch,c,g,j)

    # intra-chunk dual form; cb is PER GROUP (tiny), decay per head
    cb = jnp.einsum("bncgk,bnsgk->bngcs", Cc, Bc,
                    preferred_element_type=f32)            # (b,nch,g,c,c)
    lp_t = logp.transpose(0, 1, 3, 4, 2)                   # (b,nch,g,j,c)
    diff = lp_t[..., :, None] - lp_t[..., None, :]         # (b,nch,g,j,c,c)
    t_idx = jnp.arange(chunk)
    mask = t_idx[:, None] >= t_idx[None, :]
    G = jnp.where(mask, cb[:, :, :, None]
                  * jnp.exp(jnp.where(mask, diff, 0.0))
                  * dtg.transpose(0, 1, 3, 4, 2)[..., None, :], 0.0)
    y_intra = jnp.einsum("bngjcs,bnsgjp->bncgjp", G.astype(cdt), xg,
                         preferred_element_type=f32)

    # inter-chunk state scan (fp32 carry)
    w = jnp.exp(lp_t[..., -1:] - lp_t) \
        * dtg.transpose(0, 1, 3, 4, 2)                     # (b,nch,g,j,c)
    chunk_contrib = jnp.einsum("bncgk,bngjc,bncgjp->bngjkp",
                               Bc, w.astype(cdt), xg,
                               preferred_element_type=f32)
    chunk_decay = jnp.exp(lp_t[..., -1])                   # (b,nch,g,j)

    def scan_step(s_prev, inp):
        contrib, decay = inp
        s_new = s_prev * decay[..., None, None] + contrib
        return s_new, s_prev

    init = jnp.zeros((b, g, hpg, n, p), f32)
    s_final, s_starts = jax.lax.scan(
        scan_step, init,
        (chunk_contrib.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    s_starts = s_starts.swapaxes(0, 1)                     # (b,nch,g,j,n,p)
    y_carry = jnp.einsum("bncgk,bngjkp,bncgj->bncgjp",
                         Cc, s_starts.astype(cdt),
                         jnp.exp(logp).astype(cdt),
                         preferred_element_type=f32)
    y = (y_intra + y_carry).reshape(b, l, h, p)
    return y.astype(x.dtype), s_final.reshape(b, h, n, p)


def mamba2_apply(p, cfg, x, *, cache=None, interpret=True):
    """x: (B, L, d) -> (out, new_cache).  cache: dict(state, conv, pos)."""
    s = cfg.ssm
    b, l, d = x.shape
    proj = dense(p["in_proj"], x)
    z, xbc, dt, d_in, n_heads, gn = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(dt.dtype))      # (B,L,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)

    if cache is not None and l == 1:
        # single-token recurrent step
        conv_tail = cache["conv"]                                 # (B, W-1, C)
        window = jnp.concatenate([conv_tail, xbc], axis=1)        # (B, W, C)
        w = p["conv_w"].astype(x.dtype)
        conv_out = jax.nn.silu((window * w).sum(axis=1, keepdims=True)
                               + p["conv_b"].astype(x.dtype))
        new_conv = window[:, 1:]
        xs, B_, C_ = jnp.split(conv_out, [d_in, d_in + gn], axis=-1)
        xh = xs.reshape(b, n_heads, s.head_dim)
        Bg = B_.reshape(b, s.n_groups, s.state_dim)
        Cg = C_.reshape(b, s.n_groups, s.state_dim)
        dt1 = dt[:, 0]                                            # (B,H)
        state = cache["state"].astype(jnp.float32)
        if cfg.ssm_impl == "pallas" and cfg.kernel_plan == "measure":
            # kernelized per-token step: y and the new state come out of
            # one compiled multi-output tile kernel (group-folded B/C —
            # no head-repeated copies), served from a warm registry plan
            from repro.compiler.registry import default_registry
            y, state = default_registry().ssd_decode(state, xh, dt1, A,
                                                     Bg, Cg)
            y = y.astype(jnp.float32)
        else:
            hpg = n_heads // s.n_groups
            Bh = jnp.repeat(Bg, hpg, axis=1)
            Ch = jnp.repeat(Cg, hpg, axis=1)
            decay = jnp.exp(A[None] * dt1)                        # (B,H)
            upd = jnp.einsum("bhn,bhp->bhnp", Bh.astype(jnp.float32)
                             * dt1[..., None], xh.astype(jnp.float32))
            state = state * decay[..., None, None] + upd
            y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
        y = y + p["D"].astype(jnp.float32)[None, :, None] \
            * xh.astype(jnp.float32)
        y = y.reshape(b, 1, d_in).astype(x.dtype)
        new_cache = {"state": state.astype(cache["state"].dtype),
                     "conv": new_conv, "pos": cache["pos"] + 1}
    else:
        cont = cache is not None and cfg.prefill_continuation
        if cont:
            # continuation chunk (pos > 0): the causal conv window is
            # seeded from the cached tail instead of zeros, so token 0 of
            # this chunk sees the last conv_width-1 tokens of the previous
            # chunk.  A zero tail (pos == 0) reduces to _causal_conv.
            window = jnp.concatenate(
                [cache["conv"].astype(x.dtype), xbc], axis=1)
            w = p["conv_w"].astype(x.dtype)
            conv_out = jax.nn.silu(
                sum(window[:, i:i + l, :] * w[i]
                    for i in range(s.conv_width))
                + p["conv_b"].astype(x.dtype))
        else:
            conv_out = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                    p["conv_b"].astype(x.dtype))
        xs, B_, C_ = jnp.split(conv_out, [d_in, d_in + gn], axis=-1)
        xh = xs.reshape(b, l, n_heads, s.head_dim)
        Bg = B_.reshape(b, l, s.n_groups, s.state_dim)
        Cg = C_.reshape(b, l, s.n_groups, s.state_dim)
        chunk = min(s.chunk, l)
        if l % chunk:
            chunk = 1
        use_kernel = cfg.ssm_impl == "pallas" and cfg.kernel_plan == "measure"
        if use_kernel and cache is None:
            # plan-registry route: L pads to a seq bucket (dt=0 steps
            # are state identities, so padding is exact) and the pump
            # factor replays the measured winner from the compile cache
            # pass the configured chunk, not the l-divisibility fixup:
            # the bucketed L is what must divide it, and the registry
            # clamps the chunk to the bucket itself
            from repro.compiler.registry import default_registry
            y = default_registry().ssd_scan(xh, dt, A, Bg, Cg, chunk=s.chunk)
            s_final = None
        elif use_kernel:
            # cached prefill: the SSD builder's final-state output makes
            # the kernel usable here — the per-sweep carry state lands in a
            # real graph output instead of being recomputed by _ssd_xla
            from repro.compiler.registry import default_registry
            y, s_final = default_registry().ssd_scan(xh, dt, A, Bg, Cg,
                                                     chunk=s.chunk,
                                                     final_state=True)
        elif cfg.ssm_impl == "pallas" and cache is None:
            from repro.kernels.ops import ssd_scan as _ssd
            y = _ssd(xh, dt, A, Bg, Cg, chunk=chunk, interpret=interpret)
            s_final = None
        else:
            y, s_final = _ssd_xla(xh, dt, A, Bg, Cg, chunk)
        if cont:
            # exact initial-state continuation on top of the zero-init
            # scan: with s0 the cached state, s_t = s0·exp(Σ_{1..t} A·dt)
            # + (zero-init part), so y_t gains C_t·s0·exp(cumsum_t) and
            # the final state gains s0·exp(total decay).  Both terms are
            # exactly zero at s0 = 0, so a fresh chunk is bit-identical.
            s0 = cache["state"].astype(jnp.float32)            # (B,H,N,P)
            lp = jnp.cumsum(A[None, None, :] * dt.astype(jnp.float32),
                            axis=1)                            # (B,L,H)
            hpg = n_heads // s.n_groups
            Ch = jnp.repeat(Cg, hpg, axis=2).astype(jnp.float32)
            y_init = jnp.einsum("blhn,bhnp->blhp", Ch, s0) \
                * jnp.exp(lp)[..., None]
            y = (y.astype(jnp.float32) + y_init).astype(xh.dtype)
            s_final = s_final.astype(jnp.float32) \
                + s0 * jnp.exp(lp[:, -1])[..., None, None]
        y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
        y = y.reshape(b, l, d_in)
        new_cache = None
        if cache is not None:
            # prefill: store final SSD state + conv tail for decoding.  A
            # continuation chunk shorter than the conv window must keep the
            # earlier tokens' tail entries, so its tail comes off the
            # seeded window rather than zero-padded current tokens.
            wdt = s.conv_width
            if cont:
                tail = window[:, -(wdt - 1):, :]
            else:
                tail = jnp.pad(xbc,
                               ((0, 0), (max(0, wdt - 1 - l), 0), (0, 0))
                               )[:, -(wdt - 1):, :]
            new_cache = {"state": s_final.astype(cache["state"].dtype),
                         "conv": tail.astype(cache["conv"].dtype),
                         "pos": cache["pos"] + l}

    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y), new_cache


def mamba2_cache_init(cfg, batch: int, dtype=jnp.bfloat16,
                      per_slot_pos: bool = False):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    # the recurrent step itself is position-free (state + conv tail carry
    # all history), so per-slot mode only changes the pos bookkeeping leaf
    return {
        "state": jnp.zeros((batch, n_heads, s.state_dim, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "pos": jnp.zeros((batch,) if per_slot_pos else (), jnp.int32),
    }
