"""Family dispatcher — the single entry point the launcher/trainer uses.

    init_params(cfg, key)            -> params pytree
    loss_fn(cfg, params, batch)      -> scalar loss       (train_step)
    prefill / decode helpers         -> serve_step
    batch_spec(cfg, shape)           -> input ShapeDtypeStructs (dry-run)
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import encdec, multimodal, transformer


def _mod(cfg):
    if cfg.family == "encdec":
        return encdec
    if cfg.family == "vlm":
        return multimodal
    return transformer


def init_params(cfg, key, dtype=jnp.float32):
    return _mod(cfg).init_params(cfg, key, dtype)


def loss_fn(cfg, params, batch):
    return _mod(cfg).loss_fn(cfg, params, batch)


def forward(cfg, params, batch, *, last_only=False):
    mod = _mod(cfg)
    if cfg.family == "encdec":
        return mod.forward(cfg, params, batch)
    if cfg.family == "vlm":
        embeds = mod.project(cfg, params, batch["patches"])
        from . import transformer
        return transformer.forward(cfg, params, batch["tokens"],
                                   input_embeds=embeds, last_only=last_only)
    return mod.forward(cfg, params, batch["tokens"], last_only=last_only)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               per_slot_pos: bool = False):
    if per_slot_pos and cfg.family == "encdec":
        raise ValueError("per-slot cache positions (continuous batching) "
                         "are not supported for the encdec family")
    if per_slot_pos:
        return _mod(cfg).init_cache(cfg, batch, max_len, dtype,
                                    per_slot_pos=True)
    return _mod(cfg).init_cache(cfg, batch, max_len, dtype)


def decode_step(cfg, params, batch, cache):
    """One-token decode.  batch carries tokens (B,1) (+ enc_out for encdec)."""
    if cfg.family == "encdec":
        return encdec.decode_step(cfg, params, batch["tokens"],
                                  batch["enc_out"], cache)
    return _mod(cfg).decode_step(cfg, params, batch["tokens"], cache)


def example_batch(cfg, shape, key=None, batch_override=None):
    """Concrete random batch for smoke tests / examples."""
    key = key if key is not None else jax.random.PRNGKey(0)
    b = batch_override or shape.global_batch
    s = shape.seq_len
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-100)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k2, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            k2, (b, cfg.n_vision_tokens, cfg.d_vision), jnp.float32)
    return batch
