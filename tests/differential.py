"""Reusable differential-testing harness for the kernel library.

One registry of *cases* — every executable ``autopump.BUILDERS`` entry with
small shapes and deterministic integer-valued float32 data — and one
``run_case`` that compiles a case through a chosen backend and asserts it
against the numpy reference executor (:mod:`repro.core.executor`), replacing
the per-kernel copy-pasted differential tests that used to live in
``tests/test_compiler.py``.

Exactness contract: kernels built from exactly-representable ops on
integer-valued data (add/mul/min/max — vecadd, matmul, stencil,
floyd-warshall, grouped gemm dense *and* ragged) are asserted **bit-exact**
across every backend.  Flash attention, the SSD kernels (scan, the
final-state variant, the single-token decode step) and decode attention
contain ``exp``, whose numpy and XLA CPU implementations differ by 1 ULP on
some inputs, so no backend pair can agree bitwise; those cases assert to a
1-ULP-amplified tolerance (``rtol=atol=5e-6``) instead — the flash
running-max output ``m`` (built from max alone) is still checked bit-exact.

The sweep axes (``BACKENDS × FACTORS × MODES``) intentionally mirror the
acceptance contract: every backend must hold for M ∈ {1, 2, 4} in both
temporal modes on at least two shapes per kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro import compiler
from repro.core import executor
from repro.core.autopump import BUILDERS

BACKENDS = ("reference", "jax", "pallas")
FACTORS = (1, 2, 4)
MODES = ("T", "R")


@dataclasses.dataclass(frozen=True)
class Case:
    """One differential case: a builder invocation + data + contract."""

    kernel: str                       # BUILDERS key
    args: Tuple                       # builder positional args
    kwargs: Dict                      # builder keyword args
    input_shapes: Dict[str, Tuple]    # memory name -> shape
    outputs: Tuple[str, ...]          # memory names to compare
    exact: bool = True                # bit-exact vs executor (see module doc)
    exact_outputs: Tuple[str, ...] = ()   # bit-exact even when exact=False
    gold: Optional[Callable] = None   # inputs -> {output name: array}
    transform: Optional[Callable] = None  # post-process generated inputs
    seed: int = 0

    def inputs(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        data = {name: rng.integers(-3, 4, shape).astype(np.float32)
                for name, shape in self.input_shapes.items()}
        if self.transform is not None:
            data = self.transform(data)
        return data


def _ssd_transform(data):
    # dt > 0, a < 0: the decay recurrence's contract; keep values on a
    # coarse grid so products/sums stay exactly representable
    data["dt"] = np.abs(data["dt"]) * 0.25 + 0.25
    data["a"] = -(np.abs(data["a"]) * 0.25 + 0.25)
    return data


def _decode_transform(positions):
    """Pin the decode positions (int32 cache write offsets)."""
    def transform(data):
        data["pos"] = np.asarray(positions, np.int32)
        return data
    return transform


def _decode_gold(inputs):
    q, k, v, pos = inputs["q"], inputs["k"], inputs["v"], inputs["pos"]
    b, h, d = q.shape
    group = h // k.shape[1]
    kk = np.repeat(k, group, axis=1)
    vv = np.repeat(v, group, axis=1)
    sc = np.einsum("bhd,bhtd->bht", q * np.float32(d ** -0.5), kk)
    mask = np.arange(k.shape[2])[None, None, :] <= pos[:, None, None]
    sc = np.where(mask, sc, -1e30)
    m = sc.max(-1, keepdims=True)
    p = np.exp(sc - m)
    o = np.einsum("bht,bhtd->bhd", p / p.sum(-1, keepdims=True), vv)
    return {"o": o.astype(np.float32)}


def _ssd_decode_gold(inputs):
    st, x, dt, a = (inputs[k] for k in ("state", "x", "dt", "a"))
    hpg = x.shape[1] // inputs["bmat"].shape[1]
    Bh = np.repeat(inputs["bmat"], hpg, axis=1)
    Ch = np.repeat(inputs["cmat"], hpg, axis=1)
    st2 = st * np.exp(a[None] * dt)[..., None, None] \
        + (Bh * dt[..., None])[..., :, None] * x[..., None, :]
    y = np.einsum("bhn,bhnp->bhp", Ch, st2)
    return {"y": y.astype(np.float32), "state_out": st2.astype(np.float32)}


def _flash_gold(inputs, causal=False, scale=None):
    q, k, v = inputs["q"], inputs["k"], inputs["v"]
    group = q.shape[1] // k.shape[1]
    k = np.repeat(k, group, axis=1)
    v = np.repeat(v, group, axis=1)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = np.einsum("bhsd,bhtd->bhst", q, k) * np.float32(scale)
    if causal:
        s, t = q.shape[2], k.shape[2]
        logits = np.where(np.tril(np.ones((s, t), bool)), logits, -1e30)
    m = logits.max(-1, keepdims=True)
    p = np.exp(logits - m)
    o = np.einsum("bhst,bhtd->bhsd", p / p.sum(-1, keepdims=True), v)
    return {"o": o.astype(np.float32)}


def _grouped_gold_dense(inputs):
    return {"o": np.einsum("ecd,edf->ecf", inputs["x"], inputs["w"])}


def _grouped_gold_ragged(sizes):
    def gold(inputs):
        x, w = inputs["x"], inputs["w"]
        offs = np.cumsum([0] + list(sizes))
        return {"o": np.concatenate(
            [x[offs[i]:offs[i + 1]] @ w[i] for i in range(len(sizes))])}
    return gold


def cases(shape_index: int = 0) -> Dict[str, Case]:
    """The registry, at one of two shape points per kernel (0 = tiny tier-1
    shapes, 1 = a second, structurally different shape for each kernel)."""
    if shape_index == 0:
        return {
            "vecadd": Case("vecadd", (64,), dict(vector_width=8),
                           {"x": (64,), "y": (64,)}, ("z",)),
            "matmul": Case("matmul", (32, 32, 32),
                           dict(bm=16, bn=16, bk=16, vector_width=8),
                           {"a": (32, 32), "b": (32, 32)}, ("c",)),
            "stencil": Case("stencil", (10, 8, 8), dict(),
                            {"x": (10, 8, 8)}, ("y",)),
            "floyd_warshall": Case("floyd_warshall", (16,), dict(),
                                   {"dist": (16, 16)}, ("out",)),
            "flash_attention": Case(
                "flash_attention", (1, 2, 32, 32, 8),
                dict(bq=16, bkv=8, causal=True, vector_width=8),
                {"q": (1, 2, 32, 8), "k": (1, 2, 32, 8), "v": (1, 2, 32, 8)},
                ("o", "m", "l"), exact=False, exact_outputs=("m",),
                gold=lambda i: _flash_gold(i, causal=True)),
            "ssd_scan": Case(
                "ssd_scan", (1, 32, 2, 4, 4), dict(chunk=8, vector_width=8),
                {"x": (1, 32, 2, 4), "dt": (1, 32, 2), "a": (2,),
                 "bmat": (1, 32, 2, 4), "cmat": (1, 32, 2, 4)},
                ("y",), exact=False, transform=_ssd_transform),
            "grouped_gemm": Case(
                "grouped_gemm", (2, 32, 16, 8),
                dict(bc=8, bf=8, bd=8, vector_width=8),
                {"x": (2, 32, 16), "w": (2, 16, 8)}, ("o",),
                gold=_grouped_gold_dense),
            "grouped_gemm_ragged": Case(
                "grouped_gemm", (2, 32, 16, 8),
                dict(bc=8, bf=8, bd=8, group_sizes=(16, 24),
                     vector_width=8),
                {"x": (40, 16), "w": (2, 16, 8)}, ("o",),
                gold=_grouped_gold_ragged((16, 24))),
            "decode_attention": Case(
                "decode_attention", (2, 4, 32, 8),
                dict(bkv=8, hkv=2, vector_width=4),       # GQA fold
                {"q": (2, 4, 8), "k": (2, 2, 32, 8), "v": (2, 2, 32, 8),
                 "pos": (2,)},
                ("o",), exact=False,
                transform=_decode_transform([17, 31]),    # mid / cache-full
                gold=_decode_gold),
            "ssd_scan_final": Case(
                "ssd_scan", (1, 32, 2, 4, 4),
                dict(chunk=8, vector_width=8, final_state=True),
                {"x": (1, 32, 2, 4), "dt": (1, 32, 2), "a": (2,),
                 "bmat": (1, 32, 2, 4), "cmat": (1, 32, 2, 4)},
                ("y", "state"), exact=False, transform=_ssd_transform),
            "ssd_decode": Case(
                "ssd_decode", (2, 4, 8, 4),
                dict(n_groups=2, vector_width=4),         # grouped B/C
                {"state": (2, 4, 4, 8), "x": (2, 4, 8), "dt": (2, 4),
                 "a": (4,), "bmat": (2, 2, 4), "cmat": (2, 2, 4)},
                ("y", "state_out"), exact=False, transform=_ssd_transform,
                gold=_ssd_decode_gold),
        }
    return {
        "vecadd": Case("vecadd", (128,), dict(vector_width=4),
                       {"x": (128,), "y": (128,)}, ("z",), seed=1),
        "matmul": Case("matmul", (32, 16, 64),
                       dict(bm=8, bn=8, bk=16, vector_width=8),
                       {"a": (32, 64), "b": (64, 16)}, ("c",), seed=1),
        "stencil": Case("stencil", (6, 4, 8), dict(),
                        {"x": (6, 4, 8)}, ("y",), seed=1),
        "floyd_warshall": Case("floyd_warshall", (8,), dict(),
                               {"dist": (8, 8)}, ("out",), seed=1),
        "flash_attention": Case(
            "flash_attention", (2, 4, 16, 32, 4),
            dict(bq=8, bkv=8, hkv=2, vector_width=8),    # GQA fold
            {"q": (2, 4, 16, 4), "k": (2, 2, 32, 4), "v": (2, 2, 32, 4)},
            ("o", "m", "l"), exact=False, exact_outputs=("m",),
            gold=lambda i: _flash_gold(i), seed=1),
        "ssd_scan": Case(
            "ssd_scan", (2, 16, 4, 8, 2),
            dict(chunk=4, n_groups=2, vector_width=8),   # grouped B/C
            {"x": (2, 16, 4, 8), "dt": (2, 16, 4), "a": (4,),
             "bmat": (2, 16, 2, 2), "cmat": (2, 16, 2, 2)},
            ("y",), exact=False, transform=_ssd_transform, seed=1),
        "grouped_gemm": Case(
            "grouped_gemm", (3, 16, 32, 16),
            dict(bc=16, bf=8, bd=8, vector_width=8),
            {"x": (3, 16, 32), "w": (3, 32, 16)}, ("o",),
            gold=_grouped_gold_dense, seed=1),
        "grouped_gemm_ragged": Case(
            "grouped_gemm", (3, 16, 8, 8),
            dict(bc=8, bf=8, bd=8, group_sizes=(8, 24, 8),
                 vector_width=8),
            {"x": (40, 8), "w": (3, 8, 8)}, ("o",),
            gold=_grouped_gold_ragged((8, 24, 8)), seed=1),
        "decode_attention": Case(
            "decode_attention", (1, 4, 16, 4),
            dict(bkv=4, hkv=2, vector_width=4),
            {"q": (1, 4, 4), "k": (1, 2, 16, 4), "v": (1, 2, 16, 4),
             "pos": (1,)},
            ("o",), exact=False,
            transform=_decode_transform([0]),             # fresh cache
            gold=_decode_gold, seed=1),
        "ssd_scan_final": Case(
            "ssd_scan", (2, 16, 4, 8, 2),
            dict(chunk=4, n_groups=2, vector_width=8, final_state=True),
            {"x": (2, 16, 4, 8), "dt": (2, 16, 4), "a": (4,),
             "bmat": (2, 16, 2, 2), "cmat": (2, 16, 2, 2)},
            ("y", "state"), exact=False, transform=_ssd_transform, seed=1),
        "ssd_decode": Case(
            "ssd_decode", (1, 4, 8, 4),
            dict(n_groups=4, vector_width=4),     # hpg=1: linear head sym
            {"state": (1, 4, 4, 8), "x": (1, 4, 8), "dt": (1, 4),
             "a": (4,), "bmat": (1, 4, 4), "cmat": (1, 4, 4)},
            ("y", "state_out"), exact=False, transform=_ssd_transform,
            gold=_ssd_decode_gold, seed=1),
    }


def run_case(case: Case, factor: int, mode: str, backend: str,
             cache=False, pallas_mode: str = "auto") -> None:
    """Compile one case and assert it against the reference executor (and
    the independent numpy gold, when the case carries one)."""
    g, _est = BUILDERS[case.kernel](*case.args, **case.kwargs)
    kern = compiler.compile(g, factor=factor, mode=mode, backend=backend,
                            pallas_mode=pallas_mode, cache=cache,
                            memoize=False)
    inputs = case.inputs()
    out = kern(inputs)
    gold = executor.run(kern.graph, dict(inputs))
    for name in case.outputs:
        a, b = np.asarray(out[name]), gold[name]
        if case.exact or name in case.exact_outputs:
            np.testing.assert_array_equal(
                a, b, err_msg=f"{case.kernel}:{name} vs executor "
                              f"(M={factor} {mode} {backend})")
        else:
            np.testing.assert_allclose(
                a, b, rtol=5e-6, atol=5e-6,
                err_msg=f"{case.kernel}:{name} vs executor "
                        f"(M={factor} {mode} {backend})")
    if case.gold is not None:
        want = case.gold(inputs)
        for name, value in want.items():
            np.testing.assert_allclose(
                np.asarray(out[name]), value, rtol=1e-5, atol=1e-5,
                err_msg=f"{case.kernel}:{name} vs semantics "
                        f"(M={factor} {mode} {backend})")


def sweep(kernels: Optional[Sequence[str]] = None,
          backends: Sequence[str] = BACKENDS,
          factors: Sequence[int] = FACTORS,
          modes: Sequence[str] = MODES,
          shape_indices: Sequence[int] = (0, 1)) -> int:
    """Run the full cross product (CLI / `make test-diff` entry point);
    returns the number of executed combinations."""
    ran = 0
    for si in shape_indices:
        registry = cases(si)
        for name, case in registry.items():
            if kernels is not None and name not in kernels:
                continue
            for backend in backends:
                for factor in factors:
                    for mode in modes:
                        run_case(case, factor, mode, backend)
                        ran += 1
    return ran


if __name__ == "__main__":
    print(f"differential sweep: {sweep()} combinations ok")
