"""End-to-end system tests: training convergence, multipumped gradient
equivalence, checkpoint/restore, failure recovery, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import ModelConfig, ShapeConfig
from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, DataIterator, synthetic_batch
from repro.launch import steps as steps_mod
from repro.models import model as model_mod
from repro.runtime import failover
from repro.train.trainer import TrainConfig, train

TINY = ModelConfig("tiny", "dense", 2, 32, 4, 2, 64, 64, dtype="float32")
SHAPE = ShapeConfig("t", 32, 8, "train")


# ------------------------------------------------------------- convergence --
def test_training_loss_decreases():
    out = train(TINY, SHAPE,
                optim.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=80),
                TrainConfig(n_steps=80, log_every=10))
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"] * 0.95
    assert all(np.isfinite(e["loss"]) for e in h)


# --------------------------------------------- multipump gradient identity --
def test_pumped_step_matches_unpumped():
    """Trainer Mode T correctness: M microbatches accumulated == one big
    batch (same tokens), to float tolerance.  This is the pod-scale
    issuer/packer value-preservation property."""
    optcfg = optim.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                               grad_clip=0.0)
    params = model_mod.init_params(TINY, jax.random.PRNGKey(0))
    opt0 = optim.init(optcfg, params)

    batch = synthetic_batch(TINY, SHAPE, DataConfig(), 0)
    p1, _, m1 = jax.jit(steps_mod.make_train_step(TINY, optcfg))(
        params, opt0, batch)

    pumped = jax.tree.map(
        lambda a: a.reshape((4, 2) + a.shape[1:]), batch)
    opt0b = optim.init(optcfg, params)
    p2, _, m2 = jax.jit(steps_mod.make_train_step(TINY, optcfg, 4))(
        params, opt0b, pumped)

    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 1e-5


# ------------------------------------------------------------- data stream --
def test_data_stream_is_deterministic_and_checkpointable():
    it1 = DataIterator(TINY, SHAPE)
    for _ in range(3):
        next(it1)
    state = it1.state()
    b_next = next(it1)

    it2 = DataIterator.from_state(TINY, SHAPE, state)
    b_replay = next(it2)
    np.testing.assert_array_equal(np.asarray(b_next["tokens"]),
                                  np.asarray(b_replay["tokens"]))


# -------------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    root = str(tmp_path / "ckpt")
    state = {"w": jnp.arange(12.0).reshape(3, 4),
             "nested": {"b": jnp.ones((5,), jnp.bfloat16)}}
    ckpt.save(root, 7, state, extra={"step": 7})
    latest = ckpt.latest_valid(root)
    assert latest and latest.endswith("step_00000007")
    restored, extra = ckpt.restore(latest, state)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption(tmp_path):
    root = str(tmp_path / "ckpt")
    state = {"w": jnp.ones((4,))}
    ckpt.save(root, 1, state, extra={"step": 1})
    ckpt.save(root, 2, state, extra={"step": 2})
    # corrupt the newest shard
    shard = os.path.join(root, "step_00000002", "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    latest = ckpt.latest_valid(root)
    assert latest is not None and latest.endswith("step_00000001")


def test_checkpoint_prune(tmp_path):
    root = str(tmp_path / "ckpt")
    for s in range(6):
        ckpt.save(root, s, {"w": jnp.zeros(1)}, extra={"step": s})
    ckpt.prune(root, keep=2)
    assert ckpt.available_steps(root) == [4, 5]


# ---------------------------------------------------------------- failover --
def test_run_with_recovery_resumes_after_injected_failure(tmp_path):
    root = str(tmp_path / "ckpt")
    calls = {"n": 0, "fail_at": 7}

    def train_fn(state, step):
        calls["n"] += 1
        if step == calls["fail_at"] and calls["fail_at"] is not None:
            calls["fail_at"] = None            # fail exactly once
            raise failover.FailureInjected("simulated node loss")
        return {"x": state["x"] + 1.0}

    final = failover.run_with_recovery(
        train_fn, {"x": jnp.zeros(())}, n_steps=12, ckpt_root=root,
        ckpt_every=5)
    # exactly-once semantics: final state reflects 12 effective steps
    assert float(final["x"]) == 12.0


def test_heartbeat_and_straggler_policy():
    hb = failover.Heartbeat(timeout_s=10)
    hb.stamp(0, 5, now=100.0)
    hb.stamp(1, 5, now=100.0)
    assert hb.dead_workers(now=105.0) == []
    assert hb.dead_workers(now=115.0) == [0, 1]

    pol = failover.StragglerPolicy(base_pump=8)
    for w, t in [(0, 1.0), (1, 1.0), (2, 4.0)]:
        for _ in range(20):
            pol.observe(w, t)
    pf = pol.pump_factors()
    assert pf[0] == 8 and pf[1] == 8
    assert pf[2] < 8                            # the straggler gets derated


def test_elastic_remesh(tmp_path):
    from repro.launch import sharding as shard_mod
    root = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(root, 3, tree, extra={"step": 3})
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    placed, extra = failover.elastic_remesh(
        ckpt.latest_valid(root), tree, mesh,
        lambda t, m: shard_mod.shardings(t, m))
    assert extra["step"] == 3
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.asarray(tree["w"]))


# ----------------------------------------------------------------- serving --
def test_generate_greedy_is_deterministic():
    from repro.serve.engine import Engine, ServeConfig
    cfg = TINY
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    out1 = eng.generate(prompts, 6)
    out2 = eng.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)


def test_trainer_checkpoint_resume_bitexact(tmp_path):
    root = str(tmp_path / "ck")
    optcfg = optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    # run 1: 10 steps with ckpt every 5
    train(TINY, SHAPE, optcfg,
          TrainConfig(n_steps=10, ckpt_root=root, ckpt_every=5, log_every=5))
    # run 2: resume to 15
    out2 = train(TINY, SHAPE, optcfg,
                 TrainConfig(n_steps=15, ckpt_root=root, ckpt_every=5,
                             log_every=5))
    # run 3 (control): fresh 15 steps, no resume
    out3 = train(TINY, SHAPE, optcfg,
                 TrainConfig(n_steps=15, log_every=5))
    w2 = jax.tree.leaves(out2["final_state"].params)[0]
    w3 = jax.tree.leaves(out3["final_state"].params)[0]
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w3), atol=1e-6)
