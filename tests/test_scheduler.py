"""Continuous-batching scheduler invariant harness (docs/serving.md).

The contract the scheduler (:mod:`repro.serve.scheduler`) must hold on any
seeded trace:

* **No slot double-allocation or leak** — every slot in the per-step
  snapshots is owned by at most one request, occupancy never exceeds
  ``max_slots``, and the trace ends with every slot free.
* **FIFO admission fairness** — requests enter slots in arrival order; a
  later arrival never overtakes an earlier one into a lane.
* **Conservation** — after every step, submitted == not-yet-arrived +
  queued + in-flight + completed (also enforced inside ``run_step``).
* **Per-request parity** — every streamed request's tokens are identical
  to running it alone through ``Engine.generate()`` and its sampled-from
  logits agree to ≤5e-6 — the mixed ragged in-flight batch must be
  indistinguishable from solo serving.
* **Throughput** — the point of the exercise: the stream sustains ≥1.3×
  the tokens/s of draining the same trace sequentially per-request.

All workloads come from :func:`scheduler.synthetic_workload` (seeded
arrivals + length distributions), so every failure replays exactly.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import load_arch
from repro.models import model as model_mod
from repro.serve import scheduler as sched
from repro.serve.engine import Engine, ServeConfig

ARCH = "qwen3-0.6b"
PARITY = 5e-6


def _direct_engine(batch=4, max_len=32):
    """Compiler-free engine (plain-jnp paths): fast to build, the right
    harness for scheduler-logic tests — plan-registry routing has its own
    test below."""
    cfg = dataclasses.replace(load_arch(ARCH, smoke=True),
                              attention_impl="xla_chunked",
                              kernel_plan="direct")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(batch=batch, max_len=max_len,
                                           warmup=False))


@pytest.fixture(scope="module")
def engine():
    return _direct_engine()


# ----------------------------------------------------------- workload gen ---
def test_synthetic_workload_is_deterministic():
    a = sched.synthetic_workload(12, seed=7, arrival_rate=0.4)
    b = sched.synthetic_workload(12, seed=7, arrival_rate=0.4)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert all(np.array_equal(x.tokens, y.tokens) for x, y in zip(a, b))
    assert [r.n_new for r in a] == [r.n_new for r in b]
    # arrivals are nondecreasing and lengths come from the given sets
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    assert {r.prompt_len for r in a} <= {4, 8}
    assert {r.n_new for r in a} <= {2, 4}
    c = sched.synthetic_workload(12, seed=8, arrival_rate=0.4)
    assert any(not np.array_equal(x.tokens, y.tokens) for x, y in zip(a, c))


def test_workload_validation():
    with pytest.raises(ValueError):
        sched.synthetic_workload(2, arrival_rate=0.0)
    eng_like = sched.SlotManager
    with pytest.raises(ValueError):
        eng_like(0)


# ------------------------------------------------------------ slot manager --
def test_slot_manager_guards():
    sm = sched.SlotManager(2)
    s0 = sm.alloc(10)
    s1 = sm.alloc(11)
    assert {s0, s1} == {0, 1} and sm.free_count == 0 and sm.occupancy == 2
    with pytest.raises(RuntimeError, match="no free slots"):
        sm.alloc(12)
    sm.free(s0)
    with pytest.raises(RuntimeError, match="double-freed"):
        sm.free(s0)
    assert sm.alloc(12) == s0          # freed lane is reused
    # a corrupted free list (the seam double-alloc guards) is caught
    sm._free.append(s1)
    with pytest.raises(RuntimeError, match="double-allocated"):
        sm.alloc(13)


# -------------------------------------------------------------- invariants --
class InvariantChecker:
    """step_hook that re-derives every scheduler invariant per step."""

    def __init__(self, n_requests: int, max_slots: int):
        self.n, self.max_slots = n_requests, max_slots
        self.steps = 0
        self.admitted_order = []
        self.ever_active = set()
        self.max_occupancy = 0

    def __call__(self, snap):
        self.steps += 1
        occ = snap["occupancy"]
        assert 0 <= occ <= self.max_slots, snap
        assert occ == len(snap["active"]), "occupancy vs active desync"
        assert occ + snap["free"] == self.max_slots, "slot leak"
        rids = list(snap["active"].values())
        assert len(rids) == len(set(rids)), \
            f"request in two slots at step {snap['step']}: {snap['active']}"
        self.admitted_order.extend(snap["admitted"])
        self.ever_active.update(rids)   # lanes still in flight at step end
        self.max_occupancy = max(self.max_occupancy, occ)
        # conservation (the scheduler asserts it too; re-derive from the
        # snapshot so a broken internal assert can't hide it)
        assert (snap["pending"] + len(snap["queue"]) + occ
                + snap["completed"]) == self.n, snap

    def finish(self, results, requests):
        assert len(results) == self.n, "not every request completed"
        assert self.admitted_order == sorted(self.admitted_order), \
            f"FIFO admission violated: {self.admitted_order}"
        # every request was admitted exactly once (fast finishers may
        # complete inside their admission step, so ever_active is a subset)
        assert set(self.admitted_order) == {r.rid for r in requests}
        assert len(self.admitted_order) == self.n
        assert self.ever_active <= {r.rid for r in requests}
        for r in results:
            assert r.queue_wait_steps >= 0
            assert r.admitted_step >= 0 and r.done_step >= r.admitted_step


def test_invariants_over_200_step_trace(engine):
    """The acceptance-criteria trace: ≥200 seeded scheduler steps with
    queueing pressure (more requests than slots, bursty arrivals)."""
    reqs = sched.synthetic_workload(70, seed=3, prompt_lens=(2, 4),
                                    new_tokens=(2, 4, 6),
                                    arrival_rate=0.28,
                                    vocab=engine.cfg.vocab_size)
    chk = InvariantChecker(len(reqs), max_slots=4)
    res = engine.serve_stream(reqs, step_hook=chk)
    chk.finish(res, reqs)
    assert chk.steps >= 200, f"trace too short: {chk.steps} steps"
    assert chk.max_occupancy == 4, "the trace never filled the slots"
    assert any(r.queue_wait_steps > 0 for r in res), \
        "the trace never exercised the queue"
    # finished clean: all lanes free, nothing in flight
    s = sched.Scheduler(engine)  # fresh — engine holds no scheduler state
    assert s.slots.free_count == s.max_slots


def test_conservation_violation_fails_loud(engine):
    """A scheduler bug that loses a request must raise, not hang."""
    reqs = sched.synthetic_workload(4, seed=0, prompt_lens=(2,),
                                    new_tokens=(2,), arrival_rate=1.0,
                                    vocab=engine.cfg.vocab_size)
    s = sched.Scheduler(engine)
    s.submit(reqs)
    s._total += 1  # simulate a lost request
    with pytest.raises(RuntimeError, match="conservation"):
        while s.pending or s.queue or s.active:
            s.run_step()


def test_request_validation(engine):
    with pytest.raises(ValueError, match="exceeds max_len"):
        engine.serve_stream([sched.Request(0, np.zeros(40, np.int32), 8)])
    with pytest.raises(ValueError, match="n_new"):
        engine.serve_stream([sched.Request(0, np.zeros(4, np.int32), 0)])


def test_encdec_family_rejected():
    """Cross-attention caches are per-request; continuous batching refuses
    the family up front (both at the scheduler and at init_cache)."""
    cfg = load_arch("whisper-base", smoke=True)
    shell = object.__new__(Engine)      # cfg/scfg are all Scheduler reads
    shell.cfg, shell.scfg = cfg, ServeConfig(batch=2, max_len=16)
    with pytest.raises(ValueError, match="encdec"):
        sched.Scheduler(shell)
    with pytest.raises(ValueError, match="encdec"):
        model_mod.init_cache(cfg, 2, 16, jnp.float32, per_slot_pos=True)


# ------------------------------------------------------------------ parity --
def test_stream_token_parity_vs_solo(engine):
    """Every streamed request reproduces its solo run exactly: same tokens,
    sampled-from logits within 5e-6 — the ragged mixed batch is
    indistinguishable from serving each request alone."""
    reqs = sched.synthetic_workload(8, seed=11, prompt_lens=(3, 5, 8),
                                    new_tokens=(1, 3, 5),
                                    arrival_rate=0.5,
                                    vocab=engine.cfg.vocab_size)
    res = {r.rid: r for r in engine.serve_stream(reqs, collect_logits=True)}
    for r in reqs:
        got = res[r.rid]
        assert got.tokens.shape == (r.n_new,)
        assert got.logits.shape[0] == r.n_new
        solo_t, solo_l = engine.generate(
            jnp.asarray(np.asarray(r.tokens))[None], r.n_new,
            return_logits=True)
        np.testing.assert_array_equal(got.tokens, np.asarray(solo_t)[0],
                                      err_msg=f"rid {r.rid}")
        err = float(np.max(np.abs(got.logits - np.asarray(solo_l)[:, 0])))
        assert err <= PARITY, f"rid {r.rid}: logit drift {err:.2e}"


def test_stream_parity_registry_route(tmp_path, monkeypatch):
    """The plan-registry serving config (pallas + measured plans): parity
    still holds and the stream runs on 100% warm plans — zero post-warmup
    misses in either phase, with the ragged per-slot decode counted."""
    from repro import compiler
    from repro.compiler.registry import PlanRegistry, set_default_registry
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    compiler.clear_memo()
    old = set_default_registry(PlanRegistry())
    try:
        _run_registry_route_case()
    finally:
        set_default_registry(old)


def _run_registry_route_case():
    cfg = dataclasses.replace(load_arch(ARCH, smoke=True),
                              attention_impl="pallas")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=16))
    warm = eng.stats()["registry"]          # warmup's own cold measures
    ragged = obs.snapshot(include_views=False)["counters"].get(
        "registry.decode.ragged_pos", 0)
    reqs = sched.synthetic_workload(3, seed=2, prompt_lens=(4, 8),
                                    new_tokens=(2, 3), arrival_rate=0.8,
                                    vocab=cfg.vocab_size)
    res = {r.rid: r for r in eng.serve_stream(reqs, collect_logits=True)}
    st = eng.stats()["registry"]            # before the batch-1 solo runs
    assert st["decode"]["misses"] == warm["decode"]["misses"], \
        "the stream's decode went cold post-warmup"
    assert st["prefill"]["misses"] == warm["prefill"]["misses"], \
        "the stream's prefill went cold post-warmup"
    assert st["decode"]["hits"] > warm["decode"]["hits"]
    assert st["prefill"]["hits"] > warm["prefill"]["hits"]
    assert st["fallbacks"] == warm["fallbacks"]
    assert obs.snapshot(include_views=False)["counters"].get(
        "registry.decode.ragged_pos", 0) > ragged
    for r in reqs:
        solo_t, solo_l = eng.generate(
            jnp.asarray(np.asarray(r.tokens))[None], r.n_new,
            return_logits=True)
        np.testing.assert_array_equal(res[r.rid].tokens,
                                      np.asarray(solo_t)[0])
        err = float(np.max(np.abs(res[r.rid].logits
                                  - np.asarray(solo_l)[:, 0])))
        assert err <= PARITY, f"rid {r.rid}: logit drift {err:.2e}"


# -------------------------------------------------------------- throughput --
def test_stream_throughput_beats_sequential(engine):
    """≥1.3× tokens/s over draining the trace sequentially per-request.
    Both paths are pre-warmed (traced + compiled) before timing."""
    reqs = sched.synthetic_workload(10, seed=5, prompt_lens=(4, 8),
                                    new_tokens=(6, 8), arrival_rate=1.0,
                                    vocab=engine.cfg.vocab_size)
    total_tokens = sum(r.n_new for r in reqs)

    def run_stream():
        return engine.serve_stream(reqs)

    def run_sequential():
        for r in reqs:
            engine.generate(jnp.asarray(np.asarray(r.tokens))[None], r.n_new)

    run_stream(); run_sequential()          # warm both paths
    best_stream = min(_timed(run_stream) for _ in range(2))
    best_seq = min(_timed(run_sequential) for _ in range(2))
    tps_stream = total_tokens / best_stream
    tps_seq = total_tokens / best_seq
    speedup = tps_stream / tps_seq
    assert speedup >= 1.3, \
        (f"stream {tps_stream:.1f} tok/s vs sequential {tps_seq:.1f} tok/s "
         f"— only {speedup:.2f}x")


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ----------------------------------------------------------------- overload --
class OverloadChecker:
    """step_hook for overload traces: the InvariantChecker contract extended
    with shed accounting, preemption re-admission, queue bounds, and the
    chunked-prefill snapshot keys."""

    def __init__(self, n_requests: int, max_slots: int,
                 max_queue=None):
        self.n, self.max_slots, self.max_queue = \
            n_requests, max_slots, max_queue
        self.steps = 0
        self.admissions = {}            # rid -> times admitted into a slot
        self.preemptions = {}           # rid -> times preempted
        self.max_occupancy = 0
        self.saw_prefilling = False

    def __call__(self, snap):
        self.steps += 1
        occ = snap["occupancy"]
        assert 0 <= occ <= self.max_slots, snap
        assert occ == len(snap["active"]), "occupancy vs active desync"
        assert occ + snap["free"] == self.max_slots, "slot leak"
        rids = list(snap["active"].values())
        assert len(rids) == len(set(rids)), \
            f"request in two slots at step {snap['step']}: {snap['active']}"
        if self.max_queue is not None:
            assert len(snap["queue"]) <= self.max_queue, \
                f"admission queue bound exceeded: {snap}"
        assert set(snap["prefilling"]) <= set(snap["active"]), snap
        self.saw_prefilling |= bool(snap["prefilling"])
        for rid in snap["admitted"]:
            self.admissions[rid] = self.admissions.get(rid, 0) + 1
        for rid in snap["preempted"]:
            self.preemptions[rid] = self.preemptions.get(rid, 0) + 1
        self.max_occupancy = max(self.max_occupancy, occ)
        # conservation, now including sheds
        assert (snap["pending"] + len(snap["queue"]) + occ
                + snap["completed"] + snap["shed"]) == self.n, snap

    def finish(self, completed, shed, requests):
        done = {r.rid for r in completed}
        dropped = {s.rid for s in shed}
        # every submitted request completed or was shed, exactly once each
        assert done | dropped == {r.rid for r in requests}
        assert not (done & dropped), "request both completed and shed"
        assert len(completed) + len(shed) == self.n
        # shed requests never touched a slot; completed ones were admitted
        # exactly (1 + preemptions) times
        assert not (dropped & set(self.admissions)), \
            "a shed request was admitted into a slot"
        for r in completed:
            assert self.admissions.get(r.rid) == 1 + r.preemptions, \
                (r.rid, self.admissions.get(r.rid), r.preemptions)
            assert self.preemptions.get(r.rid, 0) == r.preemptions
        for s in shed:
            assert s.reason in ("queue_full", "deadline_unmeetable"), s


def test_overload_invariants_200_steps_with_preemption(engine):
    """The acceptance trace: 200+ steps at 2x the service rate with chunked
    prefill, preemption, deadlines and a bounded queue — every invariant
    holds, every request completes or is shed with a named reason."""
    reqs = sched.synthetic_workload(
        130, seed=13, prompt_lens=(2, 4, 8, 16), new_tokens=(2, 4, 6),
        arrival_rate=0.35, vocab=engine.cfg.vocab_size,
        prompt_len_weights=(0.35, 0.3, 0.2, 0.15),
        deadlines_ms=(10, 20, None), priorities=(0, 1, 2))
    chk = OverloadChecker(len(reqs), max_slots=2, max_queue=8)
    completed, shed = engine.serve_stream(
        reqs, max_slots=2, step_hook=chk, prefill_chunk_tokens=4,
        preempt_policy="lowest_priority", max_queue=8,
        deadline_aware=True, return_shed=True)
    chk.finish(completed, shed, reqs)
    assert chk.steps >= 200, f"trace too short: {chk.steps} steps"
    assert chk.max_occupancy == 2
    assert chk.saw_prefilling, "chunked prefill never engaged"
    assert sum(chk.preemptions.values()) >= 1, \
        "the trace never exercised preemption"
    assert shed, "the trace never exercised shedding"
    # preempted requests are never shed: they were admitted and must finish
    assert set(chk.preemptions) <= {r.rid for r in completed}


def test_chunked_prefill_token_parity(engine):
    """Chunked prefill is a pure scheduling change: the same trace served
    with and without a chunk budget yields identical tokens, and both match
    solo generation."""
    reqs = sched.synthetic_workload(6, seed=21, prompt_lens=(3, 9, 17),
                                    new_tokens=(2, 4), arrival_rate=0.6,
                                    vocab=engine.cfg.vocab_size)
    plain = {r.rid: r.tokens for r in engine.serve_stream(reqs)}
    for chunk in (4, 5):                    # aligned and ragged boundaries
        chunked = {r.rid: r for r in engine.serve_stream(
            reqs, prefill_chunk_tokens=chunk)}
        for r in reqs:
            np.testing.assert_array_equal(
                chunked[r.rid].tokens, plain[r.rid],
                err_msg=f"rid {r.rid} chunk={chunk}")
    long_req = max(reqs, key=lambda r: r.prompt_len)
    solo = engine.generate(jnp.asarray(np.asarray(long_req.tokens))[None],
                           long_req.n_new)
    np.testing.assert_array_equal(plain[long_req.rid], np.asarray(solo)[0])


def test_preempted_request_resumes_bit_exact(engine):
    """A preempted lane (evicted mid-decode, requeued, re-prefilled with
    its emitted tokens) finishes with exactly the tokens of its solo run."""
    rng = np.random.default_rng(0)
    toks = lambda n: rng.integers(0, engine.cfg.vocab_size, n,
                                  dtype=np.int64)
    reqs = [
        # two low-priority long decodes fill both slots at step 0 ...
        sched.Request(0, toks(4), 10, arrival=0, priority=0),
        sched.Request(1, toks(4), 10, arrival=0, priority=0),
        # ... then a high-priority arrival forces a preemption
        sched.Request(2, toks(4), 2, arrival=2, priority=5),
    ]
    completed, shed = engine.serve_stream(
        reqs, max_slots=2, preempt_policy="lowest_priority",
        return_shed=True)
    assert not shed
    res = {r.rid: r for r in completed}
    assert sum(r.preemptions for r in completed) >= 1, \
        "no preemption happened"
    for r in reqs:
        solo = engine.generate(
            jnp.asarray(np.asarray(r.tokens))[None], r.n_new)
        np.testing.assert_array_equal(res[r.rid].tokens,
                                      np.asarray(solo)[0],
                                      err_msg=f"rid {r.rid}")


def test_admission_control_sheds_with_named_reasons(engine):
    """queue_full fires on a bounded queue under burst arrivals;
    deadline_unmeetable fires on a deadline no admission could meet.
    Reason-named counters in the obs snapshot move for both."""
    def ctr(name):
        return obs.snapshot(include_views=False)["counters"].get(name, 0)
    before_qf = ctr("sched.shed.queue_full")
    before_dl = ctr("sched.shed.deadline_unmeetable")
    rng = np.random.default_rng(1)
    toks = lambda n: rng.integers(0, engine.cfg.vocab_size, n,
                                  dtype=np.int64)
    reqs = [sched.Request(i, toks(4), 6, arrival=0) for i in range(8)]
    # rid 8: a deadline even immediate admission cannot meet — it arrives
    # after the step-0 burst so the bounded queue has room and the shed
    # reason is the deadline, not the overflow
    reqs.append(sched.Request(8, toks(8), 8, arrival=2, deadline_ms=1.0))
    completed, shed = engine.serve_stream(
        reqs, max_slots=2, max_queue=3, deadline_aware=True,
        return_shed=True)
    reasons = {s.rid: s.reason for s in shed}
    assert reasons.get(8) == "deadline_unmeetable"
    assert "queue_full" in set(reasons.values())
    assert len(completed) + len(shed) == len(reqs)
    assert ctr("sched.shed.queue_full") > before_qf
    assert ctr("sched.shed.deadline_unmeetable") > before_dl


def test_overload_workload_shapes():
    """synthetic_workload's overload extensions: rate > 1 packs arrivals
    tighter than service, weights skew lengths, deadlines/priorities attach
    — all under the same seed contract (old signature bit-identical)."""
    old = sched.synthetic_workload(16, seed=4, arrival_rate=0.5)
    again = sched.synthetic_workload(16, seed=4, arrival_rate=0.5)
    assert [r.arrival for r in old] == [r.arrival for r in again]
    assert all(r.priority == 0 and r.deadline_ms is None for r in old)
    hot = sched.synthetic_workload(
        64, seed=4, arrival_rate=3.0, prompt_lens=(2, 16),
        prompt_len_weights=(0.9, 0.1), deadlines_ms=(5, None),
        priorities=(0, 1))
    hot2 = sched.synthetic_workload(
        64, seed=4, arrival_rate=3.0, prompt_lens=(2, 16),
        prompt_len_weights=(0.9, 0.1), deadlines_ms=(5, None),
        priorities=(0, 1))
    assert [r.arrival for r in hot] == [r.arrival for r in hot2]
    assert [r.priority for r in hot] == [r.priority for r in hot2]
    assert [r.deadline_ms for r in hot] == [r.deadline_ms for r in hot2]
    # rate 3.0 packs ~3 arrivals per step; span well under n_requests
    assert hot[-1].arrival < 40
    assert sum(r.prompt_len == 2 for r in hot) > sum(
        r.prompt_len == 16 for r in hot)
    assert {r.priority for r in hot} == {0, 1}
    assert {r.deadline_ms for r in hot} <= {5.0, None}
    with pytest.raises(ValueError):
        sched.synthetic_workload(2, prompt_len_weights=(1.0,))
    with pytest.raises(ValueError):
        sched.synthetic_workload(2, priorities=())


def test_preempt_policy_validation(engine):
    with pytest.raises(ValueError, match="preempt_policy"):
        sched.Scheduler(engine, preempt_policy="steal_everything")
    with pytest.raises(ValueError, match="prefill_chunk_tokens"):
        sched.Scheduler(engine, prefill_chunk_tokens=0)
    with pytest.raises(ValueError, match="max_queue"):
        sched.Scheduler(engine, max_queue=0)


# ------------------------------------------------------------- degradation --
def test_stream_decode_fault_degrades_not_drops(engine):
    """A decode-step fault mid-stream re-runs on the plain-jnp rung: every
    request still completes with parity and the in-flight ones are counted
    degraded (the chaos suite covers the full matrix)."""
    from repro.testing import faults
    reqs = sched.synthetic_workload(4, seed=9, prompt_lens=(4,),
                                    new_tokens=(4,), arrival_rate=1.0,
                                    vocab=engine.cfg.vocab_size)
    clean = {r.rid: r.tokens for r in engine.serve_stream(reqs)}
    before = engine.degraded_requests
    rule = faults.FaultRule("engine.decode", "error", after=1, times=1)
    try:
        with faults.inject(rule):
            res = engine.serve_stream(reqs)
    finally:
        faults.clear()
    assert rule.fired == 1
    assert len(res) == len(reqs)
    for r in res:
        np.testing.assert_array_equal(r.tokens, clean[r.rid])
    n_deg = sum(1 for r in res if r.degraded)
    assert n_deg >= 1
    assert engine.degraded_requests == before + n_deg
