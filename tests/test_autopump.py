"""Automatic-application tests: the §3 pipeline end to end (autopump) and
the grouped expert GEMM kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autopump, BUILDERS, VMEM_BYTES
from repro.core.ir import PumpSpec
from repro.kernels import ops, ref
import repro.kernels.grouped_gemm as gg_mod


# ---------------------------------------------------------------- autopump --
@pytest.mark.parametrize("kernel,args", [
    ("vecadd", (4096,)),
    ("matmul", (512, 512, 512)),
    ("stencil", (18, 16, 16)),
    ("floyd_warshall", (128,)),
    ("flash_attention", (1, 4, 128, 1024, 64)),
    ("ssd_scan", (1, 4096, 8, 64, 128)),
    ("grouped_gemm", (8, 256, 512, 256)),
])
def test_autopump_runs_full_pipeline(kernel, args):
    r = autopump(kernel, *args)
    assert r.spec.factor >= 1
    if r.spec.factor > 1:
        assert r.pump_report is not None and r.pump_report.applied
        # adapters were injected (sync/issuer/packer)
        assert r.graph.resources()["adapters"] > 0
    # streaming happened for every memory edge
    assert len(r.streaming_report.streamed) >= 2


def test_autopump_respects_vmem_budget():
    # a budget too small for even a double-width transaction forces M=1
    r = autopump("matmul", 512, 512, 512, vmem_budget=1024)
    assert r.spec.factor == 1


def test_autopump_mode_r_divisibility():
    r = autopump("vecadd", 4096, vector_width=8, mode="R", max_factor=16)
    assert r.spec.factor <= 8 and 8 % max(r.spec.factor, 1) == 0


def test_autopump_unknown_kernel():
    with pytest.raises(KeyError):
        autopump("nope", 1)


def test_autopump_spec_drives_kernel_correctly():
    r = autopump("matmul", 256, 256, 256, bm=64, bn=64, bk=32)
    a = jax.random.normal(jax.random.PRNGKey(0), (128, 96))
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 64))
    out = ops.matmul(a, b, bm=64, bn=64, bk=32, pump=r.spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(a, b)),
                               atol=1e-4)


# ------------------------------------------------------------ grouped gemm --
@pytest.mark.parametrize("mode,m", [("T", 1), ("T", 2), ("T", 4), ("R", 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm(mode, m, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 40, 48), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 48, 24), dtype)
    out = ops.grouped_gemm(x, w, bc=16, bf=8, bd=8,
                           pump=PumpSpec(factor=m, mode=mode))
    gold = ref.grouped_gemm(x, w)
    atol = 0.5 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), atol=atol)


def test_grouped_gemm_transaction_semantics():
    base = gg_mod.transactions(8, 128, 256, 128)
    assert gg_mod.transactions(8, 128, 256, 128, pump=PumpSpec(2, "T")) \
        == base // 2
    assert gg_mod.transactions(8, 128, 256, 128, pump=PumpSpec(2, "R")) \
        == base


def test_grouped_gemm_matches_moe_expert_einsum():
    """The kernel computes exactly the einsum moe_apply uses."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 32))
    w = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 8))
    gold = jnp.einsum("ecd,edf->ecf", x, w)
    out = ops.grouped_gemm(x, w, bc=8, bf=8, bd=8, pump=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=1e-4)
