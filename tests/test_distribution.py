"""Distribution-layer tests: sharding rules, HLO collective parser,
input specs, and a small real-mesh lower/compile (8 fake devices via
subprocess isolation is avoided — tests run divisibility-safe on 1 device).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, load_arch
from repro.launch import sharding as shard_mod
from repro.launch import steps as steps_mod
from repro.launch.dryrun import collective_bytes
from repro import optim


def host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


# ----------------------------------------------------------- rule fitting --
def test_fit_drops_nondividing_axes():
    mesh = host_mesh()
    spec = shard_mod._fit(P("data", "model"), (3, 5), mesh)
    assert spec == P(None, None)   # 1-device mesh: everything replicates


def test_param_specs_cover_all_leaves():
    from repro.models import model as model_mod
    for arch in ("qwen3-0.6b", "deepseek-v2-lite-16b", "mamba2-1.3b",
                 "zamba2-2.7b", "whisper-base", "internvl2-2b"):
        cfg = load_arch(arch, smoke=True)
        params = jax.eval_shape(
            lambda k: model_mod.init_params(cfg, k), jax.random.PRNGKey(0))
        specs = shard_mod.param_specs(params)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= leaf.ndim, (leaf.shape, spec)


def test_embedding_and_mlp_rules():
    specs = shard_mod.param_specs(
        {"embed": {"embedding": jax.ShapeDtypeStruct((1024, 64), jnp.float32)},
         "mlp": {"down": {"w": jax.ShapeDtypeStruct((256, 64), jnp.float32)},
                 "up": {"w": jax.ShapeDtypeStruct((64, 256), jnp.float32)}}})
    assert specs["embed"]["embedding"] == P("model", "data")
    assert specs["mlp"]["down"]["w"] == P("model", "data")   # row-parallel
    assert specs["mlp"]["up"]["w"] == P("data", "model")     # col-parallel


def test_cache_specs_head_vs_sequence_sharding():
    mesh = host_mesh()
    cache = {"k": jax.ShapeDtypeStruct((2, 4, 8, 16, 32), jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((2, 4, 8, 16, 32), jnp.bfloat16),
             "pos": jax.ShapeDtypeStruct((2,), jnp.int32)}
    specs = shard_mod.cache_specs(cache, mesh)
    assert specs["pos"] == P()


# ------------------------------------------------------------- HLO parser --
def test_collective_bytes_parser():
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar = bf16[2,4,8]{2,1,0} all-reduce(%y), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %a2a = s32[10]{0} all-to-all(%w)
  %cp = f32[4,4]{1,0} collective-permute(%v)
  %dot = f32[8,8]{1,0} dot(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 2 * 4 * 8 * 2
    assert out["reduce-scatter"] == 64 * 4
    assert out["all-to-all"] == 10 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["count"] == 5


def test_collective_bytes_ignores_noncollectives():
    assert collective_bytes("%d = f32[8]{0} dot(%a, %b)")["count"] == 0


# ------------------------------------------------------------ input specs --
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "whisper-base",
                                  "internvl2-2b"])
def test_abstract_batch_shapes(arch):
    cfg = load_arch(arch)
    shape = SHAPES["train_4k"]
    batch = steps_mod.abstract_batch(cfg, shape)
    assert batch["tokens"].shape == (256, 4096)
    if cfg.family == "encdec":
        assert batch["frames"].shape == (256, cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm":
        assert batch["patches"].shape == (256, cfg.n_vision_tokens,
                                          cfg.d_vision)
    pumped = steps_mod.abstract_batch(cfg, shape, pump_factor=4)
    assert pumped["tokens"].shape == (4, 64, 4096)


def test_abstract_cache_matches_family():
    cfg = load_arch("mamba2-1.3b")
    cache = steps_mod.abstract_cache(cfg, SHAPES["decode_32k"])
    leaves = jax.tree_util.tree_leaves(cache)
    assert leaves  # ssm caches exist, no KV tensors of seq length
    assert all(l.shape[0] == cfg.n_layers for l in leaves
               if hasattr(l, "shape") and l.ndim > 1)


# ----------------------------------------------- end-to-end sharded lower --
def test_train_step_lowers_on_host_mesh():
    cfg = load_arch("qwen3-0.6b", smoke=True)
    mesh = host_mesh()
    optcfg = optim.AdamWConfig()
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("t", 64, 4, "train")
    step = steps_mod.make_train_step(cfg, optcfg, pump_factor=2)
    in_sh, out_sh, args = steps_mod.train_shardings(
        cfg, optcfg, mesh, shape, jnp.float32, pump_factor=2)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_mesh_factories():
    from repro.launch import mesh as mesh_mod
    m = mesh_mod.make_host_mesh()
    assert set(m.axis_names) == {"data", "model"}
    assert mesh_mod.dp_degree(m) >= 1
