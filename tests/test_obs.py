"""Tier-1 contract for ``repro.obs`` — the tracing/metrics/profiling spine.

Covers the properties the rest of the repo leans on: spans nest correctly
(including under exceptions), the Chrome-trace export is valid Perfetto
input, metrics snapshots are pure JSON and round-trip, the cache health
counters fire on corruption/staleness, and StepTimer's percentile stats are
views over the obs histogram (one percentile implementation, not two).
"""
import json
import threading
import time

import pytest

from repro import obs
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


@pytest.fixture()
def tracer():
    """Fresh enabled tracer installed as the process tracer."""
    tr = Tracer(enabled=True)
    old = obs.set_tracer(tr)
    yield tr
    obs.set_tracer(old)


@pytest.fixture()
def metrics():
    """Fresh metrics registry installed as the process default."""
    reg = MetricsRegistry()
    old = obs.set_default_metrics(reg)
    yield reg
    obs.set_default_metrics(old)


# --------------------------------------------------------------- tracing ----
def test_spans_nest_with_parent_and_depth(tracer):
    with obs.span("outer", cat="t", a=1):
        with obs.span("inner"):
            time.sleep(0.001)

    by_name = {r["name"]: r for r in tracer.spans()}
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["depth"] == 0 and outer["parent"] is None
    assert inner["depth"] == 1 and inner["parent"] == "outer"
    assert outer["args"] == {"a": 1}
    # time containment: the child interval lies inside the parent's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["dur"] >= 1000.0  # slept 1ms; ts/dur are microseconds


def test_spans_record_and_unwind_on_exception(tracer):
    with pytest.raises(ValueError):
        with obs.span("outer"):
            with obs.span("boom"):
                raise ValueError("x")

    boom = tracer.spans("boom")[0]
    outer = tracer.spans("outer")[0]
    assert boom["args"]["error"] == "ValueError"
    assert outer["args"]["error"] == "ValueError"
    assert boom["parent"] == "outer" and boom["depth"] == 1

    # the thread-local stack fully unwound: a later span is a root again
    with obs.span("after"):
        pass
    after = tracer.spans("after")[0]
    assert after["depth"] == 0 and after["parent"] is None


def test_mid_span_attrs_and_instants(tracer):
    with obs.span("work") as sp:
        sp.set(factor=4)
        obs.instant("tick", n=1)
    rec = tracer.spans("work")[0]
    assert rec["args"]["factor"] == 4
    events = [r for r in tracer.records if r["type"] == "event"]
    assert events and events[0]["name"] == "tick"


def test_disabled_tracer_is_noop_and_shared(tracer):
    tracer.enabled = False
    handle = obs.span("never")
    with handle as sp:
        sp.set(anything=1)  # must not raise on the null handle
    assert obs.span("never2") is handle  # one shared null object
    obs.instant("never3")
    assert tracer.records == []


def test_spans_carry_distinct_tids_across_threads(tracer):
    def work():
        with obs.span("child_thread"):
            pass

    t = threading.Thread(target=work)
    with obs.span("main_thread"):
        t.start()
        t.join()
    tids = {r["name"]: r["tid"] for r in tracer.spans()}
    assert tids["main_thread"] != tids["child_thread"]
    # a thread's first span is a root on its own stack, not a child of main
    child = tracer.spans("child_thread")[0]
    assert child["depth"] == 0 and child["parent"] is None


def test_chrome_trace_export_is_valid(tracer, tmp_path):
    with obs.span("outer", cat="serve", k="v"):
        with obs.span("inner"):
            pass
    obs.instant("hit", kind="cache")

    path = tmp_path / "trace.json"
    obs.write_trace(path, metadata={"run": "test"})
    trace = json.loads(path.read_text())  # must be parseable JSON

    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"] == {"run": "test"}
    events = trace["traceEvents"]
    assert len(events) == 3
    for e in events:
        assert {"name", "cat", "ph", "ts", "pid", "tid", "args"} <= set(e)
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        else:
            assert e["ph"] == "i" and e["s"] == "t"
    assert {e["ph"] for e in events} == {"X", "i"}


def test_jsonl_event_log(tracer, tmp_path):
    with obs.span("a"):
        pass
    obs.instant("b")
    path = tmp_path / "events.jsonl"
    tracer.write_jsonl(path)
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["type"] for r in lines] == ["span", "event"]


# --------------------------------------------------------------- metrics ----
def test_metrics_snapshot_roundtrips(metrics):
    obs.count("c.hits", 3)
    obs.gauge("g.frac", 0.5)
    for v in (1.0, 2.0, 3.0):
        obs.observe("h.lat_s", v)

    snap = obs.snapshot()
    assert snap["counters"]["c.hits"] == 3
    assert snap["gauges"]["g.frac"] == 0.5
    h = snap["histograms"]["h.lat_s"]
    assert h["count"] == 3 and h["min"] == 1.0 and h["max"] == 3.0
    assert h["p50"] == 2.0

    # pure JSON: survives a serialize→parse cycle unchanged
    assert json.loads(json.dumps(snap)) == snap

    metrics.reset()
    assert obs.snapshot()["counters"] == {}


def test_histogram_percentiles_and_compaction():
    h = obs.Histogram(max_samples=64)
    for v in range(1, 101):
        h.record(float(v))
    # count/total/min/max stay exact through compaction
    assert h.count == 100 and h.total == sum(range(1, 101))
    assert h.min == 1.0 and h.max == 100.0
    assert len(h.values) <= 64
    # nearest-rank percentiles over the retained sample stay ordered and
    # in-range
    p50, p99 = h.percentile(50), h.percentile(99)
    assert 1.0 <= p50 <= p99 <= 100.0
    assert 30.0 <= p50 <= 70.0


def test_views_absorb_existing_stat_objects(metrics):
    obs.register_view("good", lambda: {"hits": 1})
    obs.register_view("bad", lambda: 1 / 0)
    snap = obs.snapshot()
    assert snap["views"]["good"] == {"hits": 1}
    # a broken view degrades to an error entry, never breaks the snapshot
    assert "error" in snap["views"]["bad"]
    assert json.loads(json.dumps(snap)) == snap


def test_count_emits_instant_when_tracing(tracer, metrics):
    obs.count("cache.hit", key="k")
    assert metrics.counter("cache.hit").value == 1
    events = [r for r in tracer.records if r["type"] == "event"]
    assert events[0]["name"] == "cache.hit"
    assert events[0]["args"] == {"key": "k"}


def test_formatters(metrics):
    obs.count("cache.hit", 2)
    obs.observe("serve.decode_step_s", 0.001)
    text = obs.format_snapshot(obs.snapshot())
    assert "cache.hit" in text and "serve.decode_step_s" in text
    assert "p99" in text

    phases = {"decode": {"compile_s": 0.5, "warm": {
        "calls": 3, "mean_s": 0.001, "p50_s": 0.001, "p99_s": 0.002,
        "best_s": 0.0009}}}
    lines = obs.format_phases(phases)
    assert "decode" in lines and "p99=2.00ms" in lines and "3 steps" in lines


# ----------------------------------------------------- cache health events --
def test_cache_corrupt_counter(metrics, tmp_path):
    from repro.compiler.cache import CompileCache

    path = tmp_path / "cache.json"
    path.write_text("{ this is not json")
    cache = CompileCache(path)
    assert cache.get("k") is None  # degrade contract unchanged
    assert metrics.counter("cache.corrupt").value == 1


def test_cache_stale_jax_version_counter(metrics, tmp_path):
    from repro.compiler.cache import CompileCache, _env_fingerprint

    path = tmp_path / "cache.json"
    cache = CompileCache(path)
    cache.put("fresh", {"factor": 2})       # stamped with the live env
    entries = json.loads(path.read_text())
    entries["entries"]["old"] = {"factor": 4, "env": "jax-0.0.0-older"}
    path.write_text(json.dumps(entries))

    reread = CompileCache(path)
    assert reread.get("fresh")["factor"] == 2
    assert reread.get("fresh")["env"] == _env_fingerprint()
    assert metrics.counter("cache.stale_jax_version").value == 1
    assert metrics.counter("cache.corrupt").value == 0


# ---------------------------------------------------------------- timers ----
def test_steptimer_warm_cold_split_and_percentiles():
    from repro.launch.steps import StepTimer

    timer = StepTimer()
    for _ in range(6):
        timer.run("decode", lambda: time.sleep(0.001))
    st = timer.stats()["decode"]

    # legacy flat keys survive (compat with older BENCH_* consumers)
    assert st["steps"] == 5 and st["compile_s"] > 0
    assert st["steady_mean_s"] is not None
    # explicit warm/cold split + percentiles
    assert st["cold"]["calls"] == 1
    assert st["cold"]["total_s"] == st["compile_s"]
    assert st["warm"]["calls"] == 5
    assert st["warm"]["p50_s"] <= st["warm"]["p99_s"]
    assert st["steady_p50_s"] == st["warm"]["p50_s"]
    assert st["steady_p99_s"] == st["warm"]["p99_s"]
    assert timer.steady["decode"]  # compat view over the histogram samples


# --------------------------------------------------------------- profile ----
def test_profile_without_logdir_is_a_plain_span(tracer):
    with obs.profile("window", tag="x"):
        pass
    rec = tracer.spans("window")[0]
    assert rec["cat"] == "profile"
    assert rec["args"]["profiled"] is False and rec["args"]["tag"] == "x"


# ------------------------------------------------- end-to-end serve trace ----
def test_engine_generate_produces_nested_trace(tracer, metrics, tmp_path,
                                               monkeypatch):
    """One Engine.generate() yields warmup/prefill/per-token decode spans
    with monotonic timestamps, TTFT on the generate span, and latency
    histograms in the metrics snapshot."""
    import jax
    import jax.numpy as jnp
    from repro.compiler.registry import PlanRegistry, set_default_registry
    from repro.configs.base import load_arch
    from repro.models import model as model_mod
    from repro.serve.engine import Engine, ServeConfig

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    prev = set_default_registry(PlanRegistry())
    try:
        cfg = load_arch("qwen3-0.6b", smoke=True)
        params = model_mod.init_params(cfg, jax.random.PRNGKey(0),
                                       dtype=jnp.float32)
        eng = Engine(cfg, params, ServeConfig(batch=2, max_len=16))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                     cfg.vocab_size)
        eng.generate(prompts, 3)
    finally:
        set_default_registry(prev)

    gen = tracer.spans("serve.generate")[0]
    assert tracer.spans("serve.prefill")
    decodes = sorted(tracer.spans("serve.decode"), key=lambda r: r["ts"])
    assert len(decodes) == 3
    for d in decodes:
        assert d["parent"] == "serve.generate" and d["depth"] == 1
        assert gen["ts"] <= d["ts"]
        assert d["ts"] + d["dur"] <= gen["ts"] + gen["dur"]
    assert all(a["ts"] + a["dur"] <= b["ts"]
               for a, b in zip(decodes, decodes[1:]))
    assert gen["args"]["ttft_s"] > 0

    snap = obs.snapshot()
    assert snap["counters"]["serve.tokens"] == 6
    assert snap["histograms"]["serve.ttft_s"]["count"] == 1
    assert snap["histograms"]["serve.decode_step_s"]["count"] == 3
    # the engine's stats are published as a snapshot view
    assert snap["views"]["serve.engine"]["phases"]["decode"]["steps"] >= 1


def test_scheduler_metrics_on_two_rate_trace(metrics, tmp_path, monkeypatch):
    """Satellite: obs metrics under concurrency.  The same synthetic
    workload streamed at a bursty vs a trickle arrival rate must emit sane
    scheduler metrics: the slot-occupancy gauge never exceeds max_slots
    (and drains to 0), the queue-wait histogram records every request, and
    waits are monotone with arrival rate — the bursty trace queues at
    least as hard as the trickle."""
    import dataclasses
    import jax
    import numpy as np
    from repro.configs.base import load_arch
    from repro.models import model as model_mod
    from repro.serve import scheduler as sched
    from repro.serve.engine import Engine, ServeConfig

    cfg = dataclasses.replace(load_arch("qwen3-0.6b", smoke=True),
                              attention_impl="xla_chunked",
                              kernel_plan="direct")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=16, warmup=False))

    def run(rate):
        reqs = sched.synthetic_workload(8, seed=4, prompt_lens=(2, 4),
                                        new_tokens=(2, 4), arrival_rate=rate,
                                        vocab=cfg.vocab_size)
        occs = []
        before = metrics.histogram("sched.queue_wait_steps").count
        res = eng.serve_stream(
            reqs, step_hook=lambda s: occs.append(s["occupancy"]))
        h = metrics.histogram("sched.queue_wait_steps")
        waits = [r.queue_wait_steps for r in res]
        return occs, waits, h.count - before

    occ_burst, waits_burst, n_burst = run(1.0)     # all arrive at step 0
    occ_slow, waits_slow, n_slow = run(0.2)

    for occs in (occ_burst, occ_slow):
        assert all(0 <= o <= 2 for o in occs), "occupancy exceeded max_slots"
    assert max(occ_burst) == 2, "the burst never filled the slots"
    # the gauge drained with the stream
    snap = obs.snapshot(include_views=False)
    assert snap["gauges"]["sched.slot_occupancy"] == 0
    assert snap["gauges"]["sched.queue_depth"] == 0
    # one histogram sample per admitted request, none dropped
    assert n_burst == 8 and n_slow == 8
    # monotone with arrival rate: the burst queues at least as hard
    assert np.mean(waits_burst) >= np.mean(waits_slow)
    assert max(waits_burst) >= max(waits_slow)
    assert max(waits_burst) > 0, "the burst never exercised the queue"
    # per-request latency histograms populated alongside
    assert metrics.histogram("serve.request_ttft_s").count == 16
    assert metrics.histogram("serve.request_tpot_s").count == 16
