"""Decode hot-path tests: the kernelized S=1 attention step (position-offset
mask, pos-bucketed plans) and the SSD final-state / single-token decode
routes — parity against the plain-jnp references and the numpy executor at
the exp-bearing carry tolerance (5e-6, see tests/differential.py), plus the
registry-level serving contracts (phase-split stats, warmup warning dedupe,
pos bucketing)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.registry import (PlanRegistry, default_registry,
                                     set_default_registry)
from repro.configs.base import load_arch


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    old = set_default_registry(None)
    yield
    set_default_registry(old)


def _ints(shape, seed=0, lo=-2, hi=3):
    return jnp.asarray(np.random.default_rng(seed).integers(
        lo, hi, shape).astype(np.float32))


def _gqa_setup(max_len=32, b=2):
    cfg = dataclasses.replace(load_arch("qwen3-0.6b", smoke=True),
                              attention_impl="pallas")
    p = {}
    from repro.models import attention as attn_mod
    p = attn_mod.gqa_init(jax.random.PRNGKey(0), cfg)
    kshape = (b, cfg.n_kv_heads, max_len, cfg.head_dim_)
    cache = {"k": _ints(kshape, 1), "v": _ints(kshape, 2)}
    x1 = _ints((b, 1, cfg.d_model), 3)
    return cfg, p, cache, x1


# ----------------------------------------------------- decode parity sweep --
@pytest.mark.parametrize("pos", [0, 1, 15, 16, 31])
def test_decode_attention_parity_sweep(pos):
    """Kernelized decode (registry route) vs the full-recompute jnp
    reference at pos = fresh cache, one token, both sides of a bucket
    boundary (15 -> 16, 16 -> 32), and cache-full."""
    set_default_registry(PlanRegistry(pump=1, cache=False))
    from repro.models import attention as attn_mod
    cfg, p, cache, x1 = _gqa_setup(max_len=32)
    cfg_dir = dataclasses.replace(cfg, kernel_plan="direct")
    cc = dict(cache, pos=jnp.asarray(pos, jnp.int32))
    positions = jnp.array([pos])
    o_kern, _ = attn_mod.gqa_apply(p, cfg, x1, positions=positions,
                                   cache=dict(cc))
    o_ref, _ = attn_mod.gqa_apply(p, cfg_dir, x1, positions=positions,
                                  cache=dict(cc))
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(o_ref),
                               rtol=5e-6, atol=5e-6)


def test_decode_attention_buckets_on_pos():
    """A concrete decode position attends only the pos bucket of the cache:
    the resident plan is keyed on bucket_seq(pos + 1), not max_len."""
    reg = PlanRegistry(pump=1, cache=False)
    set_default_registry(reg)
    from repro.models import attention as attn_mod
    cfg, p, cache, x1 = _gqa_setup(max_len=64)
    for pos, want_t in ((3, 16), (20, 32)):
        cc = dict(cache, pos=jnp.asarray(pos, jnp.int32))
        attn_mod.gqa_apply(p, cfg, x1, positions=jnp.array([pos]),
                           cache=dict(cc))
    plans = [pl for pl in reg.plans() if pl["kernel"] == "decode_attention"]
    assert sorted(pl["args"][2] for pl in plans) == [16, 32]


def test_decode_attention_traced_pos_keys_full_cache_bucket():
    """Inside a jit trace pos is unknowable, so the decode plan keys on the
    preallocated cache length — one plan, warmable at launch — and the
    kernel's mask keeps parity with the eager reference."""
    reg = PlanRegistry(pump=1, cache=False)
    set_default_registry(reg)
    from repro.models import attention as attn_mod
    cfg, p, cache, x1 = _gqa_setup(max_len=32)
    cfg_dir = dataclasses.replace(cfg, kernel_plan="direct")
    positions = jnp.array([7])

    @jax.jit
    def step(cc, xx):
        out, _ = attn_mod.gqa_apply(p, cfg, xx, positions=positions,
                                    cache=cc)
        return out

    cc = dict(cache, pos=jnp.asarray(7, jnp.int32))
    o_jit = step(dict(cc), x1)
    o_ref, _ = attn_mod.gqa_apply(p, cfg_dir, x1, positions=positions,
                                  cache=dict(cc))
    np.testing.assert_allclose(np.asarray(o_jit), np.asarray(o_ref),
                               rtol=5e-6, atol=5e-6)
    [plan] = [pl for pl in reg.plans() if pl["kernel"] == "decode_attention"]
    assert plan["args"][2] == 32          # bucket_seq(max_len)


# ------------------------------------------------- SSD final state / decode --
def test_ssd_final_state_matches_numpy_executor():
    """The final-state output of the SSD builder is the carry state the
    numpy executor threads — across both lowering backends."""
    from repro import compiler
    from repro.core import executor
    from repro.core.autopump import BUILDERS
    rng = np.random.default_rng(5)
    inputs = {"x": rng.integers(-2, 3, (2, 16, 2, 4)).astype(np.float32),
              "dt": np.abs(rng.integers(0, 3, (2, 16, 2))) * 0.25 + 0.25,
              "a": -(np.abs(rng.integers(0, 3, (2,))) * 0.25 + 0.25),
              "bmat": rng.integers(-2, 3, (2, 16, 2, 4)).astype(np.float32),
              "cmat": rng.integers(-2, 3, (2, 16, 2, 4)).astype(np.float32)}
    inputs = {k: np.asarray(v, np.float32) for k, v in inputs.items()}
    for backend in ("jax", "pallas"):
        g, _ = BUILDERS["ssd_scan"](2, 16, 2, 4, 4, chunk=4,
                                    final_state=True)
        kern = compiler.compile(g, factor=2, backend=backend, cache=False,
                                memoize=False)
        out = kern(inputs)
        gold = executor.run(kern.graph, dict(inputs))
        for name in ("y", "state"):
            np.testing.assert_allclose(
                np.asarray(out[name]), gold[name], rtol=5e-6, atol=5e-6,
                err_msg=f"{name} ({backend})")


def test_ssd_cached_prefill_final_state_matches_xla():
    """Cached SSM prefill through the final-state kernel (measure route)
    matches the _ssd_xla reference — y and the decode state both."""
    set_default_registry(PlanRegistry(pump=1, cache=False))
    from repro.models import ssm as ssm_mod
    cfg = dataclasses.replace(load_arch("mamba2-1.3b", smoke=True),
                              ssm_impl="pallas")
    cfg_dir = dataclasses.replace(cfg, kernel_plan="direct")
    p = ssm_mod.mamba2_init(jax.random.PRNGKey(1), cfg)
    cache0 = ssm_mod.mamba2_cache_init(cfg, 2, jnp.float32)
    x = _ints((2, 16, cfg.d_model), 7)
    y_kern, nc_kern = ssm_mod.mamba2_apply(p, cfg, x, cache=dict(cache0))
    y_ref, nc_ref = ssm_mod.mamba2_apply(p, cfg_dir, x, cache=dict(cache0))
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_ref),
                               rtol=5e-6, atol=5e-6)
    np.testing.assert_allclose(np.asarray(nc_kern["state"]),
                               np.asarray(nc_ref["state"]),
                               rtol=2e-5, atol=5e-6)


def test_ssd_decode_step_matches_jnp_reference():
    set_default_registry(PlanRegistry(pump=1, cache=False))
    from repro.models import ssm as ssm_mod
    cfg = dataclasses.replace(load_arch("mamba2-1.3b", smoke=True),
                              ssm_impl="pallas")
    cfg_dir = dataclasses.replace(cfg, kernel_plan="direct")
    p = ssm_mod.mamba2_init(jax.random.PRNGKey(1), cfg)
    cache0 = ssm_mod.mamba2_cache_init(cfg, 2, jnp.float32)
    cache = dict(cache0, state=_ints(cache0["state"].shape, 4),
                 conv=_ints(cache0["conv"].shape, 5))
    x1 = _ints((2, 1, cfg.d_model), 6)
    y_kern, nc_kern = ssm_mod.mamba2_apply(p, cfg, x1, cache=dict(cache))
    y_ref, nc_ref = ssm_mod.mamba2_apply(p, cfg_dir, x1, cache=dict(cache))
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_ref),
                               rtol=5e-6, atol=5e-6)
    np.testing.assert_allclose(np.asarray(nc_kern["state"]),
                               np.asarray(nc_ref["state"]),
                               rtol=5e-6, atol=5e-6)


# --------------------------------------------------- registry serving glue --
def test_registry_stats_split_decode_from_prefill():
    """Decode-kernel lookups are counted under their own phase so a cold
    decode bucket is visible in the serve printout at a glance."""
    reg = PlanRegistry(pump=1, cache=False)
    q = _ints((1, 2, 8), 1)
    kv = _ints((1, 2, 16, 8), 2)
    reg.decode_attention(q, kv, kv, 5)                    # miss
    reg.decode_attention(q, kv, kv, 6)                    # same bucket: hit
    reg.flash_attention(_ints((1, 2, 16, 8), 3), kv, kv, causal=True)
    d = reg.stats.as_dict()
    assert d["decode"] == {"hits": 1, "misses": 1, "fallbacks": 0}
    assert d["prefill"] == {"hits": 0, "misses": 1, "fallbacks": 0}
    assert d["hits"] == 1 and d["misses"] == 2


def test_warmup_surfaces_each_unique_compile_warning_once():
    """A bucket-grid warmup sweep re-compiles the same kernel per bucket;
    identical degradation warnings must print once per sweep, not once per
    compile."""
    reg = PlanRegistry(pump=2, cache=False)   # factor 2, no autotune
    # grouped B/C (n_groups < h) puts a table on the innermost grid symbol,
    # so mode-T splitting warns 'cannot split hi' for every bucket compiled
    reqs = [("ssd_decode", dict(b=b, h=4, p=8, n=4, n_groups=2,
                                dtype="float32")) for b in (1, 3)]
    with pytest.warns(UserWarning) as rec:
        report = reg.warmup(reqs)
    assert len(report) == 2 and reg.stats.misses == 2
    hits = [str(w.message) for w in rec
            if "cannot split" in str(w.message)]
    assert len(hits) == 1, hits


def test_decode_attention_per_row_positions_stay_kernelized():
    """A (B,) pos vector buckets on the furthest row and runs the kernel
    (no silent jnp fallback); each row's own mask cuts its prefix."""
    from repro.compiler.registry import _decode_reference
    reg = PlanRegistry(pump=1, cache=False)
    q = _ints((2, 2, 8), 1)
    kv = _ints((2, 2, 32, 8), 2)
    pos = jnp.asarray([3, 20], jnp.int32)
    out = reg.decode_attention(q, kv, kv, pos)
    assert reg.stats.fallbacks == 0
    [plan] = [pl for pl in reg.plans() if pl["kernel"] == "decode_attention"]
    assert plan["args"][2] == 32          # bucket_seq(max(pos) + 1)
    ref = _decode_reference(q, kv, kv, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-6, atol=5e-6)


def test_ssd_scan_final_state_fallback_degrades_not_crashes(monkeypatch):
    """A compile failure on the final-state route must degrade to the jnp
    recurrence (which does produce the state), not re-raise through the
    compiler-only ops entry."""
    reg = PlanRegistry(pump=1, cache=False)

    def boom(*a, **kw):
        raise RuntimeError("forced compile failure")

    monkeypatch.setattr(reg, "kernel", boom)
    x = _ints((1, 8, 2, 4), 1)
    rng = np.random.default_rng(2)
    dt = jnp.asarray(np.abs(rng.integers(0, 3, (1, 8, 2))) * 0.25 + 0.25,
                     dtype=jnp.float32)
    A = jnp.asarray(-(np.abs(rng.integers(0, 3, (2,))) * 0.25 + 0.25),
                    dtype=jnp.float32)
    B = _ints((1, 8, 2, 4), 3)
    C = _ints((1, 8, 2, 4), 4)
    with pytest.warns(UserWarning, match="plain jnp scan"):
        y, st = reg.ssd_scan(x, dt, A, B, C, chunk=4, final_state=True)
    assert reg.stats.fallbacks == 1
    from repro.kernels import ops
    y_ref, st_ref = ops.ssd_scan(x, dt, A, B, C, chunk=4, final_state=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-6, atol=5e-6)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=5e-6, atol=5e-6)


def test_engine_warms_decode_buckets():
    """The Engine's launch warmup covers the decode bucket grid: the jit'd
    decode step's trace-time lookups are pure hits."""
    from repro.models import model as model_mod
    from repro.serve.engine import Engine, ServeConfig
    set_default_registry(PlanRegistry(pump=1, cache=False))
    cfg = dataclasses.replace(load_arch("qwen3-0.6b", smoke=True),
                              attention_impl="pallas")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(batch=2, max_len=16))
    assert any(r["kernel"] == "decode_attention" for r in eng.warmup_report)
    reg = default_registry()
    before = reg.stats.phase["decode"]["misses"]
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    eng.generate(prompts, 3)
    assert reg.stats.phase["decode"]["misses"] == before
    assert reg.stats.phase["decode"]["hits"] >= 1
