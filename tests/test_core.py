"""Core IR + transformation tests, incl. hypothesis property tests.

Invariants under test (the paper's correctness claims):
  P1  streaming extraction is value-preserving
  P2  multi-pumping (either mode) is value-preserving
  P3  Mode T: throughput ×M at equal compute units
  P4  Mode R: compute units ÷M at equal throughput
  P5  effective-rate law: rate_eff = min(clk0, clk1/M)
  P6  legality: data-dependent external I/O is rejected; direct HBM access
      without streaming is rejected
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (AccessPattern, Affine, Domain, Graph, PumpSpec,
                        apply_multipump, apply_streaming, check_multipump,
                        effective_rate, executor, sequence_equivalent,
                        throughput_model)
from repro.core.pump_plan import (KernelEstimate, best_pump_factor,
                                  mxu_aligned_tile, plan_trainer_pump)


def vecadd_graph(n: int, v: int) -> Graph:
    g = Graph("vecadd")
    g.memory("x", (n,))
    g.memory("y", (n,))
    g.memory("z", (n,))
    dom = Domain.of(("i", 0, n // v))
    acc = AccessPattern(dom, (Affine.of("i", v),), width=v)
    g.compute("add", dom, fn=lambda in0, in1: {"out0": in0 + in1},
              vector_width=v)
    g.connect("x", "add", acc)
    g.connect("y", "add", acc)
    g.connect("add", "z", acc)
    return g


# -------------------------------------------------------------- symbolic ----
def test_affine_algebra():
    e = Affine.of("i", 3) + Affine.of("j", 2) + 5
    assert e.evaluate({"i": 2, "j": 1}) == 13
    assert (e * 2).evaluate({"i": 1, "j": 1}) == 20
    assert (e - e).evaluate({"i": 9, "j": 9}) == 0


def test_sequence_equivalence_detects_order_mismatch():
    dom = Domain.of(("i", 0, 4), ("j", 0, 4))
    row_major = AccessPattern(dom, (Affine.of("i"), Affine.of("j")))
    dom2 = Domain.of(("a", 0, 4), ("b", 0, 4))
    row_major2 = AccessPattern(dom2, (Affine.of("a"), Affine.of("b")))
    col_major = AccessPattern(dom, (Affine.of("j"), Affine.of("i")))
    assert sequence_equivalent(row_major, row_major2, (4, 4))
    assert not sequence_equivalent(row_major, col_major, (4, 4))


# ------------------------------------------------- P1/P2 value preservation --
@settings(max_examples=25, deadline=None)
@given(n_blocks=st.integers(2, 8), v=st.sampled_from([1, 2, 4]),
       m=st.sampled_from([2, 4]), mode=st.sampled_from(["T", "R"]),
       seed=st.integers(0, 2**31 - 1))
def test_streaming_and_pump_value_preserving(n_blocks, v, m, mode, seed):
    if mode == "R" and v % m:
        return
    n = n_blocks * v * m
    g = vecadd_graph(n, v)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    gold = x + y

    sg, rep = apply_streaming(g)
    assert len(rep.streamed) == 3 and not rep.rejected
    out_s = executor.run(sg, {"x": x, "y": y})["z"]
    np.testing.assert_allclose(out_s, gold, rtol=1e-6)     # P1

    pg, prep = apply_multipump(sg, factor=m, mode=mode)
    assert prep.applied, prep.reason
    out_p = executor.run(pg, {"x": x, "y": y})["z"]
    np.testing.assert_allclose(out_p, gold, rtol=1e-6)     # P2


# ----------------------------------------------------- P3/P4 resource model --
def test_mode_t_throughput_and_mode_r_resources():
    g, _ = apply_streaming(vecadd_graph(64, 4))
    base_tp = throughput_model(g)
    base_cu = g.resources()["compute_units"]

    tg, trep = apply_multipump(g, factor=2, mode="T")
    assert throughput_model(tg) == pytest.approx(2 * base_tp)       # P3
    assert tg.resources()["compute_units"] == base_cu

    rg, rrep = apply_multipump(g, factor=2, mode="R")
    assert throughput_model(rg) == pytest.approx(base_tp)           # P4
    assert rg.resources()["compute_units"] == base_cu // 2
    assert rrep.resource_ratio("compute_units") == pytest.approx(0.5)


# ------------------------------------------------------------- P5 rate law --
@settings(max_examples=50, deadline=None)
@given(clk0=st.floats(0.1, 10), ratio=st.floats(0.5, 8),
       m=st.integers(1, 8))
def test_effective_rate_law(clk0, ratio, m):
    clk1 = clk0 * ratio
    eff = effective_rate(clk0, clk1, m)
    assert eff <= clk0 + 1e-9
    assert eff <= clk1 / max(m, 1) + 1e-9                           # P5
    if clk1 / m >= clk0:
        assert eff == pytest.approx(clk0)


# -------------------------------------------------------------- P6 legality --
def test_multipump_rejects_data_dependent_io():
    g = Graph("gather")
    g.memory("idx", (16,))
    g.memory("x", (16,))
    g.memory("z", (16,))
    dom = Domain.of(("i", 0, 16))
    acc = AccessPattern(dom, (Affine.of("i"),))
    g.compute("gath", dom, vector_width=1, data_dependent_io=True)
    g.connect("idx", "gath", acc)
    g.connect("x", "gath", acc)
    g.connect("gath", "z", acc)
    sg, _ = apply_streaming(g)
    ok, why = check_multipump(sg, ["gath"], 2)
    assert not ok and "data-dependent" in why


def test_multipump_requires_streaming_first():
    g = vecadd_graph(32, 2)
    ok, why = check_multipump(g, ["add"], 2)
    assert not ok and "streaming" in why


def test_multipump_respects_vmem_budget():
    g, _ = apply_streaming(vecadd_graph(1 << 14, 1024))
    ok, why = check_multipump(g, ["add"], 4, vmem_budget=1024)
    assert not ok and "VMEM" in why


# ----------------------------------------------------------- pump planning --
def test_best_pump_factor_amortizes_fixed_overhead():
    # DMA-dominated kernel with large per-step overhead: pumping helps
    est = KernelEstimate(block_bytes_in=4096, block_bytes_out=4096,
                         flops_per_block=1e5, fixed_overhead_s=1e-5)
    assert best_pump_factor(est) > 1
    # compute-bound kernel with no overhead: pumping is neutral; planner
    # must not pick a factor that shrinks throughput
    est2 = KernelEstimate(block_bytes_in=64, block_bytes_out=64,
                          flops_per_block=1e9, fixed_overhead_s=0.0)
    m = best_pump_factor(est2)
    assert est2.throughput(m) >= est2.throughput(1) * 0.999


@settings(max_examples=30, deadline=None)
@given(bin_=st.integers(128, 1 << 20), bout=st.integers(0, 1 << 20),
       flops=st.floats(1e3, 1e12))
def test_pump_factor_never_violates_vmem(bin_, bout, flops):
    est = KernelEstimate(bin_, bout, flops)
    m = best_pump_factor(est, vmem_budget=1 << 22)
    assert 2 * m * (bin_ + bout) <= (1 << 22) or m == 1


def test_mxu_alignment():
    tm, tn = mxu_aligned_tile(300, 70)
    assert tm % 8 == 0 and tn % 128 == 0


def test_trainer_pump_scales_with_model_size():
    small = plan_trainer_pump(grad_bytes=int(1e8), step_flops=1e15,
                              n_chips=256, dp_degree=16)
    big = plan_trainer_pump(grad_bytes=int(1e12), step_flops=1e15,
                            n_chips=256, dp_degree=16)
    assert big >= small
