"""Tier-1 wiring for the offline tuner (`make tune-smoke`).

Covers the four layers of the fault-tolerant autotuning pipeline
(docs/robustness.md "Artifact lifecycle"):

* grid enumeration — deterministic, content-hash-deduped work groups;
* the lease ledger — claim/heartbeat/expiry-reclaim/complete semantics,
  driven with explicit clocks so the crash cases are exact, plus a real
  two-process SIGKILL: the survivor reclaims the dead worker's shard and
  the published artifact is complete and manifest-valid;
* the artifact — publish/load/verify round trip, partial-result salvage,
  and per-entry rejection (corrupt / stale) degrading to local re-measure;
* the replica — `ServeConfig.plan_artifact` warm start doing ZERO autotune
  measurements at warmup (the `make tune-smoke` acceptance).
"""
import dataclasses
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import obs
from repro.compiler.cache import CompileCache
from repro.compiler.registry import PlanRegistry, set_default_registry
from repro.configs.base import load_arch
from repro.models import model as model_mod
from repro.serve.engine import Engine, ServeConfig
from repro.testing import faults
from repro.tune import artifact as artifact_mod
from repro.tune import grid as grid_mod
from repro.tune.lease import LeaseLedger
from repro.tune.worker import run_fleet

ARCH = "qwen3-0.6b"
BATCH, MAXLEN = 2, 16


def _ctr(name: str) -> int:
    return obs.snapshot(include_views=False)["counters"].get(name, 0)


def _cfg():
    return dataclasses.replace(load_arch(ARCH, smoke=True),
                               attention_impl="pallas")


def _replica(artifact_path, cache_dir, monkeypatch) -> Engine:
    """Fresh-replica simulation: cold kernel memo, its own empty persistent
    cache, a fresh default registry, and the artifact preloaded at warmup."""
    from repro import compiler
    compiler.clear_memo()
    monkeypatch.setenv("REPRO_CACHE_DIR", str(cache_dir))
    set_default_registry(PlanRegistry())
    cfg = _cfg()
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params,
                  ServeConfig(batch=BATCH, max_len=MAXLEN,
                              plan_artifact=str(artifact_path)))


@pytest.fixture(autouse=True)
def _tune_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    old = set_default_registry(None)
    yield
    faults.clear()
    set_default_registry(old)


# ------------------------------------------------------------------- grid --
def test_grid_is_deterministic_and_deduped():
    cfg = _cfg()
    a = grid_mod.enumerate_work(cfg, BATCH, MAXLEN)
    b = grid_mod.enumerate_work(cfg, BATCH, MAXLEN)
    assert [g.key for g in a] == [g.key for g in b]
    assert a, "smoke grid enumerated no work"
    for g in a:
        # every member of a group shares the representative's content hash
        assert all(item.key == g.key for item in g.items)
        assert g.representative is g.items[0]
    # groups are distinct measurements
    assert len({g.key for g in a}) == len(a)


def test_grid_shards_partition_everything():
    groups = grid_mod.enumerate_work(_cfg(), BATCH, MAXLEN)
    shards = grid_mod.shard_groups(groups, 3)
    flat = [g.key for lst in shards.values() for g in lst]
    assert sorted(flat) == sorted(g.key for g in groups)
    keys = grid_mod.shard_keys(shards)
    assert set(keys) == set(shards)
    assert all(keys[s] == [g.key for g in shards[s]] for s in shards)


def test_grid_dedupes_equal_decode_buckets():
    """Two decode positions in the same bucket hash to one measurement."""
    from repro.compiler import measure_request_key
    from repro.core.autopump import BUILDERS
    reg = PlanRegistry()
    keys = []
    for t in (9, 12):      # both bucket to the same padded decode shape
        args, kwargs, _ = reg.decode_request(b=BATCH, h=2, hkv=1, t=t,
                                             d=16, dtype="float32")
        g, est = BUILDERS["decode_attention"](*args, **kwargs)
        keys.append(measure_request_key(g, est))
    assert keys[0] == keys[1]


# ------------------------------------------------------------------ lease --
def test_lease_claim_heartbeat_complete(tmp_path):
    led = LeaseLedger(tmp_path / "ledger.json", ttl_s=10.0)
    led.init_shards({"shard-0": ["k0"], "shard-1": ["k1"]})
    assert led.states() == {"pending": 2}

    got = led.claim("a", now=100.0)
    assert got == ("shard-0", ["k0"])
    assert led.claim("b", now=100.0) == ("shard-1", ["k1"])
    # nothing claimable while both leases are live
    assert led.claim("c", now=101.0) is None

    assert led.heartbeat("a", "shard-0", now=105.0) is True
    assert led.complete("a", "shard-0", now=106.0) is True
    assert led.complete("b", "shard-1", now=106.0) is True
    assert led.all_done()
    assert led.done_keys() == ["k0", "k1"]
    # init after completion is a no-op — finished work is never reopened
    led.init_shards({"shard-0": ["k0"], "shard-1": ["k1"]})
    assert led.states() == {"done": 2}


def test_lease_expiry_reclaim_blocks_double_publish(tmp_path):
    """The crash story with an explicit clock: worker a dies mid-lease,
    worker b reclaims after expiry, and a's late heartbeat/complete are
    rejected — the reclaimed shard can only be published once."""
    led = LeaseLedger(tmp_path / "ledger.json", ttl_s=10.0)
    led.init_shards({"shard-0": ["k0"]})
    assert led.claim("a", now=100.0) == ("shard-0", ["k0"])

    # before expiry the lease holds; at expiry it is claimable
    assert led.claim("b", now=105.0) is None
    reclaimed = _ctr("tune.lease_reclaimed")
    assert led.claim("b", now=110.5) == ("shard-0", ["k0"])
    assert _ctr("tune.lease_reclaimed") > reclaimed

    # the dead worker wakes up late: every mutation is rejected
    lost = _ctr("tune.lease_lost")
    assert led.heartbeat("a", "shard-0", now=111.0) is False
    assert led.complete("a", "shard-0", now=111.0) is False
    assert _ctr("tune.lease_lost") >= lost + 2
    # the new owner still completes normally
    assert led.complete("b", "shard-0", now=112.0) is True
    assert led.snapshot()["shard-0"]["attempts"] == 2


def test_lease_release_returns_shard_to_pool(tmp_path):
    led = LeaseLedger(tmp_path / "ledger.json", ttl_s=10.0)
    led.init_shards({"shard-0": ["k0"]})
    assert led.claim("a", now=100.0) is not None
    led.release("a", "shard-0")
    assert led.states() == {"pending": 1}
    assert led.claim("b", now=101.0) == ("shard-0", ["k0"])
    # release by a non-owner is a no-op
    led.release("a", "shard-0")
    assert led.snapshot()["shard-0"]["owner"] == "b"


def test_lease_corrupt_ledger_degrades_to_empty(tmp_path):
    path = tmp_path / "ledger.json"
    led = LeaseLedger(path, ttl_s=10.0)
    led.init_shards({"shard-0": ["k0"]})
    path.write_text("{not json!")
    before = _ctr("tune.ledger_corrupt")
    assert led.snapshot() == {}
    assert _ctr("tune.ledger_corrupt") > before
    # init_shards rebuilds it — nothing measured lives here, so no loss
    led.init_shards({"shard-0": ["k0"]})
    assert led.states() == {"pending": 1}


# -------------------------------------------------------- tune-smoke round --
def test_tune_smoke_artifact_replica_zero_measurements(tmp_path, monkeypatch):
    """`make tune-smoke`: one fleet pass measures the deduped grid and
    publishes a complete verified artifact; a fresh replica preloading it
    warms up with ZERO autotune measurements and still serves."""
    cfg = _cfg()
    art = tmp_path / "plans.artifact.json"
    out = run_fleet(cfg, BATCH, MAXLEN,
                    ledger_path=tmp_path / "ledger.json",
                    store_path=tmp_path / "tuner_cache.json",
                    out_path=art, n_shards=2, worker_id="tuner-a")
    assert out["artifact"]["complete"] is True
    assert out["artifact"]["entries"] == out["groups"] >= 1
    assert set(out["ledger"]) == {"done"}
    assert out["worker"]["measured"] == out["groups"]
    assert not out["worker"]["failed"]

    measured_before = _ctr("registry.measure")
    eng = _replica(art, tmp_path / "replica-cache", monkeypatch)
    stats = eng.stats()
    assert stats["artifact"]["verified"] == stats["artifact"]["total"] >= 1
    assert stats["artifact"]["rejected"] == 0
    # the acceptance bar: the artifact-loaded replica measures nothing
    assert stats["warmup_measured"] == 0
    assert stats["warmup_failed"] == 0
    assert _ctr("registry.measure") == measured_before

    # and it serves: tokens come out, step-time seed comes from the
    # artifact's measured timings (satellite: scheduler virtual clock)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, 8), 0,
                                 cfg.vocab_size)
    toks = eng.generate(prompts, 3)
    assert np.asarray(toks).shape == (BATCH, 3)
    seed_ms = eng.measured_step_time_ms()
    assert seed_ms is not None and seed_ms > 0


def test_step_time_seeds_from_measured_timings(tmp_path, monkeypatch):
    """serve_stream with step_time_ms=None seeds the scheduler clock from
    measured plan timings, not the 1.0 ms constant."""
    from repro.serve import scheduler as sched_mod
    cfg = _cfg()
    art = tmp_path / "plans.artifact.json"
    run_fleet(cfg, BATCH, MAXLEN, ledger_path=tmp_path / "ledger.json",
              store_path=tmp_path / "tuner_cache.json", out_path=art,
              n_shards=1)
    eng = _replica(art, tmp_path / "replica-cache", monkeypatch)
    # before any served step the estimate already exists: the plan-derived
    # floor from the artifact's measured winner timings — so serve_stream's
    # default seed is "measured", not the 1.0 ms constant
    assert (eng.measured_step_time_ms() or 0) > 0
    reqs = sched_mod.synthetic_workload(2, seed=0, prompt_lens=(4,),
                                        new_tokens=(2,),
                                        arrival_rate=1.0,
                                        vocab=cfg.vocab_size)
    before = _ctr("sched.step_time_seeded")
    res = eng.serve_stream(reqs)
    assert len(res) == 2
    assert _ctr("sched.step_time_seeded") > before


# --------------------------------------------------------------- artifact --
def test_publish_salvages_partial_store(tmp_path):
    """A fleet killed at 60%: publish never demands completeness — the
    measured entries ship (complete=false, the gap listed), and a replica
    re-measures only the gap."""
    cfg = _cfg()
    store_path = tmp_path / "tuner_cache.json"
    run_fleet(cfg, BATCH, MAXLEN, ledger_path=tmp_path / "ledger.json",
              store_path=store_path, n_shards=1)
    groups = grid_mod.enumerate_work(cfg, BATCH, MAXLEN)
    store = CompileCache(store_path)

    # copy all but the last group into a fresh store: the "killed" fleet
    partial = CompileCache(tmp_path / "partial_cache.json")
    for g in groups[:-1]:
        partial.put(g.key, store.get(g.key))
    lost = groups[-1].key

    salvaged = _ctr("artifact.salvaged")
    art = tmp_path / "partial.artifact.json"
    summary = artifact_mod.publish(partial, groups, art)
    assert summary["complete"] is False
    assert summary["missing"] == 1
    assert summary["entries"] == len(groups) - 1
    assert _ctr("artifact.salvaged") > salvaged

    doc = artifact_mod.load(art)
    assert doc["complete"] is False and doc["missing"] == [lost]
    assert lost not in doc["entries"]
    # every shipped entry is manifest-valid
    for key, plan in doc["entries"].items():
        assert artifact_mod.verify_entry(key, plan,
                                         doc["manifest"][key]) is None


def test_partial_artifact_replica_measures_only_the_gap(tmp_path,
                                                        monkeypatch):
    cfg = _cfg()
    store_path = tmp_path / "tuner_cache.json"
    run_fleet(cfg, BATCH, MAXLEN, ledger_path=tmp_path / "ledger.json",
              store_path=store_path, n_shards=1)
    groups = grid_mod.enumerate_work(cfg, BATCH, MAXLEN)
    store = CompileCache(store_path)
    partial = CompileCache(tmp_path / "partial_cache.json")
    for g in groups[:-1]:
        partial.put(g.key, store.get(g.key))
    art = tmp_path / "partial.artifact.json"
    artifact_mod.publish(partial, groups, art)

    measured_before = _ctr("registry.measure")
    eng = _replica(art, tmp_path / "replica-cache", monkeypatch)
    stats = eng.stats()
    assert stats["warmup_failed"] == 0
    # exactly one fresh measurement: the one missing bucket; everything the
    # artifact covered replays
    assert _ctr("registry.measure") - measured_before == 1
    assert stats["warmup_measured"] >= 1


def test_verify_entry_reasons():
    env = "jax-test"
    plan = {"factor": 2, "mode": "T", "env": env}
    man = {"sha256": artifact_mod.entry_hash(plan), "env": env}
    assert artifact_mod.verify_entry("k", plan, man, env=env) is None
    assert artifact_mod.verify_entry("k", plan, None, env=env) == "missing"
    assert artifact_mod.verify_entry("k", "junk", man, env=env) == "invalid"
    assert artifact_mod.verify_entry("k", {"mode": "T"}, man,
                                     env=env) == "invalid"
    tampered = dict(plan, factor=8)
    assert artifact_mod.verify_entry("k", tampered, man, env=env) == "corrupt"
    stale = dict(plan, env="jax-0.0.0")
    man_stale = {"sha256": artifact_mod.entry_hash(stale)}
    assert artifact_mod.verify_entry("k", stale, man_stale,
                                     env=env) == "stale"


def test_tampered_artifact_degrades_per_entry(tmp_path, monkeypatch):
    """Bitrot one entry (hash mismatch) in a published artifact: the replica
    rejects *that entry* (quarantining its artifact provenance), preloads
    the rest, re-measures the rejected bucket locally, and serves."""
    from repro.compiler import default_cache
    cfg = _cfg()
    art = tmp_path / "plans.artifact.json"
    run_fleet(cfg, BATCH, MAXLEN, ledger_path=tmp_path / "ledger.json",
              store_path=tmp_path / "tuner_cache.json", out_path=art,
              n_shards=1)
    doc = json.loads(art.read_text())
    bad_key = sorted(doc["entries"])[0]
    doc["entries"][bad_key]["factor"] = 999      # sha256 now mismatches
    art.write_text(json.dumps(doc))

    rejected = _ctr("artifact.rejected")
    eng = _replica(art, tmp_path / "replica-cache", monkeypatch)
    stats = eng.stats()
    assert stats["artifact"]["rejected"] == 1
    assert stats["artifact"]["reasons"] == {"corrupt": 1}
    assert stats["artifact"]["verified"] == stats["artifact"]["total"] - 1
    assert _ctr("artifact.rejected") > rejected
    # provenance quarantined under the :artifact suffix — never the
    # backend rung, so the local re-measure is not gated
    q = default_cache().quarantine_entries()
    assert f"{bad_key}:artifact" in q
    assert stats["warmup_failed"] == 0
    toks = eng.generate(jax.random.randint(jax.random.PRNGKey(1),
                                           (BATCH, 8), 0, cfg.vocab_size), 3)
    assert np.asarray(toks).shape == (BATCH, 3)


def test_stale_env_artifact_rejected_as_stale(tmp_path, monkeypatch):
    cfg = _cfg()
    art = tmp_path / "plans.artifact.json"
    run_fleet(cfg, BATCH, MAXLEN, ledger_path=tmp_path / "ledger.json",
              store_path=tmp_path / "tuner_cache.json", out_path=art,
              n_shards=1)
    doc = json.loads(art.read_text())
    for key, plan in doc["entries"].items():
        plan["env"] = "jax-0.0.0-other-build"
        # keep the hash valid so the *env* check is what rejects
        doc["manifest"][key]["sha256"] = artifact_mod.entry_hash(plan)
    art.write_text(json.dumps(doc))
    eng = _replica(art, tmp_path / "replica-cache", monkeypatch)
    stats = eng.stats()
    assert stats["artifact"]["verified"] == 0
    assert stats["artifact"]["rejected"] == stats["artifact"]["total"]
    assert set(stats["artifact"]["reasons"]) == {"stale"}
    # full local warmup still happened
    assert stats["warmup_failed"] == 0
    assert stats["plans_warmed"] >= 1


# ------------------------------------------------------------ cache prune --
def test_cache_prune_gc(tmp_path):
    from repro.compiler.cache import _env_fingerprint
    cache = CompileCache(tmp_path / "c.json")
    now = time.time()
    cache.put("fresh", {"factor": 1})
    cache.put("aged", {"factor": 1, "created": now - 1000.0})
    cache.put("stale", {"factor": 1, "env": "jax-0.0.0-other"})
    cache.record_failure("flaky", "boom", now=now)
    until = cache.quarantine_entries()["flaky"]["until"]

    pruned = _ctr("cache.pruned")
    ev = cache.prune(max_age_s=500.0, now=now)
    assert ev["stale_env"] == 1 and ev["aged"] == 1
    assert ev["quarantine"] == 0          # window still open: kept
    assert _ctr("cache.pruned") > pruned
    assert cache.get("fresh") is not None
    assert cache.get("aged") is None and cache.get("stale") is None
    assert "flaky" in cache.quarantine_entries()

    # a second prune past the backoff window forgives the quarantine row
    ev2 = cache.prune(now=until + 1.0)
    assert ev2["quarantine"] == 1 and ev2["aged"] == 0
    assert cache.quarantine_entries() == {}
    assert cache.get("fresh") is not None

    # cold re-read: the evictions persisted to disk
    cold = CompileCache(tmp_path / "c.json")
    assert cold.get("fresh") is not None and cold.get("aged") is None


def test_cache_prune_survives_readonly_store(tmp_path):
    cache = CompileCache(tmp_path / "missing" / "c.json")
    assert cache.prune(max_age_s=1.0) == {"stale_env": 0, "aged": 0,
                                          "corrupt": 0, "quarantine": 0}


# ------------------------------------------- two-process SIGKILL reclaim --
_DOOMED_WORKER = """
import sys, time
from repro.tune.lease import LeaseLedger
led = LeaseLedger(sys.argv[1], ttl_s=0.5)
got = led.claim("doomed")
print("CLAIMED", got[0] if got else "nothing", flush=True)
time.sleep(600)      # park mid-lease until SIGKILLed
"""


def test_sigkill_mid_lease_survivor_completes(tmp_path):
    """The headline crash test: a second OS process claims a shard and is
    SIGKILLed mid-lease.  After the TTL the in-process survivor reclaims
    it, finishes the whole grid, and publishes a complete artifact whose
    every entry verifies against its manifest — no lost work, no
    double-publish."""
    cfg = _cfg()
    ledger_path = tmp_path / "ledger.json"
    groups = grid_mod.enumerate_work(cfg, BATCH, MAXLEN)
    assert len(groups) >= 2, "need >=2 shards for a meaningful kill"
    shards = grid_mod.shard_groups(groups, 2)
    led = LeaseLedger(ledger_path, ttl_s=0.5)
    led.init_shards(grid_mod.shard_keys(shards))

    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(root / "src"))
    proc = subprocess.Popen([sys.executable, "-c", _DOOMED_WORKER,
                             str(ledger_path)],
                            stdout=subprocess.PIPE, text=True, env=env)
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("CLAIMED shard-"), line
        dead_shard = line.split()[1]
        proc.kill()                      # SIGKILL: no cleanup, no release
        proc.wait(timeout=30)
        assert proc.returncode == -signal.SIGKILL
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)
    assert led.snapshot()[dead_shard]["owner"] == "doomed"

    time.sleep(0.6)                      # let the dead lease expire
    reclaimed = _ctr("tune.lease_reclaimed")
    out = run_fleet(cfg, BATCH, MAXLEN, ledger_path=ledger_path,
                    store_path=tmp_path / "tuner_cache.json",
                    out_path=tmp_path / "plans.artifact.json",
                    n_shards=2, worker_id="survivor", ttl_s=0.5)
    assert _ctr("tune.lease_reclaimed") > reclaimed
    assert led.all_done()
    assert led.snapshot()[dead_shard]["owner"] == "survivor"
    assert led.snapshot()[dead_shard]["attempts"] == 2
    assert out["artifact"]["complete"] is True
    assert out["artifact"]["entries"] == len(groups)

    doc = artifact_mod.load(tmp_path / "plans.artifact.json")
    assert sorted(doc["entries"]) == sorted(g.key for g in groups)
    for key, plan in doc["entries"].items():
        assert artifact_mod.verify_entry(key, plan,
                                         doc["manifest"][key]) is None
