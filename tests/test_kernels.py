"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes / dtypes / pump factors / modes and asserts allclose, plus the
structural resource metrics the paper's tables report (transaction counts,
compute-tile footprints).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ir import PumpSpec
from repro.kernels import ops, ref
import repro.kernels.matmul as mm_mod
import repro.kernels.vecadd as va_mod
import repro.kernels.stencil as st_mod
import repro.kernels.floyd_warshall as fw_mod
import repro.kernels.flash_attention as fa_mod
import repro.kernels.ssd_scan as ssd_mod


def key(i=0):
    return jax.random.PRNGKey(i)


# ------------------------------------------------------------------ vecadd --
@pytest.mark.parametrize("n", [64, 256, 100])
@pytest.mark.parametrize("mode", ["T", "R"])
@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vecadd(n, mode, m, dtype):
    x = jax.random.normal(key(0), (n,), dtype)
    y = jax.random.normal(key(1), (n,), dtype)
    out = ops.vecadd(x, y, vector_width=8, pump=PumpSpec(factor=m, mode=mode))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.vecadd(x, y)),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_vecadd_transactions_halve_in_mode_t():
    assert va_mod.grid_steps(1024, 8, PumpSpec(2, "T")) \
        == va_mod.grid_steps(1024, 8, 1) // 2
    assert va_mod.grid_steps(1024, 8, PumpSpec(2, "R")) \
        == va_mod.grid_steps(1024, 8, 1)


# ------------------------------------------------------------------ matmul --
@pytest.mark.parametrize("shape", [(64, 64, 64), (96, 32, 128), (100, 70, 50)])
@pytest.mark.parametrize("mode,m", [("T", 1), ("T", 2), ("T", 4), ("R", 2)])
def test_matmul(shape, mode, m):
    msz, ksz, nsz = shape
    a = jax.random.normal(key(0), (msz, ksz), jnp.float32)
    b = jax.random.normal(key(1), (ksz, nsz), jnp.float32)
    out = ops.matmul(a, b, bm=32, bn=32, bk=16,
                     pump=PumpSpec(factor=m, mode=mode))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(a, b)),
                               atol=2e-4)


def test_matmul_bf16():
    a = jax.random.normal(key(0), (64, 64), jnp.bfloat16)
    b = jax.random.normal(key(1), (64, 64), jnp.bfloat16)
    out = ops.matmul(a, b, bm=32, bn=32, bk=32, pump=2)
    gold = ref.matmul(a, b, out_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), atol=0.5)


def test_matmul_resource_semantics():
    """Paper Table 3: Mode T halves transactions at constant tile; Mode R
    halves the compute tile at constant transactions."""
    base_tx = mm_mod.transactions(256, 256, 256, pump=1)
    base_tile = mm_mod.compute_tile_bytes(pump=1)
    assert mm_mod.transactions(256, 256, 256, pump=PumpSpec(2, "T")) \
        == base_tx // 2
    assert mm_mod.compute_tile_bytes(pump=PumpSpec(2, "T")) == base_tile
    assert mm_mod.transactions(256, 256, 256, pump=PumpSpec(2, "R")) == base_tx
    assert mm_mod.compute_tile_bytes(pump=PumpSpec(2, "R")) == base_tile // 2


# ----------------------------------------------------------------- stencil --
@pytest.mark.parametrize("kind", ["jacobi", "diffusion"])
@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("stages", [1, 3])
def test_stencil(kind, m, stages):
    x = jax.random.normal(key(0), (10, 8, 8), jnp.float32)
    out = ops.stencil_chain(x, stages, kind=kind, pump=m)
    gold = ref.stencil_chain(x, stages, kind=kind)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=1e-5)


# ---------------------------------------------------------- floyd-warshall --
@pytest.mark.parametrize("n", [8, 16, 32])
@pytest.mark.parametrize("m", [1, 2, 4])
def test_floyd_warshall(n, m):
    d = jax.random.uniform(key(0), (n, n), jnp.float32, 0.1, 10.0)
    d = d.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    out = ops.floyd_warshall(d, pump=m)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.floyd_warshall(d)), atol=1e-6)


def test_floyd_warshall_is_dependency_carrying():
    """The k-loop is a true dependency: processing k out of order changes
    the result (this is why spatial vectorization fails and temporal
    vectorization is needed — paper §4.4)."""
    n = 16
    d = jax.random.uniform(key(3), (n, n), jnp.float32, 0.1, 10.0)
    d = d.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    gold = np.asarray(ref.floyd_warshall(d))

    # "spatially vectorized" (wrong) variant: all k relaxations from the
    # ORIGINAL matrix, merged at the end
    dd = np.asarray(d)
    relaxed = np.min(dd[:, :, None] + dd[None, :, :], axis=1)
    wrong = np.minimum(dd, relaxed)
    assert not np.allclose(wrong, gold)


# --------------------------------------------------------- flash attention --
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("m", [1, 2])
def test_flash_attention(hq, hkv, causal, m):
    b, s, d = 2, 64, 16
    q = jax.random.normal(key(0), (b, hq, s, d), jnp.float32)
    k = jax.random.normal(key(1), (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(key(2), (b, hkv, s, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, bq=16, bkv=16, pump=m)
    gold = ref.attention(q, jnp.repeat(k, hq // hkv, 1),
                         jnp.repeat(v, hq // hkv, 1), causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=2e-5)


def test_flash_attention_long_kv_pump_transactions():
    assert fa_mod.transactions(1, 4, 128, 1024, bq=128, bkv=128, pump=4) \
        == fa_mod.transactions(1, 4, 128, 1024, bq=128, bkv=128, pump=1) // 4


# ---------------------------------------------------------------- SSD scan --
@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_scan(m, g):
    b, l, h, p, n = 2, 64, 4, 8, 6
    ks = jax.random.split(key(0), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
    A = -jax.nn.softplus(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, l, g, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, l, g, n), jnp.float32)
    out = ops.ssd_scan(x, dt, A, B, C, chunk=8, pump=m)
    gold = ref.ssd_scan(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=1e-4)


# ------------------------------------- compiler route vs hand-wired kernels --
@pytest.mark.parametrize("m", [1, 2])
def test_ops_compiler_route_matches_handwired(m):
    """kernels.ops routes flash attention / ssd scan / grouped gemm through
    compiler.compile by default; the hand-wired Pallas kernels remain as the
    differential reference (impl='pallas') and the two must agree."""
    b, h, s, d = 2, 4, 32, 8
    q = jax.random.normal(key(0), (b, h, s, d), jnp.float32)
    k = jax.random.normal(key(1), (b, h, s, d), jnp.float32)
    v = jax.random.normal(key(2), (b, h, s, d), jnp.float32)
    oc = ops.flash_attention(q, k, v, causal=True, bq=16, bkv=16, pump=m)
    oh = ops.flash_attention(q, k, v, causal=True, bq=16, bkv=16, pump=m,
                             impl="pallas")
    np.testing.assert_allclose(np.asarray(oc), np.asarray(oh), atol=2e-5)

    ks = jax.random.split(key(3), 5)
    x = jax.random.normal(ks[0], (1, 32, 2, 4), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 32, 2))) * 0.5
    A = -jax.nn.softplus(jax.random.normal(ks[2], (2,)))
    B = jax.random.normal(ks[3], (1, 32, 1, 4), jnp.float32)
    C = jax.random.normal(ks[4], (1, 32, 1, 4), jnp.float32)
    yc = ops.ssd_scan(x, dt, A, B, C, chunk=8, pump=m)
    yh = ops.ssd_scan(x, dt, A, B, C, chunk=8, pump=m, impl="pallas")
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yh), atol=1e-4)

    xg = jax.random.normal(key(4), (2, 24, 16), jnp.float32)
    wg = jax.random.normal(key(5), (2, 16, 8), jnp.float32)
    gc = ops.grouped_gemm(xg, wg, bc=8, bf=8, bd=8, pump=m)
    gh = ops.grouped_gemm(xg, wg, bc=8, bf=8, bd=8, pump=m, impl="pallas")
    np.testing.assert_allclose(np.asarray(gc), np.asarray(gh), atol=1e-4)


def test_ops_compiler_route_no_silent_fallback(recwarn):
    """The default route must actually compile — a fallback to the
    hand-wired kernel warns, and none may fire for supported shapes."""
    q = jax.random.normal(key(0), (1, 2, 32, 8), jnp.float32)
    ops.flash_attention(q, q, q, bq=16, bkv=16, pump=2)
    assert not [w for w in recwarn.list
                if "compiler route failed" in str(w.message)]


def test_ssd_pump_preserves_interchunk_dependency():
    """Pumped chunks must see the state left by earlier chunks: zeroing the
    first half of the input must change the second half's output."""
    b, l, h, p, n = 1, 32, 2, 4, 4
    ks = jax.random.split(key(1), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
    A = -0.1 * jnp.ones((h,))
    B = jax.random.normal(ks[3], (b, l, 1, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, l, 1, n), jnp.float32)
    full = ops.ssd_scan(x, dt, A, B, C, chunk=8, pump=2)
    zeroed = ops.ssd_scan(x.at[:, :16].set(0.0), dt, A, B, C, chunk=8, pump=2)
    assert not np.allclose(np.asarray(full[:, 16:]),
                           np.asarray(zeroed[:, 16:]))
