"""repro.compiler tests: pass registry/pipeline, lowering backends vs the
numpy reference executor (differential — driven by the reusable harness in
``tests/differential.py``), persistent compile cache (including corruption
negative paths), the two passes (stream-fusion, fifo-depth), and the
fused-region Pallas emission backend (region partitioning, blocked-view
derivation, temporal grid axis, carry-aware emission, measured-runtime
autotune).

Differential data is integer-valued float32 so every backend computes the
same exactly-representable values regardless of reduction order — the
lowerings are required to be *bit-exact* against the reference executor
wherever the kernel math permits (see ``tests/differential.py`` for the
exp caveat on flash attention / SSD).
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro import compiler
from repro.compiler import (CompileCache, LoweringError, Pipeline,
                            PASS_REGISTRY, make_pass)
from repro.compiler.cache import graph_fingerprint
from repro.compiler.lowering import _temporal_rechunk
from repro.compiler.passes import FifoDepthPass, StreamFusionPass
from repro.core import (AccessPattern, Affine, Domain, Graph, NodeKind,
                        apply_multipump, apply_streaming, autopump, executor)
from repro.core.autopump import BUILDERS
from repro.core.multipump import pump_spec_for
from repro.core.symbolic import blocked_access

from differential import FACTORS, MODES, Case, cases as diff_cases, run_case
from hypothesis_compat import given, settings, st


def _ints(rng, shape, lo=-4, hi=5):
    return rng.integers(lo, hi, shape).astype(np.float32)


def chain_graph(n=32, v=4):
    """Two computes through an intermediate memory: z = (x + 1) * 2."""
    g = Graph("chain")
    g.memory("x", (n,))
    g.memory("t", (n,))
    g.memory("z", (n,))
    dom = Domain.of(("i", 0, n // v))
    acc = AccessPattern(dom, (Affine.of("i", v),), width=v)
    g.compute("add1", dom, fn=lambda in0: {"out0": in0 + 1.0}, vector_width=v)
    g.compute("scale", dom, fn=lambda in0: {"out0": in0 * 2.0}, vector_width=v)
    g.connect("x", "add1", acc)
    g.connect("add1", "t", acc)
    g.connect("t", "scale", acc)
    g.connect("scale", "z", acc)
    return g


# ------------------------------------------------- differential harness --
# the copy-pasted per-kernel differential tests were replaced by the
# registry-driven sweep in tests/differential.py: every BUILDERS entry ×
# backend × M ∈ {1,2,4} × modes {T,R}, asserted against the reference
# executor (bit-exact where the math permits) and an independent numpy gold
_DIFF0 = diff_cases(0)
_DIFF1 = {k: v for k, v in diff_cases(1).items()
          if k in ("flash_attention", "ssd_scan", "grouped_gemm",
                   "grouped_gemm_ragged", "decode_attention",
                   "ssd_scan_final", "ssd_decode")}


@pytest.mark.parametrize("backend", ["reference", "jax", "pallas"])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("factor", FACTORS)
@pytest.mark.parametrize("kernel", sorted(_DIFF0))
def test_differential_all_builders(kernel, factor, mode, backend):
    run_case(_DIFF0[kernel], factor, mode, backend)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("factor", FACTORS)
@pytest.mark.parametrize("kernel", sorted(_DIFF1))
def test_differential_second_shapes(kernel, factor, mode, backend):
    """Acceptance: the three subsumed kernels hold on a second, structurally
    different shape (GQA folding, grouped B/C, different raggedness)."""
    run_case(_DIFF1[kernel], factor, mode, backend)


@settings(max_examples=8, deadline=None)
@given(nblocks=st.integers(1, 4), v=st.integers(1, 8))
def test_differential_vecadd_shape_property(nblocks, v):
    """Shape-parametrized via hypothesis (skips without it installed)."""
    n = nblocks * v * 2
    run_case(Case("vecadd", (n,), dict(vector_width=v),
                  {"x": (n,), "y": (n,)}, ("z",)), 2, "T", "jax")


@settings(max_examples=6, deadline=None)
@given(sizes=st.lists(st.integers(1, 3), min_size=1, max_size=3))
def test_differential_ragged_shape_property(sizes):
    from differential import _grouped_gold_ragged
    sizes = tuple(s * 8 for s in sizes)
    rows = sum(sizes)
    run_case(Case("grouped_gemm", (len(sizes), 16, 8, 8),
                  dict(bc=8, bf=8, bd=8, group_sizes=sizes, vector_width=8),
                  {"x": (rows, 8), "w": (len(sizes), 8, 8)}, ("o",),
                  gold=_grouped_gold_ragged(sizes)), 2, "T", "pallas")


def test_reference_backend_matches_jax_backend(tmp_path):
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    rng = np.random.default_rng(7)
    inputs = {"x": _ints(rng, 64), "y": _ints(rng, 64)}
    cache = CompileCache(tmp_path / "c.json")
    kj = compiler.compile(g, factor=2, backend="jax", cache=cache,
                          memoize=False)
    kr = compiler.compile(g, factor=2, backend="reference", cache=cache,
                          memoize=False)
    np.testing.assert_array_equal(np.asarray(kj(inputs)["z"]),
                                  kr(inputs)["z"])


# --------------------------------------------- pallas backend: structure --
def test_region_partitioning_and_emission_tiers(tmp_path):
    """Adapters/streams fuse into one region per compute chain; emission
    picks blockloop for tile-able kernels and gather for the
    dependency-carrying floyd pivot loop."""
    g, _ = BUILDERS["matmul"](32, 32, 32, bm=16, bn=16, bk=16, vector_width=8)
    kern = compiler.compile(g, factor=2, backend="pallas",
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    assert list(kern.report.emission.values())[0]["tier"] == "blockloop"
    # the temporal axis is the innermost grid dim, and kk + _pump reduce
    em = list(kern.report.emission.values())[0]
    assert em["grid"][-1][0] == "_pump" and em["grid"][-1][1] == 2
    assert "_pump" in em["reduce"] and "kk" in em["reduce"]

    g2, _ = BUILDERS["floyd_warshall"](16)
    kern2 = compiler.compile(g2, factor=2, backend="pallas",
                             cache=CompileCache(tmp_path / "c.json"),
                             memoize=False)
    assert list(kern2.report.emission.values())[0]["tier"] == "gather"
    assert kern2.report.warnings                    # downgrade is visible


def test_pallas_interpret_emission_matches_reference(tmp_path):
    """Real pl.pallas_call (interpret mode on CPU) for pallas-expressible
    regions, bit-exact in both modes."""
    rng = np.random.default_rng(3)
    inputs = {"a": rng.integers(-3, 4, (32, 32)).astype(np.float32),
              "b": rng.integers(-3, 4, (32, 32)).astype(np.float32)}
    for mode in ("T", "R"):
        g, _ = BUILDERS["matmul"](32, 32, 32, bm=16, bn=16, bk=16,
                                  vector_width=8)
        kern = compiler.compile(g, factor=2, mode=mode, backend="pallas",
                                pallas_mode="interpret",
                                cache=CompileCache(tmp_path / "c.json"),
                                memoize=False)
        assert list(kern.report.emission.values())[0]["tier"] == "pallas"
        out = np.asarray(kern(inputs)["c"])
        np.testing.assert_array_equal(
            out, executor.run(kern.graph, dict(inputs))["c"])
        np.testing.assert_array_equal(out, inputs["a"] @ inputs["b"])


def test_carry_region_emission_structure(tmp_path):
    """Carry regions emit the carry-aware tier: flash attention's online
    softmax becomes a multi-output carryloop whose carry axis is the
    innermost grid dimension; mode T splits it into transactions × beats."""
    g, _ = BUILDERS["flash_attention"](1, 2, 32, 32, 8, bq=16, bkv=8,
                                       vector_width=8)
    kern = compiler.compile(g, factor=2, backend="pallas",
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    em = list(kern.report.emission.values())[0]
    assert em["tier"] == "carryloop"
    assert em["carry"] == ["ji", "_pump"]          # M beats continue the sweep
    assert em["grid"][-1] == ["_pump", 2]
    assert set(em["outputs"]) == {"o", "m", "l"}   # multi-output region

    # mode R: the _pump axis sits OUTSIDE the carry sweep (sub-tiles run
    # their own full sweeps) and narrows the labelled 'q' axis
    g2, _ = BUILDERS["flash_attention"](1, 2, 32, 32, 8, bq=16, bkv=8,
                                        vector_width=8)
    kern2 = compiler.compile(g2, factor=2, mode="R", backend="pallas",
                             cache=CompileCache(tmp_path / "c.json"),
                             memoize=False)
    em2 = list(kern2.report.emission.values())[0]
    syms2 = [s for s, _e in em2["grid"]]
    assert em2["carry"] == ["ji"]
    assert syms2.index("_pump") < syms2.index("ji")


def test_carry_pallas_interpret_emission(tmp_path):
    """Real pl.pallas_call emission for carry regions (interpret mode):
    state in VMEM scratch, pl.when-gated init/finalize — the hand-written
    flash-attention schedule, derived from the IR."""
    for kernel in ("flash_attention", "ssd_scan"):
        case = _DIFF0[kernel]
        run_case(case, 2, "T", "pallas", pallas_mode="interpret")
        g, _ = BUILDERS[case.kernel](*case.args, **case.kwargs)
        kern = compiler.compile(g, factor=2, backend="pallas",
                                pallas_mode="interpret",
                                cache=CompileCache(tmp_path / "c.json"),
                                memoize=False)
        assert list(kern.report.emission.values())[0]["tier"] == "pallas"


def test_ragged_blockspec_derivation():
    """Group-indexed (table) access decomposes into a blocked view whose
    offsets carry the lookup — and still divides into block units, so the
    ragged grouped gemm gets a real derivable BlockSpec."""
    g, _ = BUILDERS["grouped_gemm"](2, 32, 16, 8, bc=8, bf=8, bd=8,
                                    group_sizes=(16, 24))
    acc_x = g.in_edges("expert_tile")[0].access
    ba = blocked_access(acc_x, (40, 16))
    assert ba.block == (8, 8)
    assert ba.grid_symbols == ("ti", "ji", "ki")
    assert ba.offsets[0].tables               # row offsets are a table term
    assert ba.block_unit_offsets() is not None
    # the w operand maps each tile to its expert slab via a table
    acc_w = g.in_edges("expert_tile")[1].access
    bw = blocked_access(acc_w, (2, 16, 8))
    assert bw.offsets[0].tables and bw.block == (1, 8, 8)


def test_blocked_access_derivation():
    """Symbolic access patterns decompose into block/grid/offset views."""
    g, _ = BUILDERS["matmul"](64, 64, 64, bm=16, bn=16, bk=16, vector_width=8)
    acc_a = g.in_edges("mxu_tile")[0].access
    ba = blocked_access(acc_a, (64, 64))
    assert ba.block == (16, 16)
    assert ba.grid_symbols == ("i", "j", "kk")
    assert ba.block_unit_offsets() is not None      # pallas-expressible

    # stencil halo: overlapping windows are blockable but not block-unit
    g2, _ = BUILDERS["stencil"](10, 8, 8)
    ba2 = blocked_access(g2.in_edges("plane_update")[0].access, (10, 8, 8))
    assert ba2.block == (3, 8, 8)
    assert ba2.block_unit_offsets() is None


def test_pallas_backend_on_fused_chain(tmp_path):
    """Multi-compute regions (post stream-fusion) lower through the pallas
    backend's gather tier and stay value-exact."""
    g = chain_graph(32, 4)
    kern = compiler.compile(g, factor=2, backend="pallas",
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    assert "t" not in kern.graph.nodes
    rng = np.random.default_rng(5)
    x = _ints(rng, 32)
    out = np.asarray(kern({"x": x})["z"])
    np.testing.assert_array_equal(out, (x + 1.0) * 2.0)


def test_pallas_region_order_respects_memory_deps(tmp_path):
    """A region reading memory m must run after the region writing m, even
    when declaration/toposort position order says otherwise (regression:
    emission used to schedule by first-compute position)."""
    g = Graph("xregion")
    g.memory("y", (8,))
    g.memory("x", (8,))
    g.memory("m", (8,))
    g.memory("z", (8,))
    dom = Domain.of(("i", 0, 8))
    acc = AccessPattern(dom, (Affine.of("i"),))
    rev = AccessPattern(dom, (Affine.constant(7) - Affine.of("i"),))
    # consumer region: c0 -> c1, where only the *second* compute reads m
    # (c0's node-toposort position precedes the producer a0's, so position-
    # based region scheduling would run this region first, against zeros;
    # the reversed read defeats streaming/fusion, so m stays a boundary)
    g.compute("c0", dom, fn=lambda in0: {"out0": in0 + 1.0})
    g.compute("c1", dom, fn=lambda in0, in1: {"out0": in0 + in1})
    g.connect("x", "c0", acc)
    g.connect("c0", "c1")
    g.connect("m", "c1", rev)
    g.connect("c1", "z", acc)
    # producer region declared last: m = 2 * y
    g.compute("a0", dom, fn=lambda in0: {"out0": in0 * 2.0})
    g.connect("y", "a0", acc)
    g.connect("a0", "m", acc)

    from repro.core.executor import _toposort
    from repro.compiler.pallas_backend import partition_regions
    order = _toposort(g)
    assert order.index("c0") < order.index("a0")    # the trap this guards
    assert [r.name for r in partition_regions(g)] == ["a0", "c0"]

    rng = np.random.default_rng(9)
    inputs = {"x": _ints(rng, 8), "y": _ints(rng, 8)}
    kern = compiler.compile(g, factor=1, backend="pallas",
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    out = np.asarray(kern(inputs)["z"])
    gold = (inputs["x"] + 1.0) + (2.0 * inputs["y"])[::-1]
    np.testing.assert_array_equal(out, gold)
    np.testing.assert_array_equal(
        out, executor.run(kern.graph, dict(inputs))["z"])


# ------------------------------------------------ measured-runtime autotune --
def test_autotune_measure_and_cache_replay(tmp_path):
    path = tmp_path / "cache.json"
    g, est = BUILDERS["vecadd"](256, vector_width=8)
    k1 = compiler.compile(g, factor="auto", estimate=est, backend="pallas",
                          autotune="measure", cache=CompileCache(path),
                          memoize=False)
    at = k1.report.autotune
    assert at["policy"] == "measure" and at["replayed"] is False
    assert len(at["timings_us"]) >= 2               # measured >= 2 candidates
    assert at["winner"] == k1.spec.factor

    # second compile (fresh cache instance ≙ fresh process): disk hit that
    # replays the measured plan without re-measuring
    g2, _ = BUILDERS["vecadd"](256, vector_width=8)
    k2 = compiler.compile(g2, factor="auto", estimate=est, backend="pallas",
                          autotune="measure", cache=CompileCache(path),
                          memoize=False)
    assert k2.report.served_from == "disk"
    assert k2.report.autotune["replayed"] is True
    assert k2.spec.factor == k1.spec.factor


def test_autotune_measure_requires_executable_backend():
    g, est = BUILDERS["vecadd"](64, vector_width=8)
    with pytest.raises(ValueError):
        compiler.compile(g, estimate=est, backend="none",
                         autotune="measure", cache=False)


def test_autotune_key_distinct_from_capacity_plan(tmp_path):
    """A measured winner and a capacity-model guess for the same request
    must not collide in the persistent cache."""
    path = tmp_path / "cache.json"
    g, est = BUILDERS["vecadd"](256, vector_width=8)
    compiler.compile(g, factor="auto", estimate=est, backend="pallas",
                     cache=CompileCache(path), memoize=False)
    cache = CompileCache(path)
    k = compiler.compile(g, factor="auto", estimate=est, backend="pallas",
                         autotune="measure", cache=cache, memoize=False)
    assert k.report.served_from is None             # not the heuristic entry
    assert k.report.autotune and k.report.autotune["replayed"] is False


def test_ops_pump_measure_routes_through_backend(tmp_path, monkeypatch):
    """kernels.ops pump='measure' compiles the kernel's IR graph through the
    pallas backend with measured autotuning and reuses the winning factor."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    import jax.numpy as jnp
    from repro.kernels import ops
    x = jnp.arange(512, dtype=jnp.float32)
    y = jnp.ones(512, jnp.float32)
    out = ops.vecadd(x, y, pump="measure")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x + y))
    assert (tmp_path / "compile_cache.json").exists()


# --------------------------------------------- scatter-duplicate rejection --
def test_duplicate_scatter_raises_lowering_error(tmp_path):
    """A write pattern revisiting addresses (reduction dim absent from the
    output) must fail loudly instead of silently last-write-wins — and the
    error must carry the offending producer→memory edge by name."""
    g = Graph("dup")
    g.memory("x", (8,))
    g.memory("z", (8,))
    dom = Domain.of(("k", 0, 2))
    g.compute("badwrite", dom, fn=lambda in0: {"out0": in0})
    g.connect("x", "badwrite", AccessPattern(dom, (Affine.of("k", 4),),
                                             width=4))
    g.connect("badwrite", "z", AccessPattern(dom, (Affine.constant(0),),
                                             width=4))
    for backend in ("jax", "pallas"):
        with pytest.raises(LoweringError, match="duplicate address") as ei:
            compiler.compile(g, factor=1, backend=backend,
                             cache=False, memoize=False)
        assert "badwrite" in str(ei.value) and "z" in str(ei.value)


# ------------------------------------------------ cache corruption paths --
@pytest.mark.parametrize("payload", [
    "{not valid json!!",              # syntactically broken
    '{"version": 1, "entries"',       # truncated mid-write
    json.dumps([1, 2, 3]),            # wrong top-level schema
    json.dumps({"version": 1, "entries": {"k": "not-a-plan"}}),
])
def test_corrupted_cache_falls_back_to_cold_compile(tmp_path, payload):
    """A corrupted/truncated compile-cache file must degrade to a cold
    compile (cache-off behaviour), never crash the build."""
    path = tmp_path / "cache.json"
    path.write_text(payload)
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    kern = compiler.compile(g, factor=2, cache=CompileCache(path),
                            memoize=False)
    assert kern.report.served_from is None         # cold, not crashed
    x = np.arange(64, dtype=np.float32)
    np.testing.assert_array_equal(
        np.asarray(kern({"x": x, "y": x})["z"]), x + x)


def test_corrupted_cache_entry_value_is_a_miss(tmp_path):
    """An entry whose *value* lost its factor (schema drift, hand edits)
    must be treated as a miss and recompiled cold."""
    path = tmp_path / "cache.json"
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    compiler.compile(g, factor=2, cache=CompileCache(path), memoize=False)
    blob = json.loads(path.read_text())
    blob["entries"] = {k: {"mode": "T"} for k in blob["entries"]}  # no factor
    path.write_text(json.dumps(blob))
    kern = compiler.compile(g, factor=2, cache=CompileCache(path),
                            memoize=False)
    assert kern.report.served_from is None
    assert kern.spec.factor == 2


# ------------------------------------------------- mode-R axis narrowing --
def _modeR_regression_graph(labelled: bool):
    """z[j·b+r] = c[j·b+r] · Σ x[j·b : (j+1)·b] — both operands walk the
    same offset expression with the same block size, but only ``c``'s axis
    corresponds to the output: narrowing ``x`` splits the Σ and corrupts
    the result.  The old grid-symbol heuristic (and even offset-expression
    matching) narrows both; the declared axis correspondence narrows only
    the labelled operand."""
    n, b = 16, 8
    g = Graph("modeR")
    g.memory("c", (n,))
    g.memory("x", (n,))
    g.memory("z", (n,))
    dom_b = Domain.of(("j", 0, n // b), ("r", 0, b))
    dom_j = Domain.of(("j", 0, n // b))
    acc_elem = AccessPattern(dom_b, (Affine.of("j", b) + Affine.of("r"),),
                             width=1)
    acc_block = AccessPattern(dom_j, (Affine.of("j", b),), width=b)

    def fn(in0, in1):
        c2 = in0.reshape(n // b, b)
        x2 = in1.reshape(n // b, b)
        return {"out0": (c2 * x2.sum(axis=1, keepdims=True)).reshape(-1)}

    tile_fn = lambda in0, in1: {"out0": in0 * in1.sum()}   # noqa: E731
    meta = dict(fn=fn, tile_fn=tile_fn, vector_width=8)
    if labelled:
        meta["axes"] = dict(ins=({0: "n"}, {}), outs=({0: "n"},),
                            carry=(), narrow="n")
    g.compute("scalecol", dom_j, **meta)
    g.connect("c", "scalecol", acc_elem)
    g.connect("x", "scalecol", acc_block)
    g.connect("scalecol", "z", acc_elem)
    return g


def test_mode_r_narrowing_uses_axis_correspondence(tmp_path):
    """Regression for the grid-symbol narrowing heuristic: with the compute's
    declared axis correspondence, mode R narrows only the operand dimension
    that actually corresponds to the output axis — the whole-block operand
    (a Σ over the block) stays wide, and the result stays bit-exact."""
    rng = np.random.default_rng(17)
    inputs = {"c": _ints(rng, 16), "x": _ints(rng, 16)}
    gold = (inputs["c"].reshape(2, 8)
            * inputs["x"].reshape(2, 8).sum(axis=1, keepdims=True)
            ).reshape(-1)

    g = _modeR_regression_graph(labelled=True)
    kern = compiler.compile(g, factor=2, mode="R", backend="pallas",
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    em = list(kern.report.emission.values())[0]
    assert em["pump"] == 2                        # temporal axis realized
    out = np.asarray(kern(inputs)["z"])
    np.testing.assert_array_equal(out, gold)
    np.testing.assert_array_equal(
        out, executor.run(kern.graph, dict(inputs))["z"])

    # the unlabelled graph shows why the heuristic cannot be fixed without
    # the correspondence: both operands walk the same offset expression
    # with the same block size, so narrowing picks both and splits the Σ
    g2 = _modeR_regression_graph(labelled=False)
    kern2 = compiler.compile(g2, factor=2, mode="R", backend="pallas",
                             cache=CompileCache(tmp_path / "c2.json"),
                             memoize=False)
    assert not np.array_equal(np.asarray(kern2(inputs)["z"]), gold)


# --------------------------------------------- misaligned-pump visibility --
def test_misaligned_pump_factor_warns_in_report(tmp_path):
    """factor=3 does not divide the 64-element FIFO sequence: the gearbox
    degrades to pass-through and the report says so (counted, not silent)."""
    g, _ = BUILDERS["vecadd"](64, vector_width=2)
    kern = compiler.compile(g, factor=3, backend="jax",
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    assert kern.report.warning_count > 0
    assert any("not divisible by pump factor 3" in w
               for w in kern.report.warnings)
    assert f"warn={kern.report.warning_count}" in kern.report.summary()
    # degraded, but still value-exact
    x = np.arange(64, dtype=np.float32)
    y = np.ones(64, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(kern({"x": x, "y": y})["z"]),
                                  x + y)


# ------------------------------------------------- issuer/packer identity --
def test_issuer_packer_round_trip_identity():
    x = np.arange(64, dtype=np.float32)
    for m in (1, 2, 4, 8):
        np.testing.assert_array_equal(
            np.asarray(_temporal_rechunk(jnp.asarray(x), m)), x)
    # issuer ∘ packer over the same factor is the identity (paper's gearbox)
    z = _temporal_rechunk(_temporal_rechunk(jnp.asarray(x), 4), 4)
    np.testing.assert_array_equal(np.asarray(z), x)


# ------------------------------------------------------------ new passes --
def test_stream_fusion_collapses_memory_roundtrip():
    g = chain_graph(32, 4)
    sg, _ = apply_streaming(g)
    assert "t" in sg.nodes
    fuse = StreamFusionPass()
    ok, why = fuse.can_apply(sg)
    assert ok, why
    fg, rep = fuse.apply(sg)
    assert len(rep.fused) == 1
    assert "t" not in fg.nodes                      # memory round-trip gone
    assert len(fg.streams()) == len(sg.streams()) - 1
    # value preservation through the fused pipeline
    rng = np.random.default_rng(3)
    x = _ints(rng, 32)
    out = executor.run(fg, {"x": x})["z"]
    np.testing.assert_array_equal(out, (x + 1.0) * 2.0)


def test_stream_fusion_respects_keep_marker():
    g = chain_graph(32, 4)
    g.nodes["t"].meta["keep"] = True
    sg, _ = apply_streaming(g)
    ok, _ = StreamFusionPass().can_apply(sg)
    assert not ok


def test_fused_then_pumped_chain_differential(tmp_path):
    g = chain_graph(32, 4)
    kern = compiler.compile(g, factor=2,
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    assert kern.report.record("stream-fusion").applied
    assert "t" not in kern.graph.nodes
    rng = np.random.default_rng(4)
    x = _ints(rng, 32)
    out = np.asarray(kern({"x": x})["z"])
    np.testing.assert_array_equal(out, (x + 1.0) * 2.0)
    np.testing.assert_array_equal(out, executor.run(kern.graph, {"x": x})["z"])


def test_fifo_depth_sized_from_pump_factor():
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    sg, _ = apply_streaming(g)
    pg, _ = apply_multipump(sg, factor=4)
    assert all(s.depth == 2 for s in pg.streams())  # seed default
    out, rep = FifoDepthPass().apply(pg)
    assert rep.resized
    # boundary FIFOs hold a wide transaction: depth = 2 * M
    for s in out.streams():
        assert s.depth == 8, s.name
    # unpumped graphs keep the double-buffer minimum
    out2, _ = FifoDepthPass().apply(sg)
    assert all(s.depth == 2 for s in out2.streams())


def test_stream_fusion_preserves_operand_order():
    """The fused edge must take the consumed edge's position: executors bind
    compute operands (in0, in1, ...) by edge insertion order."""
    n, v = 32, 4
    g = Graph("oporder")
    g.memory("x", (n,))
    g.memory("t", (n,))
    g.memory("y", (n,))
    g.memory("z", (n,))
    dom = Domain.of(("i", 0, n // v))
    acc = AccessPattern(dom, (Affine.of("i", v),), width=v)
    g.compute("add1", dom, fn=lambda in0: {"out0": in0 + 1.0}, vector_width=v)
    # 'sub' reads the intermediate t as in0 and fresh input y as in1
    g.compute("sub", dom, fn=lambda in0, in1: {"out0": in0 - in1},
              vector_width=v)
    g.connect("x", "add1", acc)
    g.connect("add1", "t", acc)
    g.connect("t", "sub", acc)
    g.connect("y", "sub", acc)
    g.connect("sub", "z", acc)

    rng = np.random.default_rng(11)
    x, y = _ints(rng, n), _ints(rng, n, 50, 100)
    gold = (x + 1.0) - y
    sg, _ = apply_streaming(g)
    fg, rep = StreamFusionPass().apply(sg)
    assert rep.fused
    np.testing.assert_array_equal(
        executor.run(fg, {"x": x, "y": y})["z"], gold)


def test_stream_fusion_cascaded_chains():
    """Two chains sharing a stream must fuse iteratively, not crash."""
    n, v = 32, 4
    g = Graph("cascade")
    g.memory("x", (n,))
    g.memory("t1", (n,))
    g.memory("t2", (n,))
    g.memory("z", (n,))
    dom = Domain.of(("i", 0, n // v))
    acc = AccessPattern(dom, (Affine.of("i", v),), width=v)
    g.compute("a", dom, fn=lambda in0: {"out0": in0 + 1.0}, vector_width=v)
    g.compute("b", dom, fn=lambda in0: {"out0": in0 * 2.0}, vector_width=v)
    g.compute("c", dom, fn=lambda in0: {"out0": in0 - 3.0}, vector_width=v)
    g.connect("x", "a", acc)
    g.connect("a", "t1", acc)
    g.connect("t1", "b", acc)
    g.connect("b", "t2", acc)
    g.connect("t2", "c", acc)
    g.connect("c", "z", acc)
    sg, _ = apply_streaming(g)
    fg, rep = StreamFusionPass().apply(sg)
    assert len(rep.fused) == 2
    assert "t1" not in fg.nodes and "t2" not in fg.nodes
    rng = np.random.default_rng(12)
    x = _ints(rng, n)
    np.testing.assert_array_equal(executor.run(fg, {"x": x})["z"],
                                  (x + 1.0) * 2.0 - 3.0)


def test_shared_stream_widened_once():
    """A stream bordering the pumped region on both sides (post-fusion) must
    be widened by M, not M^2 — M^2 inflates the resource model and can make
    check_multipump spuriously reject a feasible factor."""
    g = chain_graph(32, 4)
    sg, _ = apply_streaming(g)
    fg, _ = StreamFusionPass().apply(sg)
    pg, rep = apply_multipump(fg, factor=4)
    assert rep.applied
    shared = [s for s in pg.streams() if s.name == "s_add1_t"]
    assert shared and shared[0].elem_width == 4 * 4   # v * M, not v * M^2


def test_memo_distinguishes_closure_values(tmp_path):
    """Structurally identical graphs whose fn closures capture different
    values must not share a memo entry."""
    compiler.clear_memo()

    def build(scale):
        g = Graph("closure")
        g.memory("x", (8,))
        g.memory("z", (8,))
        dom = Domain.of(("i", 0, 8))
        acc = AccessPattern(dom, (Affine.of("i"),))
        g.compute("mul", dom, fn=lambda in0: {"out0": in0 * scale})
        g.connect("x", "mul", acc)
        g.connect("mul", "z", acc)
        return g

    cache = CompileCache(tmp_path / "c.json")
    x = np.arange(8, dtype=np.float32)
    k2 = compiler.compile(build(2.0), factor=1, cache=cache)
    k3 = compiler.compile(build(3.0), factor=1, cache=cache)
    np.testing.assert_array_equal(np.asarray(k2({"x": x})["z"]), x * 2.0)
    np.testing.assert_array_equal(np.asarray(k3({"x": x})["z"]), x * 3.0)


# ----------------------------------------------------- pump_mode regression --
def test_apply_multipump_records_pump_mode():
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    sg, _ = apply_streaming(g)
    pg, rep = apply_multipump(sg, factor=2, mode="R")
    assert rep.applied
    comp = pg.computes()[0]
    assert comp.meta["pump_mode"] == "R"
    assert pump_spec_for(pg, comp.name).mode == "R"


# ------------------------------------------------------------------ cache --
def test_compile_cache_persists_across_instances(tmp_path):
    path = tmp_path / "cache.json"
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    c1 = CompileCache(path)
    k1 = compiler.compile(g, factor=2, cache=c1, memoize=False)
    assert k1.report.served_from is None and k1.report.cache_hits == 0
    assert c1.stats["entries"] == 1

    c2 = CompileCache(path)   # fresh instance ≙ fresh process
    k2 = compiler.compile(g, factor=2, cache=c2, memoize=False)
    assert k2.report.served_from == "disk"
    assert k2.report.cache_hits == 1
    assert c2.stats["hits"] == 1

    rng = np.random.default_rng(5)
    inputs = {"x": _ints(rng, 64), "y": _ints(rng, 64)}
    np.testing.assert_array_equal(np.asarray(k1(inputs)["z"]),
                                  np.asarray(k2(inputs)["z"]))


def test_compile_memo_serves_repeat_requests(tmp_path):
    compiler.clear_memo()
    cache = CompileCache(tmp_path / "cache.json")
    g1, _ = BUILDERS["vecadd"](64, vector_width=8)
    k1 = compiler.compile(g1, factor=2, cache=cache)
    g2, _ = BUILDERS["vecadd"](64, vector_width=8)   # structural rebuild
    k2 = compiler.compile(g2, factor=2, cache=cache)
    assert k2.fn is k1.fn and k2.graph is k1.graph   # compiled artifact shared
    assert k2.report.served_from == "memory" and k2.report.cache_hits >= 1
    # the cold compile's provenance record is not rewritten by later hits
    assert k1.report.served_from is None and k1.report.cache_hits == 0
    # a memo hit writes the plan through to a persistent cache that has
    # not seen the request yet
    fresh = CompileCache(tmp_path / "fresh.json")
    k3 = compiler.compile(g2, factor=2, cache=fresh)
    assert k3.report.served_from == "memory"
    assert (tmp_path / "fresh.json").exists() and len(fresh) == 1


def test_plan_shared_across_backends(tmp_path):
    """The persistent plan is backend-independent: an autopump-style
    backend='none' compile must warm the cache for a jax-backend compile."""
    compiler.clear_memo()
    cache = CompileCache(tmp_path / "c.json")
    g, est = BUILDERS["vecadd"](64, vector_width=8)
    k_none = compiler.compile(g, factor="auto", estimate=est, backend="none",
                              cache=cache, memoize=False)
    k_jax = compiler.compile(g, factor="auto", estimate=est, backend="jax",
                             cache=cache, memoize=False)
    assert k_jax.report.served_from == "disk"
    assert k_jax.spec.factor == k_none.spec.factor


def test_memo_distinguishes_array_closures():
    """repr() elides the middle of large arrays; the memo must still tell
    two captured weight tables apart (hashes the buffer, not the repr)."""
    compiler.clear_memo()
    n = 2048

    def build(w):
        g = Graph("wclosure")
        g.memory("x", (n,))
        g.memory("z", (n,))
        dom = Domain.of(("i", 0, n))
        acc = AccessPattern(dom, (Affine.of("i"),))
        g.compute("addw", dom, fn=lambda in0: {"out0": in0 + w})
        g.connect("x", "addw", acc)
        g.connect("addw", "z", acc)
        return g

    w1 = np.zeros(n, np.float32)
    w2 = w1.copy()
    w2[n // 2] = 5.0
    assert repr(w1) == repr(w2)          # the trap this test guards against
    x = np.zeros(n, np.float32)
    k1 = compiler.compile(build(w1), factor=1, cache=False)
    k2 = compiler.compile(build(w2), factor=1, cache=False)
    np.testing.assert_array_equal(np.asarray(k1({"x": x})["z"]), w1)
    np.testing.assert_array_equal(np.asarray(k2({"x": x})["z"]), w2)


def test_core_import_stays_jax_free():
    """repro.core must not drag in jax (the compiler re-export is lazy)."""
    import os
    import subprocess
    import sys
    r = subprocess.run(
        [sys.executable, "-c",
         "import repro.core, sys; print('jax' in sys.modules)"],
        capture_output=True, text=True, env=dict(os.environ))
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == "False"
    # ... while the lazy attribute still resolves
    from repro.core import compiler as via_core
    assert via_core.compile is compiler.compile


def test_fingerprint_distinguishes_structure():
    g1, _ = BUILDERS["vecadd"](64, vector_width=8)
    g2, _ = BUILDERS["vecadd"](64, vector_width=8)
    g3, _ = BUILDERS["vecadd"](128, vector_width=8)
    assert graph_fingerprint(g1) == graph_fingerprint(g2)
    assert graph_fingerprint(g1) != graph_fingerprint(g3)
    sg, _ = apply_streaming(g1)
    assert graph_fingerprint(sg) != graph_fingerprint(g1)


# ------------------------------------------------------- registry/pipeline --
def test_pass_registry_and_default_order():
    assert {"streaming", "stream-fusion", "multipump", "fifo-depth"} \
        <= set(PASS_REGISTRY)
    pipe = Pipeline.default(factor=2)
    assert [p.name for p in pipe.passes] == \
        ["streaming", "stream-fusion", "multipump", "fifo-depth"]
    assert isinstance(make_pass("fifo-depth"), FifoDepthPass)
    with pytest.raises(KeyError):
        make_pass("nope")


def test_pipeline_records_skipped_passes(tmp_path):
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    kern = compiler.compile(g, factor=1,
                            cache=CompileCache(tmp_path / "c.json"),
                            memoize=False)
    rec = kern.report.record("multipump")
    assert rec is not None and not rec.applied and rec.reason
    assert kern.spec.factor == 1
    # streamed but unpumped: no adapter modules
    assert kern.graph.resources()["adapters"] == 0


# -------------------------------------------------------------- autopump --
def test_autopump_routes_through_pipeline(tmp_path):
    compiler.clear_memo()
    cache = CompileCache(tmp_path / "cache.json")
    r = autopump("vecadd", 4096, cache=cache)
    assert r.pipeline_report is not None
    assert [rec.name for rec in r.pipeline_report.records][0] == "streaming"
    assert r.pipeline_report.factor == r.spec.factor
    # second call is served from a cache layer (O(1) repeat compiles)
    r2 = autopump("vecadd", 4096, cache=cache)
    assert r2.pipeline_report.served_from in ("memory", "disk")
    assert r2.pipeline_report.cache_hits >= 1
    assert r2.spec == r.spec
