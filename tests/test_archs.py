"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import ARCH_IDS, ShapeConfig, load_arch
from repro.launch import steps as steps_mod
from repro.models import model as model_mod

SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch, rng):
    cfg = load_arch(arch, smoke=True)
    params = model_mod.init_params(cfg, rng)
    batch = model_mod.example_batch(cfg, SHAPE)
    logits, aux = model_mod.forward(cfg, params, batch)
    b, s = batch["tokens"].shape
    expect_s = s + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, rng):
    cfg = load_arch(arch, smoke=True)
    optcfg = optim.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    params = model_mod.init_params(cfg, rng)
    opt_state = optim.init(optcfg, params)
    step = steps_mod.make_train_step(cfg, optcfg)
    batch = model_mod.example_batch(cfg, SHAPE)
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-1.3b",
                                  "deepseek-v2-lite-16b", "zamba2-2.7b"])
def test_decode_consistency(arch, rng):
    """Prefill + token-by-token decode must match the full forward pass."""
    cfg = load_arch(arch, smoke=True)
    params = model_mod.init_params(cfg, rng)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    from repro.models import transformer
    logits_full, _ = transformer.forward(cfg, params, toks)
    cache = model_mod.init_cache(cfg, 2, 16, jnp.float32)
    lo, cache = transformer.decode_step(cfg, params, toks[:, :4], cache)
    outs = [lo]
    for t in range(4, 8):
        lo, cache = transformer.decode_step(cfg, params, toks[:, t:t + 1],
                                            cache)
        outs.append(lo)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=2e-3)


def test_pallas_attention_path_matches_xla(rng):
    """attention_impl='pallas' (the TPU kernel, interpret mode) agrees with
    the xla_chunked path on a smoke config."""
    import dataclasses
    cfg = load_arch("qwen3-0.6b", smoke=True)
    cfg_pl = dataclasses.replace(cfg, attention_impl="pallas")
    params = model_mod.init_params(cfg, rng)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    from repro.models import transformer
    lx, _ = transformer.forward(cfg, params, toks)
    lp, _ = transformer.forward(cfg_pl, params, toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx), atol=2e-3)


def test_pallas_ssm_path_matches_xla(rng):
    import dataclasses
    cfg = load_arch("mamba2-1.3b", smoke=True)
    cfg_pl = dataclasses.replace(cfg, ssm_impl="pallas")
    params = model_mod.init_params(cfg, rng)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    from repro.models import transformer
    lx, _ = transformer.forward(cfg, params, toks)
    lp, _ = transformer.forward(cfg_pl, params, toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx), atol=2e-3)


def test_param_counts_match_published_sizes():
    """Analytic parameter counts are within tolerance of the published
    model sizes (the configs are faithful)."""
    expected = {
        "mamba2-1.3b": (1.34e9, 0.05),
        "deepseek-v3-671b": (671e9, 0.01),
        "deepseek-v2-lite-16b": (15.7e9, 0.05),
        "qwen2.5-14b": (14.7e9, 0.05),
        "qwen2-7b": (7.6e9, 0.05),
        "qwen3-0.6b": (0.6e9, 0.10),
        "granite-3-2b": (2.5e9, 0.10),
        "zamba2-2.7b": (2.7e9, 0.15),
    }
    for arch, (target, tol) in expected.items():
        got = load_arch(arch).param_count()
        assert abs(got - target) / target < tol, (arch, got, target)
