"""Optional-``hypothesis`` shim for the property-based tests.

The container may not have ``hypothesis`` installed (it is an optional test
extra, see pyproject.toml).  When it is available this module re-exports the
real ``given``/``settings``/``st``; otherwise it provides stand-ins that turn
each ``@given`` test into a single skipped test so the rest of the suite
still collects and runs green.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    import pytest as _pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """st.integers(...), st.floats(...), ... all become inert stubs."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # plain zero-arg stub: pytest must not see the original
            # parametrized signature (it would demand fixtures for it)
            def skipped():
                _pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
