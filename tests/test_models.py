"""Model-component tests: MoE semantics, attention equivalences, layers,
and hypothesis properties on the building blocks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models import attention as attn_mod
from repro.models import layers, moe as moe_mod
from repro.kernels import ref


def moe_cfg(**kw):
    base = dict(n_experts=8, n_shared_experts=1, top_k=2, d_expert=16)
    base.update(kw)
    return ModelConfig("m", "moe", 1, 32, 4, 4, 64, 128, dtype="float32",
                       moe=MoEConfig(**base))


# ----------------------------------------------------------------- MoE -----
def test_moe_dropless_processes_every_token():
    cfg = moe_cfg()
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y1, _ = moe_mod.moe_apply(p, cfg, x, dropless=True)
    # doubling capacity_factor must not change the dropless result
    cfg2 = moe_cfg(capacity_factor=99.0)
    y2, _ = moe_mod.moe_apply(p, cfg2, cfg2 and x, dropless=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_moe_capacity_drops_overflow():
    """With pathologically low capacity some tokens are dropped (their
    routed contribution is zero) but the shared expert still applies."""
    cfg = moe_cfg(capacity_factor=1e-6, n_shared_experts=0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    y, _ = moe_mod.moe_apply(p, cfg, x, dropless=False)
    # cap=1 slot per expert: most routed outputs are zero
    zeros = np.isclose(np.asarray(y), 0.0, atol=1e-6).mean()
    assert zeros > 0.2


def test_moe_aux_loss_prefers_balance():
    cfg = moe_cfg()
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    _, aux = moe_mod.moe_apply(p, cfg, x)
    assert float(aux) >= 0
    # router collapse (all tokens to one expert) must raise the aux loss:
    p_bad = jax.tree.map(lambda a: a, p)
    p_bad["router"]["w"] = p["router"]["w"].at[:, 0].add(100.0)
    _, aux_bad = moe_mod.moe_apply(p_bad, cfg, x)
    assert float(aux_bad) > float(aux)


def test_moe_gate_renormalization_partition_of_unity():
    """Gates renormalize over top-k: outputs scale-invariant to a uniform
    router logit shift."""
    cfg = moe_cfg(n_shared_experts=0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    y1, _ = moe_mod.moe_apply(p, cfg, x, dropless=True)
    p2 = jax.tree.map(lambda a: a, p)
    p2["router"] = dict(p["router"])
    y2, _ = moe_mod.moe_apply(p2, cfg, x, dropless=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


# ------------------------------------------------------------- attention ---
@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([8, 16, 24]), block=st.sampled_from([4, 8, 64]),
       causal=st.booleans())
def test_chunked_attention_block_invariance(s, block, causal):
    """The KV block size (the pump knob) must never change values."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, s, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, s, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, s, 8))
    out = attn_mod.chunked_attention(q, k, v, causal=causal, block=block)
    gold = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=2e-5)


def test_chunked_attention_gqa_matches_broadcast():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 16, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 16, 8))
    out = attn_mod.chunked_attention(q, k, v, causal=True, block=8)
    gold = ref.attention(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                         causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=2e-5)


def test_mla_absorbed_decode_matches_decompressed():
    """The absorbed decode path must agree with decompress-then-attend."""
    cfg = ModelConfig("mla", "dense", 1, 32, 4, 4, 64, 128, dtype="float32",
                      mla=MLAConfig(kv_lora_rank=16, q_lora_rank=0,
                                    rope_head_dim=4, nope_head_dim=8,
                                    v_head_dim=8))
    p = attn_mod.mla_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))
    full, _ = attn_mod.mla_apply(p, cfg, x, positions=jnp.arange(6))
    cache = attn_mod.mla_cache_init(cfg, 2, 8, jnp.float32)
    out5, cache = attn_mod.mla_apply(p, cfg, x[:, :5],
                                     positions=jnp.arange(5), cache=cache)
    out6, _ = attn_mod.mla_apply(p, cfg, x[:, 5:6],
                                 positions=jnp.arange(5, 6), cache=cache)
    np.testing.assert_allclose(np.asarray(out6), np.asarray(full[:, 5:6]),
                               atol=2e-4)


# ----------------------------------------------------------------- layers --
@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([8, 16, 64]), seed=st.integers(0, 1000))
def test_rmsnorm_scale_invariance(d, seed):
    """rmsnorm(c·x) == rmsnorm(x) for any positive scalar c."""
    p = layers.rmsnorm_init(d)
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, d))
    a = layers.rmsnorm(p, x)
    b = layers.rmsnorm(p, 7.3 * x)
    # exact invariance is broken only by eps=1e-5 inside rsqrt
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_rope_relative_position_property():
    """RoPE inner products depend only on relative positions."""
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, d))
    q0 = layers.apply_rope(x, jnp.array([[0, 1, 2, 3]]))
    q5 = layers.apply_rope(x, jnp.array([[5, 6, 7, 8]]))
    dot0 = jnp.einsum("bsd,btd->bst", q0, q0)
    dot5 = jnp.einsum("bsd,btd->bst", q5, q5)
    np.testing.assert_allclose(np.asarray(dot0), np.asarray(dot5), atol=1e-4)


def test_cross_entropy_ignores_masked_labels():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8))
    labels = jnp.array([[1, 2, -100, -100], [3, -100, -100, -100]])
    l1 = layers.cross_entropy(logits, labels)
    # changing logits at masked positions must not change the loss
    logits2 = logits.at[:, 2:].add(100.0)
    l2 = layers.cross_entropy(logits2, labels)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_last_only_forward_matches_full():
    from repro.models import transformer, model as model_mod
    cfg = ModelConfig("t", "dense", 2, 32, 4, 2, 64, 64, dtype="float32")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    full, _ = transformer.forward(cfg, params, toks)
    last, _ = transformer.forward(cfg, params, toks, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-5)


# --------------------------------------------------------------- optimizer --
def test_adamw_bf16_moments_track_fp32():
    from repro import optim
    cfg32 = optim.AdamWConfig(lr=1e-2, grad_clip=0.0, weight_decay=0.0)
    cfg16 = dataclasses.replace(cfg32, moment_dtype="bfloat16")
    params = {"w": jnp.ones((64,))}
    s32, s16 = optim.init(cfg32, params), optim.init(cfg16, params)
    g = {"w": jnp.full((64,), 0.1)}
    p32, p16 = dict(params), dict(params)
    for _ in range(5):
        p32, s32, _ = optim.update(cfg32, g, s32, p32)
        p16, s16, _ = optim.update(cfg16, g, s16, p16)
    err = float(jnp.abs(p32["w"] - p16["w"]).max())
    assert err < 5e-3, err


def test_grad_compression_error_feedback_converges():
    from repro.optim import compress
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1024,))}
    err = None
    acc_true = jnp.zeros((1024,))
    acc_q = jnp.zeros((1024,))
    for i in range(20):
        q, err = compress.quantize(g, err)
        deq = compress.dequantize(q, g)
        acc_true += g["w"]
        acc_q += deq["w"]
    # error feedback keeps the accumulated quantized stream unbiased
    rel = float(jnp.linalg.norm(acc_q - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel
