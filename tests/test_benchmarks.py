"""Tier-1 wiring for the compiler benchmark smoke path (`make bench-smoke`):
runs the tiny-shape report in-process and checks the JSON contract the
cross-PR perf tracking relies on."""
import json

import pytest


@pytest.fixture()
def bench_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    return tmp_path


def test_compiler_bench_smoke_writes_json(bench_cache, tmp_path, capsys):
    from benchmarks import compiler_report

    out = tmp_path / "BENCH_compiler_smoke.json"
    report = compiler_report.run_report(smoke=True, out_path=out)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["smoke"] is True

    # one entry per kernel x backend x factor; exactly-representable
    # kernels are bit-exact, the exp-bearing carry kernels are 'close'
    # (numpy vs XLA exp differs by 1 ULP — see tests/differential.py)
    kernels = {e["kernel"] for e in report["entries"]}
    assert kernels == {"vecadd", "matmul", "flash_attention", "ssd_scan",
                       "grouped_gemm"}
    assert {e["backend"] for e in report["entries"]} == {"jax", "pallas"}
    for e in report["entries"]:
        if e["kernel"] in ("flash_attention", "ssd_scan"):
            assert e["parity"] in ("bitexact", "close"), e
        else:
            assert e["parity"] == "bitexact", e
        assert e["wall_us"] > 0 and e["compile_cold_us"] > 0
        assert e["cache_warm"] in ("disk", "memory")
    # the carry kernels emit through the carry-aware tier on CPU
    carry_tiers = {t for e in report["entries"]
                   if e["kernel"] in ("flash_attention", "ssd_scan")
                   for t in e["emission"]}
    assert carry_tiers <= {"carryloop", "pallas"}

    # autotune: repeat compile is a cache hit that skipped re-measurement
    for name, a in report["autotune"].items():
        assert a["replay_served_from"] == "disk", name
        assert a["replay_skipped_measurement"] is True, name
        assert a["replay_compile_us"] < a["measure_compile_us"], name

    # the headline comparison exists for the tracked factors
    assert set(report["matmul_pallas_speedup_vs_jax"]) == {"1", "2", "4"}
    # CSV rows were emitted alongside the JSON
    assert "compiler_matmul_pallas_M2" in capsys.readouterr().out


def test_serve_bench_smoke_writes_json(bench_cache, tmp_path, capsys):
    """Tier-1 wiring for `make bench-serve-smoke`: the serving-path report
    must show a 100% post-warmup plan hit rate, per-layer registry-vs-
    default-pump entries with measured pump factors, parity between the two
    paths, the per-token decode rows (schema 2 — a silently-dropped decode
    measurement must fail tier-1), and the engine's warmup/compile/steady
    timing split."""
    from benchmarks import serve_report

    out = tmp_path / "BENCH_serve_smoke.json"
    report = serve_report.run_report(smoke=True, out_path=out)
    assert out.exists()
    assert json.loads(out.read_text())["smoke"] is True
    assert report["schema"] >= 2

    layers = {e["layer"]: e for e in report["entries"]}
    assert set(layers) == {"attention", "ssm", "moe",
                           "attention_decode", "ssm_decode"}
    for e in report["entries"]:
        assert e["registry_us"] > 0 and e["direct_us"] > 0
        assert e["plan_factor"] >= 1 and e["default_factor"] == 1
        # registry path parity vs the direct default-pump path: bit-exact
        # or fp-accumulation noise from a different pump factor
        assert e["max_abs_err"] < 5e-5, e
    # the flash/ssd plans came from measured autotune; the ragged MoE
    # plans are capacity-planned and must say so
    assert layers["attention"]["plan_measured"] is True
    assert layers["ssm"]["plan_measured"] is True
    assert layers["moe"]["plan_measured"] is False

    # decode rows: the per-token fast path is kernelized, measured, and
    # phase-tagged (the stats split below proves its buckets were warm)
    for name, kernel in (("attention_decode", "decode_attention"),
                         ("ssm_decode", "ssd_decode")):
        assert layers[name]["phase"] == "decode"
        assert layers[name]["kernel"] == kernel
        assert layers[name]["plan_measured"] is True
    assert all(e["phase"] in ("prefill", "decode")
               for e in report["entries"])

    # the grid warmup makes steady-state lookups pure hits
    assert report["plan_hit_rate_post_warmup"] == 1.0
    assert report["plans_warmed"] >= 1
    assert report["registry"]["fallbacks"] == 0
    # per-phase split is part of the stats schema, and the decode phase
    # actually served lookups in this run
    for phase in ("prefill", "decode"):
        assert set(report["registry"][phase]) == {"hits", "misses"}
    assert report["registry"]["decode"]["hits"] > 0

    # engine timing split: warmup/compile never pollute steady-state
    dec = report["engine"]["phases"]["decode"]
    assert dec["steps"] >= 1 and dec["compile_s"] > 0
    assert dec["steady_mean_s"] is not None
    assert dec["steady_mean_s"] < dec["compile_s"]
    assert "serve_plan_hit_rate" in capsys.readouterr().out
