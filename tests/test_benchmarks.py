"""Tier-1 wiring for the compiler benchmark smoke path (`make bench-smoke`):
runs the tiny-shape report in-process and checks the JSON contract the
cross-PR perf tracking relies on."""
import json

import pytest


@pytest.fixture()
def bench_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    return tmp_path


def test_compiler_bench_smoke_writes_json(bench_cache, tmp_path, capsys):
    from benchmarks import compiler_report

    out = tmp_path / "BENCH_compiler_smoke.json"
    report = compiler_report.run_report(smoke=True, out_path=out)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["smoke"] is True

    # one entry per kernel x backend x factor; exactly-representable
    # kernels are bit-exact, the exp-bearing carry kernels are 'close'
    # (numpy vs XLA exp differs by 1 ULP — see tests/differential.py)
    kernels = {e["kernel"] for e in report["entries"]}
    assert kernels == {"vecadd", "matmul", "flash_attention", "ssd_scan",
                       "grouped_gemm"}
    assert {e["backend"] for e in report["entries"]} == {"jax", "pallas"}
    for e in report["entries"]:
        if e["kernel"] in ("flash_attention", "ssd_scan"):
            assert e["parity"] in ("bitexact", "close"), e
        else:
            assert e["parity"] == "bitexact", e
        assert e["wall_us"] > 0 and e["compile_cold_us"] > 0
        assert e["cache_warm"] in ("disk", "memory")
    # the carry kernels emit through the carry-aware tier on CPU
    carry_tiers = {t for e in report["entries"]
                   if e["kernel"] in ("flash_attention", "ssd_scan")
                   for t in e["emission"]}
    assert carry_tiers <= {"carryloop", "pallas"}

    # autotune: repeat compile is a cache hit that skipped re-measurement
    for name, a in report["autotune"].items():
        assert a["replay_served_from"] == "disk", name
        assert a["replay_skipped_measurement"] is True, name
        assert a["replay_compile_us"] < a["measure_compile_us"], name

    # the headline comparison exists for the tracked factors
    assert set(report["matmul_pallas_speedup_vs_jax"]) == {"1", "2", "4"}
    # CSV rows were emitted alongside the JSON
    assert "compiler_matmul_pallas_M2" in capsys.readouterr().out


def test_serve_bench_smoke_writes_json(bench_cache, tmp_path, capsys):
    """Tier-1 wiring for `make bench-serve-smoke`: the serving-path report
    must show a 100% post-warmup plan hit rate, per-layer registry-vs-
    default-pump entries with measured pump factors, parity between the two
    paths, the per-token decode rows (schema 2 — a silently-dropped decode
    measurement must fail tier-1), and the engine's warmup/compile/steady
    timing split."""
    from benchmarks import serve_report

    out = tmp_path / "BENCH_serve_smoke.json"
    report = serve_report.run_report(smoke=True, out_path=out)
    assert out.exists()
    assert json.loads(out.read_text())["smoke"] is True
    assert report["schema"] >= 5

    layers = {e["layer"]: e for e in report["entries"]}
    assert set(layers) == {"attention", "ssm", "moe",
                           "attention_decode", "ssm_decode"}
    for e in report["entries"]:
        assert e["registry_us"] > 0 and e["direct_us"] > 0
        assert e["plan_factor"] >= 1 and e["default_factor"] == 1
        # registry path parity vs the direct default-pump path: bit-exact
        # or fp-accumulation noise from a different pump factor
        assert e["max_abs_err"] < 5e-5, e
    # the flash/ssd plans came from measured autotune; the ragged MoE
    # plans are capacity-planned and must say so
    assert layers["attention"]["plan_measured"] is True
    assert layers["ssm"]["plan_measured"] is True
    assert layers["moe"]["plan_measured"] is False

    # decode rows: the per-token fast path is kernelized, measured, and
    # phase-tagged (the stats split below proves its buckets were warm)
    for name, kernel in (("attention_decode", "decode_attention"),
                         ("ssm_decode", "ssd_decode")):
        assert layers[name]["phase"] == "decode"
        assert layers[name]["kernel"] == kernel
        assert layers[name]["plan_measured"] is True
    assert all(e["phase"] in ("prefill", "decode")
               for e in report["entries"])

    # the grid warmup makes steady-state lookups pure hits
    assert report["plan_hit_rate_post_warmup"] == 1.0
    assert report["plans_warmed"] >= 1
    assert report["registry"]["fallbacks"] == 0
    # per-phase split is part of the stats schema — including fallbacks,
    # so a decode-path kernel quietly degrading to jnp is visible per
    # phase — and the decode phase actually served lookups in this run
    for phase in ("prefill", "decode"):
        assert set(report["registry"][phase]) == {"hits", "misses",
                                                  "fallbacks"}
        assert report["registry"][phase]["fallbacks"] == 0
    assert report["registry"]["decode"]["hits"] > 0

    # engine timing split: warmup/compile never pollute steady-state
    dec = report["engine"]["phases"]["decode"]
    assert dec["steps"] >= 1 and dec["compile_s"] > 0
    assert dec["steady_mean_s"] is not None
    assert dec["steady_mean_s"] < dec["compile_s"]
    # warm/cold split + percentiles (satellite: StepTimer via obs.Histogram)
    assert dec["cold"]["calls"] == 1
    assert dec["warm"]["calls"] == dec["steps"]
    assert dec["warm"]["p50_s"] <= dec["warm"]["p99_s"]
    assert dec["steady_p50_s"] == dec["warm"]["p50_s"]

    # instrumentation overhead on the decode hot path, tracer off — the
    # real bar is <2%, a *benchmark-shape* property: there a decode step is
    # ~ms and the wrapper's ~5-10us of Python vanishes.  At smoke shapes
    # the step itself is ~100us, so the same wrapper reads as up to ~10%
    # before any box noise (a loaded shared runner has put even the
    # unmodified tree at 0.098); 25% here gates "instrumentation did not
    # blow up" without flaking on scheduler jitter
    oh = report["engine"]["obs_overhead"]
    assert oh["raw_us"] > 0 and oh["instrumented_us"] > 0
    assert oh["overhead_frac"] is not None and oh["overhead_frac"] < 0.25

    # robustness row: a fault-free benchmark run must not have walked the
    # degradation ladder — a nonzero count here means a kernel silently
    # regressed to a fallback path and the "speedup" rows above are lies
    rb = report["robustness"]
    assert rb["degraded_requests"] == 0
    assert rb["warmup_failed"] == 0
    assert rb["quarantined_plans"] == 0

    # schema 3: the throughput-under-load row exists fail-loud, like the
    # decode rows — a refactor that drops the continuous-batching
    # measurement must fail tier-1, not ship a report without it
    ld = report["load"]
    assert ld["n_requests"] >= 1 and ld["total_new_tokens"] > 0
    assert 0 < ld["arrival_rate"] <= 1 and ld["max_slots"] >= 1
    assert ld["stream_tokens_per_s"] > 0
    assert ld["sequential_tokens_per_s"] > 0
    assert ld["stream_speedup"] > 0
    # the ≥1.3x acceptance bar itself lives in tests/test_scheduler.py at
    # its controlled shapes; here the contract is presence + sanity
    assert ld["request_ttft_p50_s"] <= ld["request_ttft_p99_s"]
    assert ld["request_tpot_p50_s"] <= ld["request_tpot_p99_s"]
    assert ld["request_ttft_p50_s"] > 0 and ld["request_tpot_p50_s"] > 0
    assert ld["degraded_requests"] == 0

    # prefill flash: the carried-over sub-1.0x gap was per-call plan-lookup
    # overhead, closed by the registry's wrapper-level lookup memo — the
    # row must now land at parity or better, with no tracked warning (the
    # report re-rolls the paired minima before giving up, so a miss here
    # is a real regression, not box noise)
    pf = report["prefill_flash"]
    assert pf["speedup"] is not None and pf["speedup"] >= 1.0, pf
    assert pf["plan_measured"] is True and pf["plan_factor"] >= 1
    assert pf["tracked_warning"] is None

    # schema 4: the overload row exists fail-loud.  Virtual-step TTFT
    # percentiles are deterministic under the seed contract, so the
    # acceptance comparison is exact: chunked+preemptive+deadline-aware
    # scheduling must bound the admitted p99 TTFT at or below the
    # unbounded-FIFO baseline, and every request must be accounted for as
    # completed or shed-with-reason
    ov = report["overload"]
    assert ov["n_requests"] >= 1 and ov["arrival_rate"] > 1.0
    fifo, ctl = ov["fifo"], ov["controlled"]
    assert fifo["completed"] == ov["n_requests"] and fifo["shed"] == 0
    assert ctl["completed"] + ctl["shed"] == ov["n_requests"]
    assert ctl["shed"] > 0 and ctl["shed_rate"] > 0
    assert set(ctl["shed_reasons"]) <= {"queue_full", "deadline_unmeetable"}
    assert sum(ctl["shed_reasons"].values()) == ctl["shed"]
    assert ctl["ttft_steps_p99"] <= fifo["ttft_steps_p99"]
    assert ctl["ttft_steps_p50"] <= fifo["ttft_steps_p50"]
    for side in (fifo, ctl):
        assert side["ttft_steps_p50"] <= side["ttft_steps_p99"]
        assert side["ttft_p99_s"] > 0 and side["wall_s"] > 0

    # schema 5: the warm-start row exists fail-loud.  An offline tuner
    # fleet published a complete verified artifact, and the cold replica
    # preloading it did ZERO fresh autotune measurements at warmup — both
    # by the engine's own warmup accounting and by the registry.measure
    # counter delta.  A nonzero count means replicas silently re-tune and
    # the offline fleet is decorative.
    ws = report["warm_start"]
    assert ws["artifact_complete"] is True
    assert ws["artifact_entries"] == ws["groups"] >= 1
    assert ws["grid_dedupe"] >= 0
    assert ws["artifact_verified"] == ws["artifact_entries"]
    assert ws["artifact_rejected"] == 0
    assert ws["replica_warmup_measured"] == 0, ws
    assert ws["replica_measure_delta"] == 0, ws
    assert ws["plans_warmed"] >= 1
    assert ws["tune_s"] > 0 and ws["replica_warmup_s"] > 0
    # the scheduler's virtual clock can seed from the artifact's measured
    # winner timings before a single step has been served
    assert ws["step_time_seed_ms"] is not None and ws["step_time_seed_ms"] > 0

    # the embedded metrics snapshot is the report's flight-data: registry
    # counters + serving latency histograms must be present and non-empty
    snap = report["metrics"]
    assert snap["counters"], "metrics snapshot lost its counters"
    assert any(k.startswith("registry.") for k in snap["counters"])
    assert "serve.decode_step_s" in snap["histograms"]
    assert "serve.ttft_s" in snap["histograms"]
    assert "serve_plan_hit_rate" in capsys.readouterr().out


def test_bench_reports_embed_metrics_snapshot(bench_cache, tmp_path):
    """Both BENCH_* artifacts must carry the metrics snapshot on disk —
    a report without one is a blind artifact and run_report raises."""
    from benchmarks import compiler_report

    out = tmp_path / "BENCH_compiler_smoke.json"
    compiler_report.run_report(smoke=True, out_path=out)
    snap = json.loads(out.read_text())["metrics"]
    assert snap["counters"]
    # the compile path counted how each request was served
    assert any(k.startswith("compile.") or k.startswith("cache.")
               for k in snap["counters"])
    # emission-tier mix from the pallas backend
    assert any(k.startswith("emission.tier.") for k in snap["counters"])


def test_trace_smoke_launcher(bench_cache, tmp_path, capsys):
    """`make trace-smoke`: one traced Engine.generate() through the serve
    launcher produces valid Chrome-trace JSON — nested warmup/prefill/
    per-token-decode spans with monotonic timestamps."""
    from repro import obs
    from repro.launch import serve as serve_launch

    trace_path = tmp_path / "trace.json"
    try:
        serve_launch.main(["--arch", "qwen3-0.6b", "--smoke",
                           "--batch", "2", "--prompt-len", "8",
                           "--new", "3", "--kernel-plan", "measure",
                           "--trace", str(trace_path), "--metrics"])
    finally:
        obs.disable()
        obs.get_tracer().clear()
    assert trace_path.exists()
    trace = json.loads(trace_path.read_text())

    events = trace["traceEvents"]
    assert isinstance(events, list) and events
    for e in events:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        assert e["ph"] in ("X", "i", "M")
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0

    spans = {e["name"]: e for e in events if e["ph"] == "X"}
    assert {"serve.warmup", "serve.prefill", "serve.generate",
            "serve.decode"} <= set(spans)
    gen = spans["serve.generate"]
    decodes = sorted((e for e in events
                      if e["ph"] == "X" and e["name"] == "serve.decode"),
                     key=lambda e: e["ts"])
    assert len(decodes) == 3
    # decode spans nest inside generate and advance monotonically
    for d in decodes:
        assert gen["ts"] <= d["ts"]
        assert d["ts"] + d["dur"] <= gen["ts"] + gen["dur"] + 1
    assert all(a["ts"] < b["ts"] for a, b in zip(decodes, decodes[1:]))
    # TTFT is derivable from the generate span attributes
    assert gen["args"]["ttft_s"] > 0

    out = capsys.readouterr().out
    assert "trace written" in out
    assert "[metrics]" in out
