"""Tier-1 wiring for the compiler benchmark smoke path (`make bench-smoke`):
runs the tiny-shape report in-process and checks the JSON contract the
cross-PR perf tracking relies on."""
import json

import pytest


@pytest.fixture()
def bench_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    return tmp_path


def test_compiler_bench_smoke_writes_json(bench_cache, tmp_path, capsys):
    from benchmarks import compiler_report

    out = tmp_path / "BENCH_compiler_smoke.json"
    report = compiler_report.run_report(smoke=True, out_path=out)
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["smoke"] is True

    # one entry per kernel x backend x factor; exactly-representable
    # kernels are bit-exact, the exp-bearing carry kernels are 'close'
    # (numpy vs XLA exp differs by 1 ULP — see tests/differential.py)
    kernels = {e["kernel"] for e in report["entries"]}
    assert kernels == {"vecadd", "matmul", "flash_attention", "ssd_scan",
                       "grouped_gemm"}
    assert {e["backend"] for e in report["entries"]} == {"jax", "pallas"}
    for e in report["entries"]:
        if e["kernel"] in ("flash_attention", "ssd_scan"):
            assert e["parity"] in ("bitexact", "close"), e
        else:
            assert e["parity"] == "bitexact", e
        assert e["wall_us"] > 0 and e["compile_cold_us"] > 0
        assert e["cache_warm"] in ("disk", "memory")
    # the carry kernels emit through the carry-aware tier on CPU
    carry_tiers = {t for e in report["entries"]
                   if e["kernel"] in ("flash_attention", "ssd_scan")
                   for t in e["emission"]}
    assert carry_tiers <= {"carryloop", "pallas"}

    # autotune: repeat compile is a cache hit that skipped re-measurement
    for name, a in report["autotune"].items():
        assert a["replay_served_from"] == "disk", name
        assert a["replay_skipped_measurement"] is True, name
        assert a["replay_compile_us"] < a["measure_compile_us"], name

    # the headline comparison exists for the tracked factors
    assert set(report["matmul_pallas_speedup_vs_jax"]) == {"1", "2", "4"}
    # CSV rows were emitted alongside the JSON
    assert "compiler_matmul_pallas_M2" in capsys.readouterr().out
