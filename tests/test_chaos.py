"""Chaos suite: fault injection across the compile→serve path.

The robustness contract (docs/robustness.md): for every injection point —
cache IO error, corrupt plan JSON, emission failure, measurement timeout,
NaN kernel — ``Engine.generate()`` still completes, the tokens match the
fault-free run (logit parity ≤ 5e-6), and the expected degradation-reason
counter is incremented.  Plus the self-healing plan-store semantics:
quarantined plans are not re-attempted inside their backoff window,
corruption after warmup heals on the next cold process, and concurrent
cross-process writes merge instead of clobbering.
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.compiler.registry import PlanRegistry, set_default_registry
from repro.configs.base import load_arch
from repro.models import model as model_mod
from repro.serve.engine import Engine, ServeConfig
from repro.testing import faults

ARCH = "qwen3-0.6b"
BATCH, PROMPT, NEW, MAXLEN = 2, 8, 4, 16
PARITY = 5e-6


def _ctr(name: str) -> int:
    return obs.snapshot(include_views=False)["counters"].get(name, 0)


def _fresh_engine(warmup: bool = True) -> Engine:
    """Fresh-process simulation: cold kernel memo, fresh registry against
    the (env-selected) persistent cache, new engine.  clear_memo matters —
    a memo-served kernel was compiled before the fault rules existed and
    would bypass every injection seam."""
    from repro import compiler
    compiler.clear_memo()
    set_default_registry(PlanRegistry())
    cfg = dataclasses.replace(load_arch(ARCH, smoke=True),
                              attention_impl="pallas")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(batch=BATCH, max_len=MAXLEN,
                                           warmup=warmup))


def _prompts(cfg) -> jax.Array:
    return jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0,
                              cfg.vocab_size)


def _serve(eng: Engine):
    toks, lgs = eng.generate(_prompts(eng.cfg), NEW, return_logits=True)
    return np.asarray(toks), np.asarray(lgs)


@pytest.fixture(autouse=True)
def _chaos_env(tmp_path, monkeypatch):
    """Private persistent cache per test, default-registry isolation, and a
    guaranteed-clean fault table on the way out."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    old = set_default_registry(None)
    yield
    faults.clear()
    set_default_registry(old)


@pytest.fixture(scope="module")
def baseline(tmp_path_factory):
    """Fault-free reference run: (tokens, logits) every chaos case must
    reproduce.  Module-scoped — one warmup+generate pays for all cases."""
    cache_dir = str(tmp_path_factory.mktemp("baseline-cache"))
    prev_env = os.environ.get("REPRO_CACHE_DIR")
    os.environ["REPRO_CACHE_DIR"] = cache_dir
    prev_reg = set_default_registry(None)
    try:
        toks, lgs = _serve(_fresh_engine())
    finally:
        if prev_env is None:
            os.environ.pop("REPRO_CACHE_DIR", None)
        else:
            os.environ["REPRO_CACHE_DIR"] = prev_env
        set_default_registry(prev_reg)
    return toks, lgs


def _assert_parity(baseline, toks, lgs):
    base_toks, base_lgs = baseline
    np.testing.assert_array_equal(toks, base_toks)
    err = float(np.max(np.abs(lgs - base_lgs)))
    assert err <= PARITY, f"logit parity {err:.2e} > {PARITY:.0e}"


# --------------------------------------------------------- the fault matrix --
# (site, action, rule kwargs, counter that must move) — one row per
# injection point of the acceptance matrix; docs/robustness.md mirrors it.
MATRIX = [
    pytest.param("cache.load", "io_error", {}, "cache.corrupt",
                 id="cache-io-error"),
    pytest.param("cache.json", "truncate", {}, "cache.corrupt",
                 id="cache-json-truncate"),
    pytest.param("cache.json", "garbage", {}, "cache.corrupt",
                 id="cache-json-garbage"),
    pytest.param("emission.lower", "error", {}, "degrade.compile",
                 id="emission-failure"),
    pytest.param("compile.measure", "timeout", {"times": 1},
                 "compile.measure_failed", id="measure-timeout"),
    pytest.param("emission.exec", "nan", {},
                 "registry.spotcheck_failed", id="nan-kernel"),
]


@pytest.mark.parametrize("site,action,kwargs,counter", MATRIX)
def test_generate_completes_under_fault(baseline, tmp_path, site, action,
                                        kwargs, counter):
    from repro.compiler.cache import CompileCache, _default_path
    if site == "cache.json":
        # the mangle seam needs an existing file to corrupt; seed it with a
        # throwaway instance so the engine's default_cache still does its
        # first read with the rules installed
        CompileCache(_default_path()).put("seed", {"factor": 1})
    before = _ctr(counter)
    injected = _ctr("faults.injected")
    with faults.inject(faults.FaultRule(site, action, **kwargs)):
        toks, lgs = _serve(_fresh_engine())
    _assert_parity(baseline, toks, lgs)
    assert _ctr("faults.injected") > injected, "the fault never fired"
    assert _ctr(counter) > before, \
        f"{counter} did not move under a {site}/{action} fault"


def test_nan_kernel_is_quarantined_and_degraded(baseline, tmp_path):
    """The NaN row in detail: plan-time spot-check catches the poisoned
    pallas kernel, quarantines that rung, and the degraded jax recompile
    serves the request — degradation happens at *plan* time, so the request
    itself is never degraded mid-flight."""
    from repro.compiler import default_cache
    q_before = _ctr("cache.quarantine")
    skip_before = _ctr("cache.quarantine_skip")
    with faults.inject(faults.FaultRule("emission.exec", "nan")):
        eng = _fresh_engine()
        toks, lgs = _serve(eng)
    _assert_parity(baseline, toks, lgs)
    assert _ctr("cache.quarantine") > q_before
    # the degraded recompile hit the quarantine gate instead of re-paying
    # the known-bad pallas rung
    assert _ctr("cache.quarantine_skip") > skip_before
    entries = default_cache().quarantine_entries()
    assert entries and all(k.endswith(":pallas") for k in entries)
    assert all(e["reason"] == "nonfinite" for e in entries.values())
    # plan-time healing: the request was served off a good plan, not off
    # the engine's mid-request fallback
    assert eng.degraded_requests == 0


def test_midrequest_decode_fault_degrades_one_step(baseline, tmp_path):
    """An exception out of a single decode step re-runs that step through
    the plain-jnp bottom rung from the pre-step cache: same tokens, one
    degraded request counted."""
    before = _ctr("engine.degraded")
    served = _ctr("serve.degraded_request")
    rule = faults.FaultRule("engine.decode", "error", after=1, times=1)
    with faults.inject(rule):
        eng = _fresh_engine()
        toks, lgs = _serve(eng)
    assert rule.fired == 1
    _assert_parity(baseline, toks, lgs)
    assert eng.degraded_requests == 1
    assert eng.stats()["degraded_requests"] == 1
    assert _ctr("engine.degraded") > before
    assert _ctr("serve.degraded_request") > served


def test_registry_exec_fault_falls_back_one_rung(baseline, tmp_path):
    """A plan that starts failing on the serving path (after installation)
    degrades exactly one rung: the registry wrapper's reference fallback,
    counted per phase — not the engine's whole-step fallback."""
    before = _ctr("engine.degraded")
    with faults.inject(faults.FaultRule("registry.exec", "error", times=1)):
        eng = _fresh_engine()
        toks, lgs = _serve(eng)
    _assert_parity(baseline, toks, lgs)
    reg = eng._registry()
    assert reg.stats.fallbacks >= 1
    # one-rung contract: the wrapper absorbed it before the engine could
    assert _ctr("engine.degraded") == before
    assert eng.degraded_requests == 0


# ---------------------------------------------- continuous-batching chaos --
def _stream_reqs(cfg):
    from repro.serve import scheduler as sched
    return sched.synthetic_workload(4, seed=6, prompt_lens=(4, 8),
                                    new_tokens=(3,), arrival_rate=0.6,
                                    vocab=cfg.vocab_size)


STREAM_MATRIX = [
    # a prefill admitting new requests mid-stream fails
    pytest.param("engine.prefill", {"after": 1, "times": 1},
                 id="stream-prefill-fault"),
    # a decode step fails while later arrivals are still queued
    pytest.param("engine.decode", {"after": 2, "times": 1},
                 id="stream-decode-fault"),
    # reclaiming a finished request's slot fails
    pytest.param("sched.slot_free", {"times": 1},
                 id="stream-slot-free-fault"),
]


@pytest.mark.parametrize("site,kwargs", STREAM_MATRIX)
def test_stream_completes_under_fault(tmp_path, site, kwargs):
    """Scheduler-site injections: whatever fails mid-stream — a grouped
    prefill, a batched decode with queued requests, a slot reclaim — every
    in-flight request still completes with the fault-free tokens (the
    degradation ladder re-runs the step; a slot-free fault still frees the
    lane) and ``degraded_requests`` counts the affected requests."""
    eng = _fresh_engine()
    reqs = _stream_reqs(eng.cfg)
    clean = {r.rid: r.tokens for r in eng.serve_stream(reqs)}
    before = eng.degraded_requests
    served = _ctr("serve.degraded_request")
    rule = faults.FaultRule(site, "error", **kwargs)
    with faults.inject(rule):
        res = eng.serve_stream(reqs)
    assert rule.fired >= 1, "the fault never fired"
    assert len(res) == len(reqs), "a request was dropped under fault"
    for r in res:
        np.testing.assert_array_equal(r.tokens, clean[r.rid],
                                      err_msg=f"rid {r.rid} under {site}")
    n_deg = sum(1 for r in res if r.degraded)
    assert n_deg >= 1, "no request was marked degraded"
    assert eng.degraded_requests == before + n_deg
    assert _ctr("serve.degraded_request") > served
    if site == "sched.slot_free":
        # the lane was reclaimed regardless: nothing leaked, so the next
        # stream on the same engine still has every slot
        assert _ctr("sched.slot_free_fault") >= 1
        res2 = eng.serve_stream(reqs)
        assert len(res2) == len(reqs)


# ------------------------------------------------- overload-control chaos --
def _overload_reqs(cfg):
    """Deterministic overload trace for MAXLEN=16 engines: two low-priority
    long decodes fill both slots, a high-priority arrival forces a
    preemption, and the 8-token prompts exceed the 4-token chunk budget so
    every admission goes through chunked prefill."""
    from repro.serve import scheduler as sched
    rng = np.random.default_rng(3)
    toks = lambda n: rng.integers(0, cfg.vocab_size, n, dtype=np.int64)
    return [
        sched.Request(0, toks(8), 6, arrival=0, priority=0),
        sched.Request(1, toks(8), 6, arrival=0, priority=0),
        sched.Request(2, toks(4), 3, arrival=2, priority=5),
        sched.Request(3, toks(8), 2, arrival=3, priority=1),
    ]


def _overload_serve(eng, reqs):
    return eng.serve_stream(reqs, max_slots=2, prefill_chunk_tokens=4,
                            preempt_policy="lowest_priority")


OVERLOAD_MATRIX = [
    # one continuation-prefill chunk fails mid-admission
    pytest.param("engine.prefill_chunk", {"after": 1, "times": 1},
                 id="overload-prefill-chunk-fault"),
    # the preemption bookkeeping site fails while evicting a victim
    pytest.param("sched.preempt", {"times": 1},
                 id="overload-preempt-fault"),
    # zeroing the victim's cache rows fails
    pytest.param("sched.evict_rows", {"times": 1},
                 id="overload-evict-rows-fault"),
]


@pytest.mark.parametrize("site,kwargs", OVERLOAD_MATRIX)
def test_overload_stream_completes_under_fault(tmp_path, site, kwargs):
    """The overload-control sites: a fault in a prefill chunk (degradation
    ladder re-runs it on the plain-jnp rung) or in the preemption/eviction
    bookkeeping (absorbed, lane still parked + requeued) never drops a
    request — tokens match the fault-free overload run *and* each request's
    solo run, affected requests are counted degraded, and no slot leaks."""
    eng = _fresh_engine()
    reqs = _overload_reqs(eng.cfg)
    clean = {r.rid: r for r in _overload_serve(eng, reqs)}
    assert sum(r.preemptions for r in clean.values()) >= 1, \
        "the overload trace must exercise preemption"
    before = eng.degraded_requests
    rule = faults.FaultRule(site, "error", **kwargs)
    with faults.inject(rule):
        res = _overload_serve(eng, reqs)
    assert rule.fired >= 1, "the fault never fired"
    assert len(res) == len(reqs), "a request was dropped under fault"
    for r in res:
        np.testing.assert_array_equal(r.tokens, clean[r.rid].tokens,
                                      err_msg=f"rid {r.rid} under {site}")
    # solo parity: the faulted overload stream still serves every request
    # exactly as if it ran alone
    for req in reqs:
        solo = np.asarray(eng.generate(
            jnp.asarray(np.asarray(req.tokens))[None], req.n_new))[0]
        np.testing.assert_array_equal(clean[req.rid].tokens, solo,
                                      err_msg=f"rid {req.rid} vs solo")
    n_deg = sum(1 for r in res if r.degraded)
    assert n_deg >= 1, "no request was marked degraded"
    assert eng.degraded_requests == before + n_deg
    if site != "engine.prefill_chunk":
        assert _ctr(f"{site}_fault") >= 1
    # zero slot leaks: the same engine immediately serves the trace again
    res2 = _overload_serve(eng, reqs)
    assert len(res2) == len(reqs)
    for r in res2:
        np.testing.assert_array_equal(r.tokens, clean[r.rid].tokens)


# ------------------------------------------------------ quarantine/backoff --
def test_quarantine_backoff_window_respected(tmp_path):
    from repro import compiler
    from repro.compiler.cache import CompileCache, QuarantinePolicy
    from repro.core.autopump import BUILDERS

    compiler.clear_memo()
    pol = QuarantinePolicy(base_s=10.0, cap_s=40.0, budget=3)
    # exponential backoff, capped once the budget is spent
    assert [pol.window_s(n) for n in (1, 2, 3, 9)] == [10.0, 20.0, 40.0, 40.0]

    cache = CompileCache(tmp_path / "c.json", quarantine=pol)
    g, _ = BUILDERS["vecadd"](64, vector_width=8)
    args = dict(factor=2, backend="pallas", cache=cache, memoize=False)
    key = compiler.compile(g, **args).report.cache_key
    qkey = f"{key}:pallas"

    cache.record_failure(qkey, "nonfinite")
    # inside the window the rung is not re-attempted
    skip = _ctr("cache.quarantine_skip")
    with pytest.raises(compiler.PlanQuarantined):
        compiler.compile(g, **args)
    assert _ctr("cache.quarantine_skip") > skip
    # compile_degraded steps past it without re-recording the failure
    kern = compiler.compile_degraded(g, **args)
    assert kern.backend == "jax"
    assert cache.quarantine_entries()[qkey]["fails"] == 1
    assert any("degraded compile" in w for w in kern.report.warnings)

    # the ledger is persistent: a fresh store (new process) sees the entry
    assert CompileCache(tmp_path / "c.json",
                        quarantine=pol).quarantine_entries()[qkey]["fails"] == 1

    # an expired window requalifies the rung but keeps the failure count
    cache.record_failure(qkey, "nonfinite", now=time.time() - 3600.0)
    assert cache.quarantined(qkey) is None
    assert compiler.compile(g, **args).backend == "pallas"
    assert cache.quarantine_entries()[qkey]["fails"] == 2

    # a recorded success clears the entry entirely
    cache.record_success(qkey)
    assert qkey not in cache.quarantine_entries()
    assert qkey not in CompileCache(tmp_path / "c.json").quarantine_entries()


# ------------------------------------------------------- self-healing store --
def test_plan_store_heals_after_corruption_post_warmup(tmp_path):
    """Corrupting the store *after* a warm run must cost exactly one cold
    re-measure in the next process — never an error on the serving path —
    and the next save rewrites a valid file."""
    from repro.compiler import cache as cache_mod

    eng = _fresh_engine()
    first = _serve(eng)
    path = cache_mod._default_path()
    assert path.exists() and json.loads(path.read_text())["entries"]

    path.write_text("{not json!")
    corrupt = _ctr("cache.corrupt")
    # fresh process: cold memo, fresh CompileCache instance for the path
    cache_mod._DEFAULT_CACHES.clear()
    toks, lgs = _serve(_fresh_engine())
    np.testing.assert_array_equal(toks, first[0])
    assert float(np.max(np.abs(lgs - first[1]))) <= PARITY
    assert _ctr("cache.corrupt") > corrupt
    healed = json.loads(path.read_text())
    assert healed["version"] == 2 and healed["entries"]


def test_concurrent_cross_process_writes_merge(tmp_path):
    """Two processes writing the same store under the file lock merge their
    entries; last-writer-wins clobbering would drop one side's keys."""
    path = tmp_path / "shared" / "compile_cache.json"
    script = (
        "import sys\n"
        "from repro.compiler.cache import CompileCache\n"
        "c = CompileCache(sys.argv[1])\n"
        "for i in range(20):\n"
        "    c.put(f'{sys.argv[2]}-{i}', {'factor': 1})\n"
    )
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    procs = [subprocess.Popen([sys.executable, "-c", script, str(path), tag],
                              env=env, stderr=subprocess.PIPE)
             for tag in ("a", "b")]
    for p in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
    from repro.compiler.cache import CompileCache
    store = CompileCache(path)
    missing = [f"{tag}-{i}" for tag in ("a", "b") for i in range(20)
               if f"{tag}-{i}" not in store]
    assert not missing, f"lost under concurrent write: {missing}"


# ------------------------------------------------------------ warmup/engine --
def test_warmup_isolates_per_request_failures(tmp_path, monkeypatch):
    """One unplannable bucket yields a failure record with the error string
    — not an aborted grid — and the engine still serves afterwards."""
    from repro import compiler

    eng = _fresh_engine(warmup=False)
    failed = _ctr("registry.warmup_failed")

    def boom(*a, **kw):
        raise RuntimeError("injected warmup failure")

    with monkeypatch.context() as m:
        m.setattr(compiler, "compile_degraded", boom)
        report = eng.warmup()
    assert report and all("error" in r for r in report)
    assert all("injected warmup failure" in r["error"] for r in report)
    assert eng.stats()["warmup_failed"] == len(report)
    assert _ctr("registry.warmup_failed") > failed
    # the patch is lifted: serving compiles its plans on demand and works
    out = eng.generate(_prompts(eng.cfg), 2)
    assert out.shape == (BATCH, 2)


# ------------------------------------------------------------- train rungs --
def test_recovery_skips_corrupt_latest_checkpoint(tmp_path):
    """run_with_recovery's except path: a latest checkpoint whose payload
    fails hash verification is skipped (counted) and the previous valid one
    restores — the recovery loop never crashes on its own recovery data."""
    from repro.checkpoint import manager as ckpt
    from repro.runtime import failover

    root = str(tmp_path / "ckpt")
    calls = {"fail_at": 10}

    def train_fn(state, step):
        if step == calls["fail_at"]:
            calls["fail_at"] = None
            # corrupt the newest checkpoint, then die: recovery must fall
            # back to the previous valid one
            shard = os.path.join(root, "step_00000010", "shard_00000.npz")
            with open(shard, "r+b") as f:
                f.seek(10)
                f.write(b"\xde\xad\xbe\xef")
            raise failover.FailureInjected("simulated node loss")
        return {"x": state["x"] + 1.0}

    skipped = _ctr("failover.ckpt_skipped")
    final = failover.run_with_recovery(
        train_fn, {"x": jnp.zeros(())}, n_steps=12, ckpt_root=root,
        ckpt_every=5)
    assert float(final["x"]) == 12.0       # resumed from step 5, not 10
    assert _ctr("failover.ckpt_skipped") > skipped
    # the re-run re-saved step 10: the corrupt checkpoint healed in place
    assert ckpt.verify(os.path.join(root, "step_00000010"))


def test_trainer_wires_heartbeat_and_straggler(tmp_path):
    """The launch-path failover wiring: train() stamps the heartbeat every
    step and feeds step times to the straggler policy, gauging the derated
    pump factor."""
    from repro import optim
    from repro.configs.base import ModelConfig, ShapeConfig
    from repro.runtime.failover import Heartbeat, StragglerPolicy
    from repro.train.trainer import TrainConfig, train

    tiny = ModelConfig("tiny", "dense", 2, 32, 4, 2, 64, 64, dtype="float32")
    shape = ShapeConfig("t", 32, 8, "train")
    hb = Heartbeat(timeout_s=60.0)
    pol = StragglerPolicy()
    out = train(tiny, shape, optim.AdamWConfig(lr=1e-3, warmup_steps=1,
                                               total_steps=5),
                TrainConfig(n_steps=5, log_every=5),
                heartbeat=hb, straggler=pol, log=lambda *a, **k: None)
    worker = jax.process_index()
    assert hb._step[worker] == 5           # stamped through the last step
    assert hb.dead_workers() == []
    assert worker in pol._t                # EWMAs observed
    # the policy derates from the resolved pump, and the gauge is published
    assert pol.base_pump == out["pump"]
    snap = obs.snapshot(include_views=False)
    assert snap["gauges"].get("train.pump_derated") == out["pump"]


# ------------------------------------------------------- artifact warm start --
# Offline-tuner chaos (docs/robustness.md "Artifact lifecycle"): the
# warm-start path must degrade exactly like every other rung — an unreadable
# or corrupt artifact costs measurements, never correctness or availability.
ARTIFACT_MATRIX = [
    pytest.param("artifact.load", "io_error", "artifact.load_failed",
                 id="artifact-io-error"),
    pytest.param("artifact.load", "garbage", "artifact.load_failed",
                 id="artifact-garbage"),
    pytest.param("artifact.verify", "error", "artifact.rejected",
                 id="artifact-verify-error"),
]


@pytest.fixture(scope="module")
def tuned_artifact(tmp_path_factory):
    """Fault-free tuner fleet pass: the complete verified artifact every
    artifact-chaos case warm-starts from.  Module-scoped like `baseline` —
    one measured grid pays for all cases."""
    from repro import compiler
    from repro.tune.worker import run_fleet
    work = tmp_path_factory.mktemp("tuner")
    prev_env = os.environ.get("REPRO_CACHE_DIR")
    os.environ["REPRO_CACHE_DIR"] = str(work / "cache")
    prev_reg = set_default_registry(None)
    try:
        compiler.clear_memo()
        cfg = dataclasses.replace(load_arch(ARCH, smoke=True),
                                  attention_impl="pallas")
        out = run_fleet(cfg, BATCH, MAXLEN,
                        ledger_path=work / "ledger.json",
                        store_path=work / "tuner_cache.json",
                        out_path=work / "plans.artifact.json", n_shards=2,
                        worker_id="chaos-tuner")
        assert out["artifact"]["complete"] is True
    finally:
        if prev_env is None:
            os.environ.pop("REPRO_CACHE_DIR", None)
        else:
            os.environ["REPRO_CACHE_DIR"] = prev_env
        set_default_registry(prev_reg)
    return work / "plans.artifact.json"


def _warm_engine(artifact_path) -> Engine:
    """_fresh_engine with the plan artifact preloaded at warmup."""
    from repro import compiler
    compiler.clear_memo()
    set_default_registry(PlanRegistry())
    cfg = dataclasses.replace(load_arch(ARCH, smoke=True),
                              attention_impl="pallas")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params,
                  ServeConfig(batch=BATCH, max_len=MAXLEN,
                              plan_artifact=str(artifact_path)))


@pytest.mark.parametrize("site,action,counter", ARTIFACT_MATRIX)
def test_serve_completes_under_artifact_fault(baseline, tuned_artifact,
                                              site, action, counter):
    """A faulted artifact load/verify degrades to local measurement — the
    replica warms up the classic way, serves fault-free tokens at parity,
    and the degradation is counted, never silent."""
    before = _ctr(counter)
    injected = _ctr("faults.injected")
    with faults.inject(faults.FaultRule(site, action)):
        eng = _warm_engine(tuned_artifact)
        toks, lgs = _serve(eng)
    _assert_parity(baseline, toks, lgs)
    assert _ctr("faults.injected") > injected, "the fault never fired"
    assert _ctr(counter) > before, \
        f"{counter} did not move under a {site}/{action} fault"
    stats = eng.stats()
    assert stats["warmup_failed"] == 0
    if site == "artifact.verify":
        # per-entry degrade: every entry rejected, none preloaded, and the
        # local re-measure served the whole grid anyway
        assert stats["artifact"]["rejected"] == stats["artifact"]["total"] > 0
        assert stats["artifact"]["verified"] == 0
    else:
        # whole-file degrade: the preload reports the load error and the
        # warmup proceeds exactly as if no artifact existed
        assert "error" in stats["artifact"]
        assert stats["artifact"]["verified"] == 0


def test_tuner_survives_lease_faults(baseline, tmp_path):
    """Ledger I/O faults mid-fleet (`tune.lease` io_error) cost bounded
    retries, not the run: the fleet still completes the grid, publishes a
    complete artifact, and a replica warm-starts from it with zero
    measurements at full parity."""
    from repro.tune.worker import run_fleet
    cfg = dataclasses.replace(load_arch(ARCH, smoke=True),
                              attention_impl="pallas")
    rule = faults.FaultRule("tune.lease", "io_error", times=2)
    with faults.inject(rule):
        out = run_fleet(cfg, BATCH, MAXLEN,
                        ledger_path=tmp_path / "ledger.json",
                        store_path=tmp_path / "tuner_cache.json",
                        out_path=tmp_path / "plans.artifact.json",
                        n_shards=2, worker_id="chaos-tuner")
    assert rule.fired >= 1, "the lease fault never fired"
    assert out["artifact"]["complete"] is True
    assert not out["worker"]["failed"]

    # warm-start replica in a genuinely cold cache dir: zero measurements
    os.environ["REPRO_CACHE_DIR"] = str(tmp_path / "replica-cache")
    measured = _ctr("registry.measure")
    eng = _warm_engine(tmp_path / "plans.artifact.json")
    toks, lgs = _serve(eng)
    _assert_parity(baseline, toks, lgs)
    assert eng.stats()["warmup_measured"] == 0
    assert _ctr("registry.measure") == measured
