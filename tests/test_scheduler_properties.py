"""Property-style scheduler tests (overload controls, docs/serving.md).

Three properties that must hold on *any* seeded overload trace, not just
the curated ones in ``tests/test_scheduler.py``:

* **Conservation under preemption** — every submitted request is completed
  or shed exactly once; a completed request was admitted into a slot
  exactly ``1 + preemptions`` times; a shed request never touched a slot.
* **Chunk-boundary token parity** — serving with any prefill chunk budget
  yields bit-identical tokens to serving without one.
* **Admission-queue bound** — with ``max_queue`` set, the queue depth in
  every per-step snapshot stays within the bound.

Each property is stated twice, following the idiom of
``tests/test_registry.py``: once as a ``hypothesis`` ``@given`` test over
random seeds/shapes (skipped when hypothesis is not installed — see
``hypothesis_compat``), and once as a deterministic seeded sweep that
always runs, so the properties stay enforced in every container.
"""
import dataclasses
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.base import load_arch
from repro.models import model as model_mod
from repro.serve import scheduler as sched
from repro.serve.engine import Engine, ServeConfig

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import given, settings, st  # noqa: E402

_ENGINE = None


def _get_engine():
    """Lazily-built module engine (plain function, not a fixture, so the
    ``@given`` tests can reach it under real hypothesis too)."""
    global _ENGINE
    if _ENGINE is None:
        cfg = dataclasses.replace(load_arch("qwen3-0.6b", smoke=True),
                                  attention_impl="xla_chunked",
                                  kernel_plan="direct")
        params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
        _ENGINE = Engine(cfg, params,
                         ServeConfig(batch=2, max_len=32, warmup=False))
    return _ENGINE


def _workload(seed, n=18, rate=2.0, vocab=None):
    return sched.synthetic_workload(
        n, seed=seed, prompt_lens=(2, 5, 9, 14), new_tokens=(1, 3, 5),
        arrival_rate=rate, vocab=vocab or _get_engine().cfg.vocab_size,
        prompt_len_weights=(0.4, 0.3, 0.2, 0.1),
        deadlines_ms=(8, 30, None), priorities=(0, 1))


def _check_conservation(seed, rate):
    eng = _get_engine()
    reqs = _workload(seed, rate=rate)
    max_queue = 6
    admissions, preemptions, depth_ok = {}, {}, []

    def hook(snap):
        depth_ok.append(len(snap["queue"]) <= max_queue)
        for rid in snap["admitted"]:
            admissions[rid] = admissions.get(rid, 0) + 1
        for rid in snap["preempted"]:
            preemptions[rid] = preemptions.get(rid, 0) + 1
        assert (snap["pending"] + len(snap["queue"]) + snap["occupancy"]
                + snap["completed"] + snap["shed"]) == len(reqs), snap

    completed, shed = eng.serve_stream(
        reqs, max_slots=2, step_hook=hook, prefill_chunk_tokens=4,
        preempt_policy="lowest_priority", max_queue=max_queue,
        deadline_aware=True, return_shed=True)
    done = {r.rid for r in completed}
    dropped = {s.rid for s in shed}
    assert done | dropped == {r.rid for r in reqs}
    assert not (done & dropped)
    assert all(depth_ok), "admission-queue bound exceeded"
    assert not (dropped & set(admissions)), "shed request reached a slot"
    for r in completed:
        assert admissions.get(r.rid) == 1 + r.preemptions, \
            (r.rid, admissions.get(r.rid), r.preemptions)
        assert preemptions.get(r.rid, 0) == r.preemptions


def _check_chunk_parity(seed, chunk):
    eng = _get_engine()
    reqs = sched.synthetic_workload(
        5, seed=seed, prompt_lens=(3, 9, 15), new_tokens=(2, 4),
        arrival_rate=0.7, vocab=eng.cfg.vocab_size)
    plain = {r.rid: r.tokens for r in eng.serve_stream(reqs)}
    chunked = eng.serve_stream(reqs, prefill_chunk_tokens=chunk)
    for r in chunked:
        np.testing.assert_array_equal(
            r.tokens, plain[r.rid],
            err_msg=f"seed={seed} chunk={chunk} rid={r.rid}")


# ------------------------------------------------- hypothesis properties ---
@given(seed=st.integers(min_value=0, max_value=1 << 12),
       rate=st.sampled_from([1.5, 2.0, 3.0]))
@settings(max_examples=8, deadline=None)
def test_conservation_property(seed, rate):
    """Property: admitted = completed + shed exactly once each, slot
    admissions match preemption counts, queue bound holds — any seed."""
    _check_conservation(seed, rate)


@given(seed=st.integers(min_value=0, max_value=1 << 12),
       chunk=st.integers(min_value=2, max_value=9))
@settings(max_examples=6, deadline=None)
def test_chunk_parity_property(seed, chunk):
    """Property: any chunk budget reproduces the unchunked tokens."""
    _check_chunk_parity(seed, chunk)


# ------------------------------------------------- deterministic sweeps ----
@pytest.mark.parametrize("seed,rate", [(0, 2.0), (7, 1.5), (23, 3.0)])
def test_conservation_sweep(seed, rate):
    """Deterministic sweep of the conservation property (always runs)."""
    _check_conservation(seed, rate)


@pytest.mark.parametrize("seed,chunk", [(1, 4), (2, 7)])
def test_chunk_parity_sweep(seed, chunk):
    """Deterministic sweep of the chunk-parity property (always runs)."""
    _check_chunk_parity(seed, chunk)
