"""Plan-registry tests: shape bucketing, the cold-miss → measure → warm-hit
lifecycle, corrupted-state degradation (mirroring the compile-cache negative
paths), the ragged grouped-gemm serving entry, and end-to-end parity of the
model layers' registry route vs the direct ``kernels.ops`` reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler import CompileCache
from repro.compiler.registry import (BucketPolicy, PlanRegistry,
                                     default_registry, set_default_registry)
from repro.configs.base import load_arch


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets a private persistent cache and a fresh default
    registry (the module singleton is process-wide state)."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    old = set_default_registry(None)
    yield
    set_default_registry(old)


def _rng_ints(shape, lo=-2, hi=3, seed=0):
    return np.random.default_rng(seed).integers(lo, hi, shape).astype(
        np.float32)


# ------------------------------------------------------------- bucketing ----
def test_bucket_policy_boundaries():
    pol = BucketPolicy(seq_min=16, batch_min=1, row_block=16)
    assert pol.bucket_seq(1) == 16
    assert pol.bucket_seq(16) == 16      # exact boundary stays
    assert pol.bucket_seq(17) == 32      # one past the boundary jumps
    assert pol.bucket_seq(32) == 32
    assert pol.bucket_seq(33) == 64
    assert pol.bucket_batch(1) == 1
    assert pol.bucket_batch(3) == 4
    assert pol.bucket_group(0) == 0      # empty expert: no tiles
    assert pol.bucket_group(1) == 16
    assert pol.bucket_group(17) == 32
    assert pol.seq_grid(100) == [16, 32, 64, 128]
    # per-slot decode positions bucket on the furthest lane
    assert pol.bucket_pos(0) == 16
    assert pol.bucket_pos(np.array([3, 40, 7])) == 64


# The BucketPolicy properties every dimension must hold.  Written twice on
# purpose: once property-based through the hypothesis shim (broad random
# coverage where the container has hypothesis installed, a single skip
# where it does not) and once as deterministic pow2-boundary sweeps that
# run everywhere — the invariants themselves are always exercised in
# tier-1.
def _check_bucket_invariants(pol: BucketPolicy, n: int, m: int):
    for fn, floor in ((pol.bucket_seq, pol.seq_min),
                      (pol.bucket_batch, pol.batch_min)):
        a, b = fn(min(n, m)), fn(max(n, m))
        assert a <= b, f"{fn.__name__} not monotone at ({n}, {m})"
        out = fn(n)
        assert out >= max(n, 1) and out >= floor, (fn.__name__, n, out)
        assert fn(out) == out, f"{fn.__name__} not idempotent at {n}"
    # bucket_pos maps a position (index) to the seq bucket covering slots
    # 0..pos: monotone, covering, and stable — every position inside a
    # padded bucket looks up that same bucket (pad-then-lookup idempotence
    # for the decode dim, where the "shape" is the furthest valid slot)
    assert pol.bucket_pos(min(n, m)) <= pol.bucket_pos(max(n, m))
    bp = pol.bucket_pos(n)
    assert bp >= n + 1 and bp >= pol.seq_min
    assert pol.bucket_pos(bp - 1) == bp
    g = pol.bucket_group(n)
    assert g >= n and pol.bucket_group(g) == g
    assert g == 0 or g % pol.row_block == 0


from hypothesis_compat import given, settings, st  # noqa: E402


@given(n=st.integers(min_value=0, max_value=1 << 16),
       m=st.integers(min_value=0, max_value=1 << 16))
@settings(max_examples=200, deadline=None)
def test_bucket_policy_properties(n, m):
    """Property-based (hypothesis): monotone, covering (bucket ≥ request ≥
    floor) and pad-then-lookup idempotent on random shape pairs."""
    _check_bucket_invariants(BucketPolicy(), n, m)


def test_bucket_policy_pow2_sweep():
    """Deterministic sweep of the same invariants over every pow2 boundary
    (2^k - 1, 2^k, 2^k + 1) up to 2^16, for batch/seq/pos/group dims."""
    pol = BucketPolicy()
    pts = sorted({p for k in range(0, 17)
                  for p in ((1 << k) - 1, 1 << k, (1 << k) + 1) if p >= 0})
    for n in pts:
        _check_bucket_invariants(pol, n, n + 1)
        _check_bucket_invariants(pol, n + 1, n)
        # lookup after padding lands in the same bucket: a padded call
        # can never cascade into a bigger plan than the original request
        assert pol.bucket_seq(pol.bucket_seq(n)) == pol.bucket_seq(n)
        assert pol.bucket_batch(pol.bucket_batch(n)) == pol.bucket_batch(n)
    # every seq_grid is exactly the reachable bucket set, sorted, unique
    for top in (16, 100, 4096):
        grid = pol.seq_grid(top)
        assert grid == sorted(set(grid))
        assert grid[-1] == pol.bucket_seq(top)
        assert all(pol.bucket_seq(g) == g for g in grid)


@pytest.mark.parametrize("s", [13, 16, 17])
def test_flash_bucket_boundary_parity(s):
    """Bucketed (padded) flash attention matches the direct ops path at,
    below and just past a bucket boundary — KV padding is masked out by
    causality, padded query rows are sliced away."""
    from repro.kernels import ops
    b, h, hkv, d = 3, 4, 2, 8
    q, k, v = (jnp.asarray(_rng_ints((b, hh, s, d), seed=i))
               for i, hh in enumerate((h, hkv, hkv)))
    reg = PlanRegistry(pump=1, cache=False)
    out = reg.flash_attention(q, k, v, causal=True)
    assert out.shape == (b, h, s, d)
    sb = reg.policy.bucket_seq(s)
    ref = ops.flash_attention(q, k, v, causal=True, bq=sb, bkv=sb, pump=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=5e-6)


def test_ssd_bucket_padding_is_identity():
    """L-padding the SSD scan with dt=0 steps is exact (state identity)."""
    from repro.kernels import ops
    b, l, h, p, n = 2, 24, 2, 4, 4
    rng = np.random.default_rng(3)
    x = jnp.asarray(_rng_ints((b, l, h, p), seed=1))
    dt = jnp.asarray(np.abs(rng.integers(0, 3, (b, l, h))) * 0.25 + 0.25,
                     dtype=jnp.float32)
    A = jnp.asarray(-(np.abs(rng.integers(0, 3, (h,))) * 0.25 + 0.25),
                    dtype=jnp.float32)
    B = jnp.asarray(_rng_ints((b, l, h, n), seed=2))
    C = jnp.asarray(_rng_ints((b, l, h, n), seed=4))
    reg = PlanRegistry(pump=1, cache=False)
    out = reg.ssd_scan(x, dt, A, B, C, chunk=8)   # 24 pads to bucket 32
    ref = ops.ssd_scan(x, dt, A, B, C, chunk=8, pump=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=5e-6)


# ------------------------------------------- miss → measure → hit lifecycle --
def test_cold_miss_measure_then_warm_hit(tmp_path):
    cache = CompileCache(tmp_path / "plans.json")
    reg = PlanRegistry(pump="measure", cache=cache)
    q = jnp.asarray(_rng_ints((1, 2, 13, 8)))
    k = jnp.asarray(_rng_ints((1, 2, 13, 8), seed=1))
    v = jnp.asarray(_rng_ints((1, 2, 13, 8), seed=2))
    reg.flash_attention(q, k, v, causal=True)
    assert reg.stats.misses == 1 and reg.stats.hits == 0
    assert reg.stats.measure_s > 0          # cold: paid the timing runs
    [plan] = reg.plans()
    assert plan["measured"] and not plan["replayed"]

    # same bucket (13 and 15 both pad to 16): O(1) warm hit, no compile
    pad2 = ((0, 0), (0, 0), (0, 2), (0, 0))
    reg.flash_attention(jnp.pad(q, pad2), jnp.pad(k, pad2), jnp.pad(v, pad2),
                        causal=True)
    assert reg.stats.hits == 1 and reg.stats.misses == 1

    # fresh registry, same persistent cache = a new serving process
    # (clear_memo drops the in-process kernels a real restart wouldn't
    # have): the measured plan replays from disk without re-measurement
    from repro import compiler
    compiler.clear_memo()
    reg2 = PlanRegistry(pump="measure", cache=CompileCache(
        tmp_path / "plans.json"))
    reg2.flash_attention(q, k, v, causal=True)
    [plan2] = reg2.plans()
    assert plan2["replayed"] is True
    assert plan2["factor"] == plan["factor"]
    assert reg2.stats.measure_s == 0.0      # replay never re-times


def test_same_bucket_different_shapes_share_one_plan():
    reg = PlanRegistry(pump=1, cache=False)
    for s in (9, 12, 16):                   # all bucket to 16
        x = jnp.asarray(_rng_ints((1, 2, s, 8), seed=s))
        reg.flash_attention(x, x[:, :2], x[:, :2], causal=True)
    assert reg.stats.misses == 1 and reg.stats.hits == 2
    assert len(reg.plans()) == 1


def test_corrupted_registry_state_degrades_to_cold_compile(tmp_path):
    """Garbage in the persistent plan store must degrade to a cold compile
    (mirror of the compile-cache corruption negative paths)."""
    path = tmp_path / "plans.json"
    path.write_text('{"entries": {"x": 41,,}')     # invalid JSON
    reg = PlanRegistry(pump=1, cache=CompileCache(path))
    q = jnp.asarray(_rng_ints((1, 2, 16, 8)))
    out = reg.flash_attention(q, q, q, causal=True)
    assert out.shape == (1, 2, 16, 8)
    assert reg.stats.misses == 1 and reg.stats.fallbacks == 0
    # and the rebuilt store serves the next fresh process from disk
    from repro import compiler
    compiler.clear_memo()
    reg2 = PlanRegistry(pump=1, cache=CompileCache(path))
    reg2.flash_attention(q, q, q, causal=True)
    [plan] = reg2.plans()
    assert plan["served_from"] == "disk"


def test_jax_version_is_part_of_the_cache_key(monkeypatch):
    """Measured plans persisted under one jax build must not be replayed
    under another: the version is folded into every request key."""
    from repro.compiler import request_key
    from repro.core.autopump import BUILDERS
    g, _ = BUILDERS["vecadd"](64)
    k1 = request_key(g, factor=1)
    monkeypatch.setattr(jax, "__version__", "0.0.0-other")
    k2 = request_key(g, factor=1)
    assert k1 != k2


def test_mixed_carry_reduction_warning_names_symbols():
    """A carry region with extra reduction symbols falls to the gather tier
    with a warning naming the region and the symbols (serving-path tier
    regressions must be diagnosable from PipelineReport.warnings)."""
    from repro.compiler.pallas_backend import partition_regions, plan_region
    from repro.core.ir import CarrySpec, Graph
    from repro.core.symbolic import AccessPattern, Affine, Domain

    g = Graph("mixcr")
    g.memory("x", (8, 4))
    g.memory("o", (8,))
    dom = Domain.of(("ci", 0, 2), ("ri", 0, 2))
    acc_x = AccessPattern(
        Domain.of(("ci", 0, 2), ("ri", 0, 2), ("r", 0, 4)),
        (Affine.of("ci", 4) + Affine.of("r"), Affine.of("ri", 2)), width=2)
    acc_o = AccessPattern(
        Domain.of(("ci", 0, 2), ("r", 0, 4)),
        (Affine.of("ci", 4) + Affine.of("r"),), width=1)

    def step(carry, blk):
        (s,) = carry
        return (s + blk.sum(axis=-1),), None

    g.compute("acc", dom,
              carry=CarrySpec(axis="ci", state=(((4,), "float32"),),
                              step_fn=step,
                              final_fn=lambda c: {"out0": c[0]}))
    g.connect("x", "acc", acc_x)
    g.connect("acc", "o", acc_o)

    [region] = partition_regions(g)
    notes = []
    assert plan_region(g, region, notes.append) is None
    msg = [n for n in notes if "mixed carry+reduction" in n]
    assert msg, notes
    assert "'ci'" in msg[0] and "ri" in msg[0] and region.name in msg[0]


# ------------------------------------------------------ ragged grouped gemm --
def test_ops_ragged_grouped_gemm_matches_per_expert_matmul():
    from repro.kernels import ops
    sizes = [5, 0, 12, 3]
    e, d, f = 4, 8, 10
    x = _rng_ints((sum(sizes), d), seed=7)
    w = _rng_ints((e, d, f), seed=8)
    out = ops.grouped_gemm(jnp.asarray(x), jnp.asarray(w), bc=4,
                           group_sizes=sizes)
    ref, off = [], 0
    for ei, sz in enumerate(sizes):
        ref.append(x[off:off + sz] @ w[ei])
        off += sz
    np.testing.assert_array_equal(np.asarray(out), np.concatenate(ref))


def test_ops_ragged_rejects_bad_sizes():
    from repro.kernels import ops
    x = jnp.zeros((8, 4))
    w = jnp.zeros((2, 4, 4))
    with pytest.raises(ValueError):
        ops.grouped_gemm(x, w, group_sizes=[4, 5])   # rows mismatch
    with pytest.raises(ValueError):
        ops.grouped_gemm(x, w, group_sizes=[8], bc=4)  # wrong expert count
    with pytest.raises(ValueError):
        ops.grouped_gemm(x, w, group_sizes=[4, 4], impl="pallas")


def test_moe_ragged_dropless_matches_dense_path():
    """The ragged serving path (registry grouped GEMM over per-expert row
    groups) agrees with the dense dropless einsum reference."""
    from repro.models import moe as moe_mod
    cfg = load_arch("deepseek-v2-lite-16b", smoke=True)
    cfg_r = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ragged_dropless=True))
    p = moe_mod.moe_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    y_dense, aux_dense = moe_mod.moe_apply(p, cfg, x, dropless=True)
    y_ragged, aux_ragged = moe_mod.moe_apply(p, cfg_r, x, dropless=True)
    np.testing.assert_allclose(np.asarray(y_ragged), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_ragged), float(aux_dense))
    # under jit the routing is traced: the ragged path must quietly keep
    # the dense reference path instead of crashing on tracers
    y_jit, _ = jax.jit(
        lambda xx: moe_mod.moe_apply(p, cfg_r, xx, dropless=True))(x)
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-5)


def test_ragged_plans_never_measure_on_the_hot_path():
    """Routing shifts per request, so ragged group-size tuples keep
    producing fresh plan keys — those cold misses must pay a capacity-model
    compile (milliseconds), never a measured autotune (seconds of timing
    runs) mid-request."""
    reg = PlanRegistry(pump="measure", cache=False)   # ragged_pump='auto'
    w = jnp.asarray(_rng_ints((3, 8, 8), seed=9))
    for sizes in ([4, 3, 5], [1, 11, 0], [6, 0, 6]):  # three routings
        x = jnp.asarray(_rng_ints((sum(sizes), 8), seed=sum(sizes)))
        reg.grouped_gemm(x, w, group_sizes=sizes)
    assert reg.stats.measure_s == 0.0
    assert all(not pl["measured"] for pl in reg.plans())


def test_kernel_plan_typo_is_rejected():
    cfg = load_arch("qwen3-0.6b", smoke=True)
    with pytest.raises(ValueError, match="kernel_plan"):
        dataclasses.replace(cfg, kernel_plan="measured")


# -------------------------------------------------- end-to-end model parity --
def test_forward_registry_route_matches_direct_route():
    """transformer + ssm step through the registry ('measure') is within
    carry-accumulation tolerance of the direct kernels.ops path
    ('direct') — the measured pump factor must not change the math."""
    from repro.models import model as model_mod, transformer
    for arch, impl_field in (("qwen3-0.6b", "attention_impl"),
                             ("mamba2-1.3b", "ssm_impl")):
        cfg = dataclasses.replace(load_arch(arch, smoke=True),
                                  **{impl_field: "pallas"})
        cfg_dir = dataclasses.replace(cfg, kernel_plan="direct")
        params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                  cfg.vocab_size)
        l_reg, _ = transformer.forward(cfg, params, toks)
        l_dir, _ = transformer.forward(cfg_dir, params, toks)
        np.testing.assert_allclose(np.asarray(l_reg), np.asarray(l_dir),
                                   rtol=2e-5, atol=5e-6, err_msg=arch)


def test_warmup_grid_makes_real_calls_pure_hits():
    from repro.models import model as model_mod, transformer
    cfg = dataclasses.replace(load_arch("qwen3-0.6b", smoke=True),
                              attention_impl="pallas")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    reg = default_registry()
    reqs = transformer.plan_requests(cfg, 2, 16)
    assert reqs, "pallas attention config must enumerate warmup requests"
    reg.warmup(reqs)
    before = reg.stats.misses
    transformer.forward(cfg, params, toks)
    assert reg.stats.misses == before       # every real call was a hit
    assert reg.stats.hits > 0


def test_engine_registry_serving_matches_xla_engine():
    """Engine generation over the registry path (pallas attention,
    measured plans) produces the same tokens as the xla_chunked engine,
    and reports warmup/compile time separately from steady-state."""
    from repro.models import model as model_mod
    from repro.serve.engine import Engine, ServeConfig
    cfg = load_arch("qwen3-0.6b", smoke=True)
    cfg_pl = dataclasses.replace(cfg, attention_impl="pallas")
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch=2, max_len=16)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out_x = Engine(cfg, params, scfg).generate(prompts, 4)
    eng = Engine(cfg_pl, params, scfg)
    assert eng.warmup_s > 0 and eng.warmup_report   # grid pre-measured
    out_r = eng.generate(prompts, 4)
    np.testing.assert_array_equal(np.asarray(out_r), np.asarray(out_x))
    st = eng.stats()
    assert st["phases"]["decode"]["steps"] == 3     # first step = compile
    assert st["phases"]["decode"]["compile_s"] > 0
    assert st["registry"]["hits"] >= 1
