"""Compiler-pipeline benchmark: backend wall time, compile latency, cache
behaviour, autotune, parity — tracked across PRs via ``BENCH_compiler.json``.

    PYTHONPATH=src python -m benchmarks.run --mode compiler [--smoke]

For every kernel × backend (per-node ``jax`` lowering vs fused-region
``pallas`` emission) × pump factor {1, 2, 4} it records execution wall time,
cold/warm compile latency and cache layer, plus a measured-runtime autotune
entry demonstrating that a repeat ``compile(..., autotune='measure')`` is a
cache hit that skips re-measurement.  Since the kernel library was subsumed
by the compiler, the tracked set includes the three formerly hand-wired
kernels — flash attention (multi-output carry region), the SSD scan
(sequential-carry chunk loop) and grouped gemm (reduction-accumulated
expert tiles).  The JSON lands at the repo root (``--smoke`` uses tiny
shapes and writes ``BENCH_compiler_smoke.json``) so the perf trajectory —
in particular *fused backend beats per-node lowering on matmul at factor ≥
2* — is diffable across PRs.

Also emits the standard ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import json
import platform
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro import compiler, obs
from repro.compiler import CompileCache
from repro.core import executor
from repro.core.autopump import BUILDERS

from .common import emit, time_fn

FACTORS = (1, 2, 4)
BACKENDS = ("jax", "pallas")


def _cases(smoke: bool):
    rng = np.random.default_rng(0)

    def ints(shape, lo=-4, hi=5):
        return rng.integers(lo, hi, shape).astype(np.float32)

    def ssd_inputs(b, l, h, n):
        return {"x": ints((b, l, h, 4)),
                "dt": np.abs(ints((b, l, h))) * 0.25 + 0.25,
                "a": -(np.abs(ints((h,))) * 0.25 + 0.25),
                "bmat": ints((b, l, h, n)), "cmat": ints((b, l, h, n))}

    # (name, builder args, builder kwargs, out memory, inputs, exact?) —
    # flash/ssd contain exp (numpy vs XLA differ by 1 ULP), so their parity
    # contract is 'close' instead of bit-exact; see tests/differential.py
    if smoke:
        specs = [
            ("vecadd", (256,), dict(vector_width=8), "z",
             lambda: {"x": ints(256), "y": ints(256)}, True),
            ("matmul", (64, 64, 64), dict(bm=16, bn=16, bk=16,
                                          vector_width=8), "c",
             lambda: {"a": ints((64, 64)), "b": ints((64, 64))}, True),
            ("flash_attention", (1, 2, 16, 16, 8),
             dict(bq=8, bkv=8, vector_width=8), "o",
             lambda: {"q": ints((1, 2, 16, 8)), "k": ints((1, 2, 16, 8)),
                      "v": ints((1, 2, 16, 8))}, False),
            ("ssd_scan", (1, 16, 2, 4, 4), dict(chunk=4, vector_width=8),
             "y", lambda: ssd_inputs(1, 16, 2, 4), False),
            ("grouped_gemm", (2, 16, 8, 8),
             dict(bc=8, bf=8, bd=8, vector_width=8), "o",
             lambda: {"x": ints((2, 16, 8)), "w": ints((2, 8, 8))}, True),
        ]
    else:
        specs = [
            ("vecadd", (65536,), dict(vector_width=8), "z",
             lambda: {"x": ints(65536), "y": ints(65536)}, True),
            ("matmul", (256, 256, 256), dict(bm=64, bn=64, bk=64,
                                             vector_width=8), "c",
             lambda: {"a": ints((256, 256)), "b": ints((256, 256))}, True),
            ("stencil", (34, 32, 32), dict(), "y",
             lambda: {"x": ints((34, 32, 32))}, True),
            ("floyd_warshall", (48,), dict(), "out",
             lambda: {"dist": ints((48, 48), 1, 9)}, True),
            ("flash_attention", (2, 4, 128, 128, 32),
             dict(bq=32, bkv=32, vector_width=8), "o",
             lambda: {"q": ints((2, 4, 128, 32)), "k": ints((2, 4, 128, 32)),
                      "v": ints((2, 4, 128, 32))}, False),
            ("ssd_scan", (2, 256, 4, 4, 8), dict(chunk=16, vector_width=8),
             "y", lambda: ssd_inputs(2, 256, 4, 8), False),
            ("grouped_gemm", (8, 64, 64, 64),
             dict(bc=32, bf=32, bd=32, vector_width=8), "o",
             lambda: {"x": ints((8, 64, 64)), "w": ints((8, 64, 64))}, True),
        ]
    return [(name, args, kw, out, mk(), exact)
            for name, args, kw, out, mk, exact in specs]


def run_report(smoke: bool = False, out_path=None) -> dict:
    cache_dir = Path(tempfile.mkdtemp(prefix="repro-bench-cache-"))
    cache_path = cache_dir / "bench_cache.json"
    report = {
        "schema": 1,
        "smoke": smoke,
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "entries": [],
        "autotune": {},
    }

    for name, args, kw, out_name, inputs, exact in _cases(smoke):
        for backend in BACKENDS:
            for factor in FACTORS:
                g, _ = BUILDERS[name](*args, **kw)
                cache = CompileCache(cache_path)
                t0 = time.perf_counter()
                kern = compiler.compile(g, factor=factor, backend=backend,
                                        cache=cache, memoize=False)
                cold_us = (time.perf_counter() - t0) * 1e6
                t0 = time.perf_counter()
                kern2 = compiler.compile(g, factor=factor, backend=backend,
                                         cache=CompileCache(cache_path),
                                         memoize=False)
                warm_us = (time.perf_counter() - t0) * 1e6

                wall_us = time_fn(kern.fn, inputs)
                out = np.asarray(kern(inputs)[out_name])
                gold = executor.run(kern.graph, dict(inputs))[out_name]
                if np.array_equal(out, gold):
                    parity = "bitexact"
                elif not exact and np.allclose(out, gold, rtol=1e-5,
                                               atol=1e-4):
                    # exp: numpy vs XLA differ by 1 ULP; benchmark shapes
                    # accumulate it (tight bounds live in the tier-1
                    # differential harness at tiny shapes)
                    parity = "close"
                else:
                    parity = "MISMATCH"
                tiers = sorted({v["tier"] for v in
                                (kern.report.emission or {}).values()})
                entry = {
                    "kernel": name, "backend": backend, "factor": factor,
                    "achieved_factor": kern.spec.factor,
                    "wall_us": round(wall_us, 1),
                    "compile_cold_us": round(cold_us, 1),
                    "compile_warm_us": round(warm_us, 1),
                    "cache_cold": kern.report.served_from or "miss",
                    "cache_warm": kern2.report.served_from or "miss",
                    "emission": tiers,
                    "parity": parity,
                }
                report["entries"].append(entry)
                emit(f"compiler_{name}_{backend}_M{factor}", wall_us,
                     f"cold={cold_us:.0f}us;warm={warm_us:.0f}us;"
                     f"cache={entry['cache_warm']};{entry['parity']}")

        # measured-runtime autotune: first compile measures, repeat is a
        # cache hit that replays the plan without re-measuring
        g, est = BUILDERS[name](*args, **kw)
        t0 = time.perf_counter()
        k1 = compiler.compile(g, factor="auto", estimate=est,
                              backend="pallas", autotune="measure",
                              cache=CompileCache(cache_path), memoize=False)
        measure_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        k2 = compiler.compile(g, factor="auto", estimate=est,
                              backend="pallas", autotune="measure",
                              cache=CompileCache(cache_path), memoize=False)
        replay_us = (time.perf_counter() - t0) * 1e6
        report["autotune"][name] = {
            "winner": k1.report.autotune["winner"],
            "timings_us": k1.report.autotune["timings_us"],
            "measure_compile_us": round(measure_us, 1),
            "replay_compile_us": round(replay_us, 1),
            "replay_served_from": k2.report.served_from,
            "replay_skipped_measurement": bool(
                k2.report.autotune and k2.report.autotune.get("replayed")),
        }
        emit(f"compiler_{name}_autotune", measure_us,
             f"winner=M{k1.report.autotune['winner']};"
             f"replay={replay_us:.0f}us;"
             f"served={k2.report.served_from}")

    # headline: fused backend vs per-node lowering on matmul at factor >= 2
    walls = {(e["kernel"], e["backend"], e["factor"]): e["wall_us"]
             for e in report["entries"]}
    speedups = {}
    for f in FACTORS:
        jax_t = walls.get(("matmul", "jax", f))
        pal_t = walls.get(("matmul", "pallas", f))
        if jax_t and pal_t:
            speedups[str(f)] = round(jax_t / pal_t, 2)
    report["matmul_pallas_speedup_vs_jax"] = speedups
    emit("compiler_matmul_speedup", 0.0,
         ";".join(f"M{f}={s}x" for f, s in speedups.items()))

    # unified metrics snapshot: compile/cache counters + emission-tier mix
    # accumulated over the whole run.  A report without it means the obs
    # spine went dark — fail loudly rather than ship a blind artifact.
    report["metrics"] = obs.snapshot()
    if not report["metrics"].get("counters"):
        raise RuntimeError(
            "BENCH_compiler: embedded metrics snapshot is empty — "
            "the obs spine recorded no counters during the run")

    if out_path is None:
        out_path = Path(__file__).resolve().parents[1] / (
            "BENCH_compiler_smoke.json" if smoke else "BENCH_compiler.json")
    out_path = Path(out_path)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    return report


def main(smoke: bool = False) -> None:
    run_report(smoke=smoke)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
