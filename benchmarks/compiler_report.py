"""Compiler-pipeline benchmark: compile latency, cache behaviour, parity.

    PYTHONPATH=src python -m benchmarks.run compiler

Emits the standard ``name,us_per_call,derived`` rows: cold compile (full
pass pipeline + lowering), warm compile (served from the persistent cache /
in-process memo), and lowered-vs-reference-executor parity for the vecadd
and matmul IR graphs.
"""
from __future__ import annotations

import time

import numpy as np

from repro import compiler
from repro.core import executor
from repro.core.autopump import BUILDERS

from .common import emit


def _cases():
    rng = np.random.default_rng(0)
    g_va, _ = BUILDERS["vecadd"](4096, vector_width=8)
    va_inputs = {"x": rng.integers(-4, 5, 4096).astype(np.float32),
                 "y": rng.integers(-4, 5, 4096).astype(np.float32)}
    g_mm, _ = BUILDERS["matmul"](64, 64, 64, bm=32, bn=32, bk=32,
                                 vector_width=8)
    mm_inputs = {"a": rng.integers(-3, 4, (64, 64)).astype(np.float32),
                 "b": rng.integers(-3, 4, (64, 64)).astype(np.float32)}
    return [("vecadd", g_va, va_inputs, "z"),
            ("matmul", g_mm, mm_inputs, "c")]


def main() -> None:
    for name, g, inputs, out_name in _cases():
        t0 = time.perf_counter()
        kern = compiler.compile(g, factor=2)
        cold_us = (time.perf_counter() - t0) * 1e6
        emit(f"compile_{name}_cold", cold_us,
             f"M={kern.spec.factor};{kern.report.summary().split('] ')[1]}")

        t0 = time.perf_counter()
        kern2 = compiler.compile(g, factor=2)
        warm_us = (time.perf_counter() - t0) * 1e6
        emit(f"compile_{name}_warm", warm_us,
             f"served={kern2.report.served_from};hits={kern2.report.cache_hits}")

        out = np.asarray(kern(inputs)[out_name])
        gold = executor.run(kern.graph, dict(inputs))[out_name]
        parity = "bitexact" if np.array_equal(out, gold) else "MISMATCH"
        emit(f"compile_{name}_parity", 0.0, parity)


if __name__ == "__main__":
    main()
