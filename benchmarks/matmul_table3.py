"""Paper Table 3: communication-avoiding MMM, Original vs Double-Pumped vs
scaled-PE Double-Pumped.

Paper claims on the U280: DP at equal PEs → DSP 90→45.6 %, BRAM 80→47 %,
perf −14 % (effective-rate loss); reinvesting the savings (32→64 PEs) →
+15 % end-to-end and MOp/s-per-DSP 98.8→167.

TPU analogues: compute-tile bytes per MXU issue (DSP analogue), wide-DMA
transactions, modeled TPU step time under the effective-rate law, measured
interpret-mode wall time for correctness-at-equal-throughput, and
MOp-per-tile-byte (the per-DSP efficiency metric).  "More PEs" maps to a
larger output tile per core once the per-issue footprint halves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ir import PumpSpec
from repro.core.pump_plan import HBM_BW, PEAK_FLOPS_BF16
import repro.kernels.matmul as mm_mod
from repro.kernels import ops, ref

from .common import emit, time_fn

M = N = K = 256
BM = BN = 64
BK = 32


def modeled_gops(bm, bn, bk, pump: PumpSpec) -> float:
    """TPU effective-rate model: one grid step = one wide transaction."""
    mfac = pump.factor if pump.mode == "T" else 1
    block_bytes = (bm * bk + bk * bn) * 4 * mfac
    flops = 2.0 * bm * bn * bk * mfac
    if pump.mode == "R":
        flops = 2.0 * bm * bn * bk           # same work, narrower issues
    dma = block_bytes / HBM_BW + 1e-6
    compute = flops / PEAK_FLOPS_BF16 * (pump.factor if pump.mode == "R"
                                         else 1)
    step = max(dma, compute)
    return flops / step / 1e9


def main() -> None:
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    gold = np.asarray(ref.matmul(a, b))

    cases = [
        ("mmm_32PE_O", BM, BN, PumpSpec(1)),
        ("mmm_32PE_DP", BM, BN, PumpSpec(2, "R")),     # −50 % tile bytes
        ("mmm_64PE_DP", BM, BN * 2, PumpSpec(2, "R")),  # reinvest: 2× tile
    ]
    for name, bm, bn, spec in cases:
        fn = lambda x, y, bm=bm, bn=bn, spec=spec: ops.matmul(
            x, y, bm=bm, bn=bn, bk=BK, pump=spec)
        out = fn(a, b)
        np.testing.assert_allclose(np.asarray(out), gold, atol=2e-3)
        us = time_fn(fn, a, b)
        tx = mm_mod.transactions(M, N, K, bm, bn, BK, spec)
        tile = mm_mod.compute_tile_bytes(bm, bn, spec)
        gops = modeled_gops(bm, bn, BK, spec)
        op_per_byte = 2.0 * M * N * K / tile
        emit(name, us, f"tile_bytes={tile};tx={tx};"
             f"modeled_gops={gops:.1f};op_per_tile_byte={op_per_byte:.0f}")


if __name__ == "__main__":
    main()
